"""Motivation I: BoundedME as an approximate LMO inside Frank-Wolfe.

Frank-Wolfe over the convex hull of a vector set S solves
    min_{x in conv(S)} f(x)
and each iteration needs an LMO:  argmin_{v in S} <grad f(x), v>  — a MIPS
query with q = -grad.  Because x (hence q) changes every iteration, any
preprocessing-based index would have to amortize over ... one query.  This
is exactly the regime the paper targets: zero preprocessing, fresh bandit
per query, eps-optimal LMO (Jaggi 2013 shows FW tolerates eps-approximate
oracles with an O(eps) floor in the final gap).

    PYTHONPATH=src python examples/frank_wolfe_lmo.py
"""

import time

import jax
import numpy as np

from repro.core import bounded_me, reward_matrix


def frank_wolfe(S, target, iters=30, lmo="exact", eps=0.3, seed=0):
    """min_x ||x - target||^2 over conv(S) with exact or bandit LMO."""
    rng = np.random.default_rng(seed)
    n, N = S.shape
    x = S[0].copy()
    pulls = 0
    for t in range(iters):
        grad = 2.0 * (x - target)
        q = -grad
        if lmo == "exact":
            i = int(np.argmax(S @ q))
            pulls += n * N
        else:
            vr = float(np.abs(S).max() * np.abs(q).max())
            R = reward_matrix(S, q, rng)
            res = bounded_me(R, K=1, eps=eps * vr, delta=0.1,
                             value_range=2 * vr)
            i = int(res.topk[0])
            pulls += res.total_pulls
        gamma = 2.0 / (t + 2.0)
        x = (1 - gamma) * x + gamma * S[i]
    return x, pulls


def main():
    rng = np.random.default_rng(1)
    n, N = 1000, 20_000
    S = rng.normal(size=(n, N)).astype(np.float32)
    # target inside the hull: convex combo of a few atoms
    w = rng.dirichlet(np.ones(8))
    target = (w[None] @ S[:8]).ravel()

    for lmo, eps in (("exact", None), ("boundedme", 0.2),
                     ("boundedme", 0.5)):
        t0 = time.time()
        x, pulls = frank_wolfe(S, target, iters=25, lmo=lmo, eps=eps or 0)
        err = float(np.linalg.norm(x - target) / np.linalg.norm(target))
        tag = lmo if eps is None else f"{lmo}(eps={eps})"
        print(f"{tag:18s}: rel err {err:.4f}, "
              f"LMO multiplies {pulls / (25 * n * N):.2f}x naive, "
              f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
