"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Thin wrapper over the production launcher (`repro.launch.train`) with a
~100M-parameter config (mamba2-130m family at its published size is the
cheapest assigned arch; pass --arch to pick another).  On CPU this runs a
reduced-width variant by default; pass --full on real hardware.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="published size (needs accelerators)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--steps", str(args.steps),
           "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
           "--batch", "8", "--seq", "128", "--lr", "3e-3"]
    if not args.full:
        cmd.append("--smoke")
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
