"""End-to-end serving driver: batched requests, BoundedME logit search.

Trains nothing; loads a randomly initialized small model, prefills a batch
of prompts, and decodes greedily with the paper's bandit replacing the
final (d x vocab) matvec.  Compares against exact decode token-for-token.

    PYTHONPATH=src python examples/serve_decode_mips.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step


def main():
    # a small-but-real config: qwen1.5 family at reduced width, full vocab
    cfg = dataclasses.replace(
        REGISTRY["qwen1.5-0.5b"].smoke(),
        vocab=151_936, vocab_pad=2048, d_model=256, n_heads=8, d_head=32,
        n_kv_heads=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P, T = 8, 12, 20
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)

    results = {}
    for mode, eps in (("exact", None), ("boundedme", 0.1),
                      ("boundedme", 0.4)):
        c = dataclasses.replace(cfg, mips_mode=mode,
                                mips_eps=eps or cfg.mips_eps)
        _, caches = prefill_step(params, c, prompts, cache_len=P + T)
        dfn = jax.jit(lambda p, ca, t, pos, k, c=c: decode_step(
            p, c, ca, t, pos, key=k))
        tok = prompts[:, -1:]
        toks = []
        t0 = time.time()
        for i in range(T):
            nxt, caches = dfn(params, caches, tok, jnp.int32(P + i),
                              jax.random.PRNGKey(i))
            toks.append(np.asarray(nxt))
            tok = nxt[:, None]
        dt = time.time() - t0
        tag = mode if eps is None else f"{mode}(eps={eps})"
        results[tag] = np.stack(toks, 1)
        print(f"{tag:22s}: {T} tokens x {B} requests in {dt:.2f}s")

    ref = results["exact"]
    for tag, toks in results.items():
        if tag == "exact":
            continue
        agree = float((toks == ref).mean())
        print(f"{tag:22s}: token agreement with exact = {agree:.3f}")
    print("vocab =", cfg.vocab, "| the bandit searched",
          cfg.padded_vocab, "padded rows with zero preprocessing")


if __name__ == "__main__":
    main()
