"""Quickstart: MIPS with a suboptimality knob and zero preprocessing.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro.core import exact_topk, make_plan, mips_topk


def main():
    from repro.data.synthetic import mf_dataset

    # recommender-style item embeddings (the paper's fig-4 regime):
    # low-rank structure => real gaps between arm means => bandit wins
    n, N = 20_000, 8192
    V, q = mf_dataset(n, N, rank=32, seed=0)

    # exact baseline: full (n x N) matvec
    ids_exact, scores_exact = exact_topk(V, q, K=5)
    print("exact top-5:", np.asarray(ids_exact))

    # BoundedME: no index build, direct (eps, delta) control.
    # eps is on the mean-product scale; express it in units of the
    # cross-arm score spread so the knob is data-meaningful.
    sigma = float(np.std(V[:512] @ q / N))
    # soft value range (8-sigma of coordinate products): the paper assumes a
    # known reward range a priori ([0,1]); a hard max over outliers would be
    # needlessly conservative for heavy-tailed embedding data
    vr = float(8.0 * np.std(V) * np.std(q))
    for mult in (0.5, 2.0, 8.0):
        eps = mult * sigma
        plan = make_plan(n, N, K=5, eps=eps, delta=0.1, value_range=vr,
                         block=128)
        t0 = time.time()
        ids, scores = mips_topk(V, q, K=5, method="boundedme", eps=eps,
                                delta=0.1, value_range=vr,
                                key=jax.random.PRNGKey(0), final_exact=True,
                                block=128)
        overlap = len(set(np.asarray(ids).tolist())
                      & set(np.asarray(ids_exact).tolist()))
        print(f"eps={mult:3.1f}*sigma: top-5 overlap {overlap}/5, "
              f"FLOP speedup {plan.speedup:4.1f}x, "
              f"wall {time.time()-t0:.2f}s "
              f"(eps-optimal w.p. >= 0.9)")


if __name__ == "__main__":
    main()
