"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod 16x16 mesh:
  compute term    = HLO_FLOPs_per_chip / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_chip / HBM_bw              [s]
  collective term = collective_bytes_per_chip / link_bw      [s]
(cost_analysis reports the per-chip SPMD program, so no /chips is applied.)

Also reported: MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*tokens
(serve), the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips), the
dominant term, and a one-line lever.  Prefers `_unrolled` dry-run records
(exact FLOPs); scanned records are marked, their FLOPs being per-layer
undercounts.  An analytic attention-chunk correction is applied for
train/prefill cells (the q-chunk lax.map body is counted once by XLA).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from repro.configs import REGISTRY, SHAPES, cells

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link
RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
ATTN_CHUNK = 512


def model_flops(cfg, shape) -> float:
    """Useful FLOPs per step: the 6ND / 2ND convention + attention."""
    Na = cfg.active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * Na * B * S
        attn = 0.0
        if cfg.family != "ssm":
            frac_attn = (1.0 / cfg.attn_period) if cfg.attn_period else 1.0
            n_attn = cfg.n_layers * frac_attn
            attn = 3 * 2 * 2 * B * cfg.n_heads * cfg.head_dim * S * S / 2 \
                * n_attn
        return base + attn
    if shape.kind == "prefill":
        base = 2.0 * Na * B * S
        attn = 0.0
        if cfg.family != "ssm":
            frac_attn = (1.0 / cfg.attn_period) if cfg.attn_period else 1.0
            attn = 2 * 2 * B * cfg.n_heads * cfg.head_dim * S * S / 2 \
                * cfg.n_layers * frac_attn
        return base + attn
    # decode: one token, attention over the full cache
    base = 2.0 * Na * B
    attn = 0.0
    if cfg.family != "ssm":
        frac_attn = (1.0 / cfg.attn_period) if cfg.attn_period else 1.0
        attn = 2 * 2 * B * cfg.n_heads * cfg.head_dim * S \
            * cfg.n_layers * frac_attn
    return base + attn


def analytic_hbm_bytes(cfg, shape, chips: int = 256) -> float:
    """Per-chip HBM traffic model (cost_analysis 'bytes accessed' counts
    every fused intermediate, overstating HBM by ~10x; this is the standard
    weights+activations+cache accounting instead).

    train:   params (fwd read + bwd read + update rw) + f32 moments rw
             + remat'd layer-boundary activations (2x write+read)
    prefill: params read + KV write + boundary activations
    decode:  params read + full KV-cache read + state
    """
    p_bytes = cfg.n_params() * 2 / chips                     # bf16, sharded
    B, S = shape.global_batch, shape.seq_len
    d, L = cfg.d_model, cfg.n_layers
    # remat'd layer-boundary activations: bf16, write+read, x2 for recompute
    act = L * (B * S / chips) * d * 2 * 2 * 2
    if shape.kind == "train":
        moments = cfg.n_params() * (2 if cfg.n_params() > 50e9 else 4) \
            * 2 / chips                                      # mu+nu r/w -> x2
        w = p_bytes * 4                                      # fwd+bwd+rw upd
        return w + moments + act * 2
    kvh = cfg.n_kv_heads * cfg.head_dim
    frac_attn = (1.0 / cfg.attn_period) if cfg.attn_period else 1.0
    if cfg.family == "ssm":
        frac_attn = 0.0
    kv_bytes = B * S * kvh * 2 * L * frac_attn * 2 / chips   # k and v
    if shape.kind == "prefill":
        return p_bytes + kv_bytes + act
    # decode: every step streams all weights + the whole cache
    return p_bytes + kv_bytes + B * d * L * 2 * 4 / chips


def attn_chunk_correction(cfg, shape, n_devices: int) -> float:
    """Per-chip FLOPs missed because the q-chunk lax.map is counted once."""
    if shape.kind == "decode" or cfg.family == "ssm":
        return 0.0
    S = shape.seq_len if shape.kind != "prefill" else shape.seq_len
    n_chunks = max(1, S // ATTN_CHUNK)
    if n_chunks <= 1:
        return 0.0
    frac_attn = (1.0 / cfg.attn_period) if cfg.attn_period else 1.0
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd(+remat fwd) ~ 3x
    attn = 2 * 2 * shape.global_batch * cfg.n_heads * cfg.head_dim \
        * S * S / 2 * cfg.n_layers * frac_attn * mult
    return attn * (1.0 - 1.0 / n_chunks) / n_devices


def load_cell(arch: str, shape: str, mesh: str = "single",
              suffix: str = "") -> Optional[Dict]:
    for suf in ("_unrolled", "") if not suffix else (suffix,):
        path = os.path.join(RESULTS, f"{arch}_{shape}_{mesh}{suf}.json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("ok"):
                return rec
    return None


def analyse(rec: Dict, cfg, shape) -> Dict:
    chips = rec["n_devices"]
    corr = 0.0 if rec.get("unrolled") else None  # scanned: FLOPs undercount
    flops_chip = rec["flops"]
    if rec.get("unrolled"):
        flops_chip += attn_chunk_correction(cfg, shape, chips)
    t_comp = flops_chip / PEAK_FLOPS
    t_mem_hlo = rec["hlo_bytes_accessed"] / HBM_BW
    t_mem = analytic_hbm_bytes(cfg, shape, chips) / HBM_BW
    coll = rec["collectives"]["total_bytes"]
    t_coll = coll / LINK_BW
    mf = model_flops(cfg, shape)
    ratio = mf / (flops_chip * chips) if flops_chip > 0 else float("nan")
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = {k: v / bound for k, v in terms.items()}
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_memory_hlo_s": t_mem_hlo, "t_collective_s": t_coll,
        "dominant": dom, "model_flops": mf, "hlo_flops_chip": flops_chip,
        "useful_ratio": ratio, "exact_flops": bool(rec.get("unrolled")),
        "step_bound_s": bound,
        "roofline_fraction": t_comp / bound if bound > 0 else 0.0,
    }


LEVERS = {
    ("compute", "train"): "more chips / reduce remat recompute",
    ("compute", "prefill"): "attention-kernel fusion (flash) to cut "
                            "softmax overhead FLOPs",
    ("compute", "decode"): "batch more requests per step",
    ("memory", "train"): "larger per-chip batch to raise arithmetic "
                         "intensity; fuse optimizer update",
    ("memory", "prefill"): "KV-cache layout fusion; wider q-chunks",
    ("memory", "decode"): "weights dominate: raise batch or quantize; "
                          "BoundedME cuts unembed reads",
    ("collective", "train"): "overlap grad all-reduce with bwd; "
                             "compress cross-pod grads to bf16",
    ("collective", "prefill"): "shift TP collectives to reduce-scatter + "
                               "all-gather pairs; overlap with compute",
    ("collective", "decode"): "replicate small weights to drop all-gathers"
                              "; merge per-layer collectives",
}


def table(mesh: str = "single") -> str:
    rows = []
    header = ("| arch | shape | compute s | memory s | collective s | "
              "dominant | MODEL_FLOPS | useful ratio | note |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for cfg, shp, skip in cells():
        if skip:
            rows.append(f"| {cfg.name} | {shp.name} | — | — | — | — | — | — "
                        f"| SKIP: {skip} |")
            continue
        rec = load_cell(cfg.name, shp.name, mesh)
        if rec is None:
            rows.append(f"| {cfg.name} | {shp.name} | — | — | — | — | — | — "
                        f"| missing |")
            continue
        a = analyse(rec, cfg, shp)
        lever = LEVERS[(a["dominant"], shp.kind)]
        note = ("" if a["exact_flops"] else "scanned-FLOPs; ") + lever
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3e} "
            f"| {a['t_memory_s']:.3e} | {a['t_collective_s']:.3e} "
            f"| **{a['dominant']}** | {a['model_flops']:.3e} "
            f"| {a['useful_ratio']:.2f} | {note} |")
    return "\n".join(rows)


def main():
    md = table()
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "roofline.md")
    with open(out, "w") as f:
        f.write("# Roofline (single-pod 16x16, v5e constants)\n\n")
        f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
