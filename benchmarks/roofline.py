"""Pull-loop roofline: bytes moved per pull, row vs coord mode (ISSUE 7).

The BoundedME cascade is a pure streaming workload: every pull DMAs one
``tile x block`` slab of the table from HBM into VMEM and spends
``2 * tile * block`` MACs on it, so its arithmetic intensity is pinned
near ``2 / dtype_bytes`` flops per byte — three orders of magnitude under
the v5e machine balance (``PEAK_FLOPS / HBM_BW`` ~ 241 flops/byte).  The
cascade is therefore *always* memory-bound and the only lever is the
numerator: total bytes moved.  That is exactly what the coordinate pull
mode (DESIGN.md §14) attacks — a coord pull moves ``tile * coord_block``
table bytes instead of ``tile * 512``, so the per-pull DMA shrinks 4x at
the default widths while the schedule grows only like the
without-replacement radius over ``d_blocks = ceil(d / coord_block)``.

Per (pull_mode x precision) cell at the PR-7 bench geometry we report the
analytic per-pull traffic (table slab + query block + int8 scales), the
schedule's certified pull count, total bytes / flops / arithmetic
intensity, the HBM-bound step-time floor at v5e bandwidth, and a
*measured* wall-clock of the jnp cascade on this host, converted to
achieved bytes/s.  The CPU number tracks the trend only — the ordering
(coord moves fewer bytes than row at large d) is the claim, the v5e
floor times are the model.

Importable API: ``analyse(plan) -> dict``, ``run(csv=True) -> dict``
(the BENCH_PR7 ``roofline`` payload), ``main()`` (writes
``results/roofline.md``).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core.boundedme_jax import BlockedPlan, bounded_me_decode, make_plan

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
MACHINE_BALANCE = PEAK_FLOPS / HBM_BW   # ~241 flops per HBM byte

# PR-7 bench geometry (matches benchmarks/bench_coord.py at its largest d)
_N, _D, _K, _B = 1024, 8192, 2, 4
_EPS, _DELTA, _VR = 3.0, 0.1, 2.0
_COORD_BLOCK = 128


def pull_bytes(plan: BlockedPlan) -> int:
    """HBM bytes one pull moves: table slab + query block (+ int8 scales).

    The table slab is ``tile * block`` at the sampling precision's element
    width; the query block is always fp32 (it is the unquantized operand of
    the asymmetric int8 scheme, DESIGN.md §10) and is re-read per pull in
    the streaming model; int8 adds ``tile`` per-row dequant scales plus the
    query block's one scale, 4 bytes each.
    """
    elem = 1 if plan.precision == "int8" else 4
    table = plan.tile * plan.block * elem
    query = plan.block * 4
    scales = (plan.tile + 1) * 4 if plan.precision == "int8" else 0
    return table + query + scales


def pull_flops(plan: BlockedPlan) -> int:
    """MACs one pull performs: the ``tile x block`` tile-dot, counted 2x."""
    return 2 * plan.tile * plan.block


def analyse(plan: BlockedPlan) -> dict:
    """Roofline terms for one plan's full certified schedule.

    Returns per-pull and total bytes/flops, arithmetic intensity vs the
    v5e machine balance, the memory-bound step-time floor at ``HBM_BW``,
    and the (always 'memory') binding term — the cascade's intensity sits
    ~100x below balance at every supported geometry.
    """
    bpp, fpp = pull_bytes(plan), pull_flops(plan)
    pulls = int(plan.schedule.total_pulls)
    total_bytes, total_flops = pulls * bpp, pulls * fpp
    t_mem = total_bytes / HBM_BW
    t_comp = total_flops / PEAK_FLOPS
    return {
        "pull_mode": plan.pull_mode, "precision": plan.precision,
        "tile": plan.tile, "block": plan.block,
        "n_blocks": plan.n_blocks, "total_pulls": pulls,
        "bytes_per_pull": bpp, "flops_per_pull": fpp,
        "total_bytes": total_bytes, "total_flops": total_flops,
        "intensity_flops_per_byte": fpp / bpp,
        "machine_balance": MACHINE_BALANCE,
        "bound": "memory" if fpp / bpp < MACHINE_BALANCE else "compute",
        "t_mem_floor_s": t_mem, "t_compute_s": t_comp,
    }


def _measure_ms(plan: BlockedPlan, reps: int = 3) -> float:
    rng = np.random.default_rng(0)
    V = rng.normal(size=(_N, _D)).astype(np.float32)
    Q = rng.normal(size=(_B, _D)).astype(np.float32)
    key = jax.random.PRNGKey(0)

    def f():
        return bounded_me_decode(V, Q, key, plan=plan, final_exact=False,
                                 use_pallas=False)

    jax.block_until_ready(f())          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def run(csv: bool = True) -> dict:
    """Analytic + measured roofline over pull_mode x precision."""
    out = {"geometry": {"n": _N, "d": _D, "K": _K, "batch": _B,
                        "eps": _EPS, "delta": _DELTA,
                        "value_range": _VR, "coord_block": _COORD_BLOCK,
                        "range_mode": "exact"},
           "machine": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                       "machine_balance": MACHINE_BALANCE},
           "cells": []}
    kw = dict(K=_K, eps=_EPS, delta=_DELTA, value_range=_VR,
              range_mode="exact", coord_block=_COORD_BLOCK)
    hyb = make_plan(_N, _D, pull_mode="hybrid", **kw)
    out["hybrid_resolves_to"] = hyb.pull_mode
    for pull_mode in ("row", "coord"):
        for precision in ("fp32", "int8"):
            plan = make_plan(_N, _D, pull_mode=pull_mode,
                             precision=precision, **kw)
            cell = analyse(plan)
            ms = _measure_ms(plan)
            cell["measured_ms_host"] = ms
            # B queries share each pull's table slab in the batched path
            cell["achieved_bytes_per_s_host"] = \
                cell["total_bytes"] / (ms * 1e-3)
            out["cells"].append(cell)
            if csv:
                print(f"roofline,{pull_mode},{precision},"
                      f"bytes_per_pull={cell['bytes_per_pull']}"
                      f";pulls={cell['total_pulls']}"
                      f";total_MB={cell['total_bytes'] / 1e6:.2f}"
                      f";intensity={cell['intensity_flops_per_byte']:.3f}"
                      f";v5e_floor_us={cell['t_mem_floor_s'] * 1e6:.1f}"
                      f";host_ms={ms:.1f}")
    row_b = next(c for c in out["cells"]
                 if c["pull_mode"] == "row" and c["precision"] == "fp32")
    coord_b = next(c for c in out["cells"]
                   if c["pull_mode"] == "coord" and c["precision"] == "fp32")
    out["coord_bytes_ratio"] = coord_b["total_bytes"] / row_b["total_bytes"]
    if csv:
        print(f"roofline,summary,fp32,"
              f"coord_total_bytes/row_total_bytes="
              f"{out['coord_bytes_ratio']:.3f}"
              f";hybrid={out['hybrid_resolves_to']}")
    return out


def table(payload: dict | None = None) -> str:
    """Markdown roofline table (for ``results/roofline.md``)."""
    payload = payload or run(csv=False)
    rows = ["| mode | prec | block | B/pull | pulls | total MB | "
            "flops/B | bound | v5e floor us | host ms |",
            "|" + "---|" * 10]
    for c in payload["cells"]:
        rows.append(
            f"| {c['pull_mode']} | {c['precision']} | {c['block']} "
            f"| {c['bytes_per_pull']} | {c['total_pulls']} "
            f"| {c['total_bytes'] / 1e6:.2f} "
            f"| {c['intensity_flops_per_byte']:.3f} | {c['bound']} "
            f"| {c['t_mem_floor_s'] * 1e6:.1f} "
            f"| {c['measured_ms_host']:.1f} |")
    g = payload["geometry"]
    rows.append("")
    rows.append(f"fp32 coord/row total-bytes ratio: "
                f"{payload['coord_bytes_ratio']:.3f} at n={g['n']} "
                f"d={g['d']} (hybrid -> {payload['hybrid_resolves_to']}); "
                f"machine balance {payload['machine']['machine_balance']:.0f}"
                f" flops/byte, every cell memory-bound.")
    return "\n".join(rows)


def main():
    payload = run(csv=True)
    md = table(payload)
    res_dir = os.path.join(os.path.dirname(__file__), "..", "results")
    os.makedirs(res_dir, exist_ok=True)
    out = os.path.join(res_dir, "roofline.md")
    with open(out, "w") as f:
        f.write("# Pull-loop roofline (v5e constants, row vs coord)\n\n")
        f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
