"""PR-9 observability overhead benchmark: off vs metrics vs metrics+trace.

Emits the rows for ``BENCH_PR9.json`` (via `benchmarks.run`): the
BENCH_PR6 bursty sustained workload served three times —

  * ``off``           — ``metrics=null_registry()``, no tracer, no
    flight recorder: every instrumentation call hits a shared no-op
    stub (the hard-off baseline);
  * ``metrics``       — the real `MetricsRegistry` (the default);
  * ``metrics_trace`` — registry + `SpanTracer` + an armed (path-less)
    `FlightRecorder`: the full observability surface.

Each mode runs ``_REPEATS`` times on identical seeds; the medians of
sustained throughput and answered p99 are compared against ``off`` as
``overhead_pct`` — the ISSUE-9 acceptance gate is <= 3% on both.  A
``micro`` table prices the raw instrumentation ops (labeled counter
inc, histogram observe, null-stub inc) in ns/op for context: per
dispatch the runtime makes tens of such calls against a multi-ms jitted
kernel launch, so the end-to-end overhead should be noise.

Geometry is CPU-feasible on purpose (see bench_runtime); the *ratio*
between modes is the tracked quantity, not absolute rps.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_runtime import (_DEADLINE_MS, _DIM, _EPS,
                                      _EPS_FLOOR, _K, _LANES, _N_ARMS,
                                      _QUEUE, _REQUESTS, _make_runtime)
from repro.launch.serve import simulate_stream
from repro.obs import (FlightRecorder, MetricsRegistry, SpanTracer,
                       null_registry)

_REPEATS = 3


def _serve_once(table, queries, mode: str) -> dict:
    tracer = flight = None
    metrics = None                      # ServeRuntime builds its own
    if mode == "off":
        metrics = null_registry()
    elif mode == "metrics_trace":
        tracer = SpanTracer(max_requests=512, seed=0)
        flight = FlightRecorder(capacity=256)      # armed, path-less
    elif mode != "metrics":
        raise ValueError(mode)
    rt = _make_runtime(table, eps_floor=_EPS_FLOOR, metrics=metrics,
                       tracer=tracer, flight=flight)
    stats = simulate_stream(rt, queries, pattern="bursty", seed=1,
                            open_loop=True, interarrival_ms=4.0)
    return {"rps": float(stats["throughput_rps"]),
            "p99_ms": float(stats["latency_ms"]["p99"])}


def _micro() -> dict:
    """ns/op of the raw instrumentation calls (hot-path price list)."""
    reg = MetricsRegistry()
    c = reg.counter("bench_total", labels=("outcome",))
    h = reg.histogram("bench_ms")
    nc = null_registry().counter("bench_total", labels=("outcome",))
    n = 100_000
    out = {}
    for name, fn in (("counter_inc_labeled", lambda: c.inc(outcome="ok")),
                     ("histogram_observe", lambda: h.observe(3.7)),
                     ("null_inc", lambda: nc.inc(outcome="ok"))):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        out[name + "_ns"] = (time.perf_counter() - t0) / n * 1e9
    return out


def run(csv: bool = True) -> dict:
    """Run the three modes; returns the BENCH_PR9 payload dict."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(_N_ARMS, _DIM)).astype(np.float32)
    queries = rng.normal(size=(_REQUESTS, _DIM)).astype(np.float32)

    out = {"geometry": {"n": _N_ARMS, "N": _DIM, "K": _K,
                        "requests": _REQUESTS, "lanes": _LANES,
                        "queue_capacity": _QUEUE, "eps": _EPS,
                        "eps_floor": _EPS_FLOOR,
                        "deadline_ms": _DEADLINE_MS,
                        "repeats": _REPEATS},
           "modes": [], "micro": _micro()}

    base_rps = base_p99 = None
    for mode in ("off", "metrics", "metrics_trace"):
        runs = [_serve_once(table, queries, mode)
                for _ in range(_REPEATS)]
        rps = float(np.median([r["rps"] for r in runs]))
        p99 = float(np.median([r["p99_ms"] for r in runs]))
        row = {"mode": mode, "sustained_rps": rps, "p99_ms": p99,
               "runs": runs}
        if mode == "off":
            base_rps, base_p99 = rps, p99
        else:
            row["throughput_overhead_pct"] = \
                (base_rps - rps) / base_rps * 100.0
            row["p99_overhead_pct"] = (p99 - base_p99) / base_p99 * 100.0
        out["modes"].append(row)
        if csv:
            extra = ("" if mode == "off" else
                     f",tput_ovh={row['throughput_overhead_pct']:+.2f}%,"
                     f"p99_ovh={row['p99_overhead_pct']:+.2f}%")
            print(f"obs_{mode},{rps:.0f}rps,p99={p99:.2f}ms{extra}")
    if csv:
        m = out["micro"]
        print("micro," + ",".join(f"{k}={v:.0f}" for k, v in m.items()))
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
