"""PR-7 perf benchmark: coordinate-sampling pull mode vs row pulls.

Emits the rows for ``BENCH_PR7.json`` (via `benchmarks.run`): a sweep
over d in {128, 512, 2048, 8192} at fixed (n, K, eps, delta) comparing
the three pull modes (DESIGN.md §14) on

  * **certified multiplies** — ``plan.total_multiplies``, the honest
    width-weighted cost model (`Schedule.total_coords` per arm tile):
    a row pull prices ``tile * 512`` MACs, a coord pull only
    ``tile * coord_block``;
  * **measured wall time** of the jnp decode path on this host, and
  * **measured contract compliance** — eps-suboptimality violations
    against the exact answer (must be zero; at eps=3.0 >> the ~1/sqrt(d)
    score gaps of gaussian data, *any* arm is eps-optimal, so raw recall
    is reported for context but is not the acceptance metric).

The acceptance claims: coord's pull cost grows *sublinearly* in d where
row's grows linearly (its without-replacement population d_blocks keeps
growing, so the fixed-m radius keeps shrinking, while row's single-shot
population is pinned at d/512); and the hybrid dispatcher is never more
than 10% worse than the better single mode (true by construction —
`choose_pull_mode` prices both plans — but measured here anyway).
``range_mode='exact'`` keeps sizing honest per d; eps is deliberately
loose (3.0) so the schedule genuinely samples at every d.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.boundedme_jax import bounded_me_decode, make_plan

_N_ARMS, _K, _B = 1024, 2, 4
_EPS, _DELTA, _VR = 3.0, 0.1, 2.0
_DIMS = (128, 512, 2048, 8192)
_COORD_BLOCK = 128
_MODES = ("row", "coord", "hybrid")


def _time_ms(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def run(csv: bool = True) -> dict:
    """Run the pull-mode sweep; returns the BENCH_PR7 payload."""
    key = jax.random.PRNGKey(0)
    out = {"geometry": {"n": _N_ARMS, "K": _K, "batch": _B, "eps": _EPS,
                        "delta": _DELTA, "value_range": _VR,
                        "coord_block": _COORD_BLOCK,
                        "range_mode": "exact"},
           "dims": []}
    for d in _DIMS:
        rng = np.random.default_rng(d)
        V = rng.normal(size=(_N_ARMS, d)).astype(np.float32)
        Q = rng.normal(size=(_B, d)).astype(np.float32)
        S = (V.astype(np.float64) @ Q.astype(np.float64).T).T / d  # (B, n)
        truth = np.argsort(-S, axis=1)[:, :_K]
        true_top = np.sort(S, axis=1)[:, ::-1][:, :_K]
        row = {"d": d, "modes": {}}
        for mode in _MODES:
            plan = make_plan(_N_ARMS, d, K=_K, eps=_EPS, delta=_DELTA,
                             value_range=_VR, range_mode="exact",
                             pull_mode=mode, coord_block=_COORD_BLOCK)
            ms = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False))
            ids, _ = bounded_me_decode(V, Q, key, plan=plan,
                                       final_exact=True, use_pallas=False)
            ids = np.asarray(ids)[:, :_K]
            recall = sum(len(set(ids[b]) & set(truth[b]))
                         for b in range(_B)) / truth.size
            got = np.sort(np.take_along_axis(S, ids, axis=1),
                          axis=1)[:, ::-1]
            subopt = np.maximum(true_top - got, 0.0)
            violations = int((subopt.max(axis=1)
                              > plan.eps_effective + 1e-7).sum())
            row["modes"][mode] = {
                "resolved": plan.pull_mode, "block": plan.block,
                "total_pulls": int(plan.schedule.total_pulls),
                "total_multiplies": int(plan.total_multiplies),
                "ms": ms, "recall": recall,
                "max_suboptimality": float(subopt.max()),
                "eps_violations": violations,
            }
            if csv:
                print(f"coord_sweep,d={d},{mode},"
                      f"resolved={plan.pull_mode}"
                      f";multiplies={int(plan.total_multiplies)}"
                      f";ms={ms:.1f};recall={recall:.3f}"
                      f";max_subopt={subopt.max():.4f}"
                      f";eps_violations={violations}")
        m = row["modes"]
        best = min(m["row"]["total_multiplies"],
                   m["coord"]["total_multiplies"])
        row["hybrid_overhead"] = m["hybrid"]["total_multiplies"] / best - 1.0
        out["dims"].append(row)

    # the sublinearity claim, explicit: coord cost growth factor across the
    # d sweep vs row's (row is ~linear in d once its schedule saturates)
    def growth(mode):
        ms_ = [r["modes"][mode]["total_multiplies"] for r in out["dims"]]
        return ms_[-1] / ms_[0]

    out["growth_factor_row"] = growth("row")
    out["growth_factor_coord"] = growth("coord")
    out["coord_sublinear_vs_row"] = \
        out["growth_factor_coord"] < out["growth_factor_row"]
    if csv:
        print(f"coord_sweep,summary,,"
              f"growth_row={out['growth_factor_row']:.2f}x"
              f";growth_coord={out['growth_factor_coord']:.2f}x"
              f";coord_sublinear={out['coord_sublinear_vs_row']}")
    return out
