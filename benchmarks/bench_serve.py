"""PR-2 serve-loop benchmark: micro-batch throughput vs batch deadline.

Emits the rows for ``BENCH_PR2.json`` (via `benchmarks.run`): for each
batch size B in {1, 8, 32} and each batch deadline, the request-loop
throughput, achieved batch occupancy, and latency percentiles, driven by
`simulate_stream`'s virtual clock (arrival spacing + *measured* compute
per flush — no sleeps, so the numbers are stable on shared CI hardware).
A second table measures the quantized-query LRU under a repeat-heavy
stream.

Geometry is CPU-feasible on purpose; the trends (occupancy rises with the
deadline, per-request cost falls with B) are what's tracked across PRs,
not the absolute numbers of this container.
"""

from __future__ import annotations

import numpy as np

from repro.launch.serve import MIPSServeEngine, simulate_stream

# serve-bench geometry: big enough that a flush is real MXU work, small
# enough that 9 sweep cells finish in CI minutes on CPU
_N_ARMS, _DIM, _K = 8192, 1024, 4
_REQUESTS = 192
_INTERARRIVAL_MS = 0.3
_BATCHES = (1, 8, 32)
_DEADLINES_MS = (0.5, 2.0, 8.0)


def _make_engine(batch_size: int, deadline_ms: float, table,
                 cache_entries: int = 0) -> MIPSServeEngine:
    return MIPSServeEngine(
        table, K=_K, eps=0.2, delta=0.1, value_range=8.0, block=256,
        batch_size=batch_size, deadline_ms=deadline_ms,
        cache_entries=cache_entries, recall_sample_rate=0.05)


def run(csv: bool = True) -> dict:
    """Run the sweep; returns the BENCH_PR2 payload dict."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(_N_ARMS, _DIM)).astype(np.float32)
    queries = rng.normal(size=(_REQUESTS, _DIM)).astype(np.float32)

    out = {"geometry": {"n": _N_ARMS, "N": _DIM, "K": _K,
                        "requests": _REQUESTS,
                        "interarrival_ms": _INTERARRIVAL_MS},
           "throughput_vs_deadline": []}
    for B in _BATCHES:
        for dl in _DEADLINES_MS:
            eng = _make_engine(B, dl, table)
            # warm the jit cache so compile time doesn't pollute the clock
            eng.submit(queries[0], now=-1e3)
            eng.drain(now=-1e3)
            stats = simulate_stream(eng, queries,
                                    interarrival_ms=_INTERARRIVAL_MS)
            row = {
                "batch_size": B,
                "deadline_ms": dl,
                "throughput_rps": stats["throughput_rps"],
                "mean_batch_occupancy": stats["mean_batch_occupancy"],
                "full_flushes": stats["full_flushes"],
                "deadline_flushes": stats["deadline_flushes"],
                "latency_ms_p50": stats["latency_ms"]["p50"],
                "latency_ms_p95": stats["latency_ms"]["p95"],
                "recall_mean": stats["recall"]["mean"],
            }
            out["throughput_vs_deadline"].append(row)
            if csv:
                print(f"serve_loop,B={B};deadline={dl}ms,"
                      f"rps={row['throughput_rps']:.0f}"
                      f";occ={row['mean_batch_occupancy']:.1f}"
                      f";p95={row['latency_ms_p95']:.2f}ms")

    # LRU under a repeat-heavy stream (half the queries repeat an earlier
    # one): hits bypass the flush entirely
    eng = _make_engine(8, 2.0, table, cache_entries=256)
    eng.submit(queries[0], now=-1e3)
    eng.drain(now=-1e3)
    reps = queries.copy()
    reps[_REQUESTS // 2:] = queries[:_REQUESTS - _REQUESTS // 2]
    stats = simulate_stream(eng, reps, interarrival_ms=_INTERARRIVAL_MS)
    out["lru_repeat_stream"] = {
        "repeat_rate": 0.5,
        "cache_hit_rate": stats["cache"]["hit_rate"],
        "throughput_rps": stats["throughput_rps"],
        "latency_ms_p50": stats["latency_ms"]["p50"],
    }
    if csv:
        print(f"serve_loop_lru,repeat=0.5,"
              f"hit_rate={stats['cache']['hit_rate']:.2f}"
              f";rps={stats['throughput_rps']:.0f}")
    return out
