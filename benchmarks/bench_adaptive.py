"""PR-5 perf benchmark: adaptive early-exit cascade vs the static schedule.

Emits the rows for ``BENCH_PR5.json`` (via `benchmarks.run`): for decode
batch sizes B in {1, 8, 32}, the *sample-complexity* effect of adaptive
certification (DESIGN.md §12) on two synthetic workloads:

  * **easy** — every query has a planted self-similar row (top-1 margin
    ~ 1 vs ~ 1/sqrt(N) noise): certification fires rounds early and the
    executed pull count collapses;
  * **hard** — pure gaussian noise (top-K gaps far below every round's
    radius): certification never fires, the full schedule runs, and the
    only cost of ``adaptive=True`` is the round-boundary bound check.

Per configuration we report mean executed pulls per query (converted
from the per-query ``rounds_used`` through
`repro.core.schedule.pulls_through_round`), the ``rounds_used``
histogram, measured wall time, and measured top-K recall against the
exact answer — the acceptance criterion being >= 30% mean-pull reduction
on the easy workload at unchanged recall.  The geometry is chosen in the
non-saturated regime (the last round still samples a strict subset of
the blocks) so the bandit genuinely estimates; a fully-covered schedule
would leave adaptivity nothing to skip.  Wall-clock on this CPU
container tracks the trend only — the pull savings translate to skipped
HBM tile-DMAs on TPU, where the fused kernel masks a certified query's
remaining steps to no-ops.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.core.schedule import pulls_through_round

_N_ARMS, _DIM, _K = 1024, 16384, 4
_BATCHES = (1, 8, 32)
_EPS, _DELTA, _VR, _BLOCK = 1.6, 0.05, 8.0, 32


def _time_ms(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def _recall(V, Q, ids):
    exact = np.asarray(V) @ np.asarray(Q).T                    # (n, B)
    truth = np.argsort(-exact, axis=0)[:_K].T                  # (B, K)
    ids = np.asarray(ids)[:, :_K]
    hits = sum(len(set(ids[b]) & set(truth[b])) for b in range(len(truth)))
    return hits / truth.size


def _workload(kind: str, B: int, rng):
    V = rng.normal(size=(_N_ARMS, _DIM)).astype(np.float32)
    Q = rng.normal(size=(B, _DIM)).astype(np.float32)
    if kind == "easy":
        # each query's top-K are its own planted aligned rows, spread over
        # tiles; margins ~ (1.0 .. 0.7) vs ~ 1/sqrt(N) noise
        for b in range(B):
            for j in range(_K):
                V[(b * _K + j) * 17 % _N_ARMS] = (1.0 - 0.1 * j) * Q[b]
    return jnp.asarray(V), jnp.asarray(Q)


def run(csv: bool = True) -> dict:
    """Run the adaptive-vs-static sweep; returns the BENCH_PR5 payload."""
    key = jax.random.PRNGKey(0)
    plans = {bound: make_plan(_N_ARMS, _DIM, K=_K, eps=_EPS, delta=_DELTA,
                              value_range=_VR, tile=8, block=_BLOCK,
                              bound=bound)
             for bound in ("hoeffding", "bernstein")}
    plan = plans["hoeffding"]
    pulls = pulls_through_round(plan.schedule)
    assert plan.schedule.rounds[-1].t_cum < plan.n_blocks, \
        "saturated schedule: adaptivity has nothing to skip"
    out = {
        "geometry": {"n": _N_ARMS, "N": _DIM, "K": _K, "eps": _EPS,
                     "delta": _DELTA, "block": _BLOCK},
        "plan": {bound: {"rounds": len(p.schedule.rounds),
                         "total_pulls": int(p.schedule.total_pulls),
                         "pulls_through_round":
                             pulls_through_round(p.schedule).tolist()}
                 for bound, p in plans.items()},
        "workloads": [],
    }
    for kind in ("easy", "hard"):
        for B in _BATCHES:
            rng = np.random.default_rng(B * 7 + (kind == "easy"))
            V, Q = _workload(kind, B, rng)
            row = {"workload": kind, "batch_size": B}
            ms_off = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False))
            ids_off, _ = bounded_me_decode(V, Q, key, plan=plan,
                                           final_exact=True,
                                           use_pallas=False)
            ms_on = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False,
                adaptive=True))
            ids_on, _, rounds = bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False,
                adaptive=True)
            rounds = np.asarray(rounds)
            hist = {str(r): int((rounds == r).sum())
                    for r in sorted(set(rounds.tolist()))}
            mean_pulls = float(np.mean(pulls[rounds]))
            row.update({
                "static": {"ms": ms_off, "mean_pulls": int(pulls[-1]),
                           "recall": _recall(V, Q, ids_off)},
                "adaptive": {"ms": ms_on, "mean_pulls": mean_pulls,
                             "recall": _recall(V, Q, ids_on),
                             "rounds_hist": hist,
                             "mean_rounds": float(rounds.mean())},
                "pull_reduction": 1.0 - mean_pulls / float(pulls[-1]),
            })
            out["workloads"].append(row)
            if csv:
                print(f"adaptive_decode,{kind},B={B},"
                      f"pulls_static={int(pulls[-1])}"
                      f";pulls_adaptive={mean_pulls:.0f}"
                      f";reduction={row['pull_reduction']:.1%}"
                      f";recall_static={row['static']['recall']:.3f}"
                      f";recall_adaptive={row['adaptive']['recall']:.3f}"
                      f";rounds_hist={hist}")

    # the variance-aware family on the easy workload: its certification
    # radii collapse with the (tiny) empirical variance, buying earlier
    # exits at the cost of a slightly larger sizing (delta split)
    B = 8
    rng = np.random.default_rng(3)
    V, Q = _workload("easy", B, rng)
    eb = plans["bernstein"]
    eb_pulls = pulls_through_round(eb.schedule)
    _, _, rounds = bounded_me_decode(V, Q, key, plan=eb, final_exact=True,
                                     use_pallas=False, adaptive=True)
    rounds = np.asarray(rounds)
    out["bernstein_easy_B8"] = {
        "total_pulls": int(eb_pulls[-1]),
        "mean_pulls": float(np.mean(eb_pulls[rounds])),
        "mean_rounds": float(rounds.mean()),
        "rounds_hist": {str(r): int((rounds == r).sum())
                        for r in sorted(set(rounds.tolist()))},
    }
    if csv:
        b8 = out["bernstein_easy_B8"]
        print(f"adaptive_bernstein,easy,B=8,"
              f"pulls={b8['mean_pulls']:.0f}/{b8['total_pulls']}"
              f";mean_rounds={b8['mean_rounds']:.2f}")
    return out
