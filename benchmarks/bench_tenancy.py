"""PR-10 multi-tenant serving benchmark: fairness, paging, isolation.

Emits the rows for ``BENCH_PR10.json`` (via `benchmarks.run`), the three
acceptance quantities of the tenancy layer:

* ``fairness`` — three tenants behind one `MultiTenantRuntime`, the hot
  one submitting 8x the cold rate into a bounded private queue.  Per
  tenant: answered fraction, shed count, answered p99.  The gate shape:
  cold tenants answer everything while the hot tenant is throttled (its
  queue bound sheds the excess) but never starved.
* ``paging`` — a byte budget that holds only two of three tables, served
  round-robin so every acquire evicts the LRU table and pages the
  victim's successor back in.  Reports eviction/page-in counts, page-in
  milliseconds (store rebuild from the page image) and the off-clock
  executor warm cost (jit retrace) — the price of oversubscribing device
  memory.
* ``isolation`` — a cold tenant's answered p99 served next to the hot
  tenant, divided by the same tenant/stream served on a *dedicated*
  single-tenant `ServeRuntime` (the isolated baseline).  The acceptance
  gate tracks ``p99_ratio <= 2.0``.

Geometry is CPU-feasible on purpose (same philosophy as bench_runtime);
ratios between runs are the tracked quantities, not absolute rps.
"""

from __future__ import annotations

import numpy as np

from repro.launch.admission import PriorityClass
from repro.launch.engine import ServeRuntime
from repro.launch.tenancy import (MultiTenantRuntime, TableRegistry,
                                  TenantConfig)
from repro.store import DynamicTableStore

_DIM = 192
_ROWS = 384
_LANES = 8
_K = 4
_EPS = 1.6
_DELTA = 0.2
_DEADLINE_MS = 50.0
_QUEUE = 32
_ITERS = 40
_HOT_RATE = 12           # per-iteration burst, > hot queue capacity
_HOT_QUEUE = 8           # hot queue bound: the throttle
_STEP_S = 0.004          # virtual inter-arrival per iteration


def _table(seed):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(_ROWS, _DIM)) / np.sqrt(_DIM)
            ).astype(np.float32)


def _cfg(seed, **over):
    kw = dict(K=_K, eps=_EPS, delta=_DELTA, deadline_ms=_DEADLINE_MS,
              queue_capacity=_QUEUE, seed=seed)
    kw.update(over)
    return TenantConfig(**kw)


def _skewed_run():
    """Hot tenant at 8x + two cold tenants through one runtime."""
    reg = TableRegistry(lanes=_LANES)
    reg.register("hot", _table(0), _cfg(0, queue_capacity=_HOT_QUEUE))
    for name, seed in (("c1", 1), ("c2", 2)):
        reg.register(name, _table(seed), _cfg(seed))
    mt = MultiTenantRuntime(reg, batch_wait_ms=2.0)
    mt.warmup()
    rng = np.random.default_rng(7)
    t = 0.0
    for _ in range(_ITERS):
        for _ in range(_HOT_RATE):
            mt.submit(rng.normal(size=_DIM).astype(np.float32),
                      tenant="hot", now=t)
        for name in ("c1", "c2"):
            mt.submit(rng.normal(size=_DIM).astype(np.float32),
                      tenant=name, now=t)
        _, busy = mt.poll(now=t + 0.002)
        t += _STEP_S + busy
    mt.drain(now=t + 1.0)
    return mt.stats()


def _isolated_p99(seed):
    """The same cold stream on a dedicated single-tenant runtime."""
    cfg = _cfg(seed)
    rt = ServeRuntime(
        _table(seed), K=cfg.K, eps=cfg.eps, delta=cfg.delta,
        lanes=_LANES, batch_wait_ms=2.0, queue_capacity=cfg.queue_capacity,
        classes={"default": PriorityClass("default", priority=cfg.priority,
                                          deadline_ms=cfg.deadline_ms)},
        seed=cfg.seed)
    rt.warmup()
    rng = np.random.default_rng(7)
    t = 0.0
    for _ in range(_ITERS):
        # reproduce the arrival cadence, minus the co-tenants
        rng.normal(size=(_HOT_RATE, _DIM))          # burn the hot draws
        rt.submit(rng.normal(size=_DIM).astype(np.float32), now=t)
        rng.normal(size=_DIM)                       # burn the c2 draw
        _, busy = rt.poll(now=t + 0.002)
        t += _STEP_S + busy
    rt.drain(now=t + 1.0)
    return float(rt.stats()["latency_ms"]["p99"])


def _paging_run():
    """Budget for two of three tables: round-robin serve = LRU thrash."""
    unit = DynamicTableStore(_table(0)).resident_bytes()
    reg = TableRegistry(byte_budget=int(2.2 * unit), lanes=_LANES)
    for name, seed in (("a", 10), ("b", 11), ("c", 12)):
        reg.register(name, _table(seed), _cfg(seed))
    page_ms = []
    for i in range(9):
        name = ("a", "b", "c")[i % 3]
        _, page_s = reg.executors(name)
        page_ms.append(page_s * 1e3)
    snap = {m["name"]: m for m in reg.metrics.snapshot()["metrics"]}
    cells = snap["tenancy_warm_ms"]["values"]
    warm = {"sum": sum(c["sum"] for c in cells),
            "count": sum(c["count"] for c in cells)}
    stats = reg.stats()
    paged = [ms for ms in page_ms if ms > 0.0]
    return {
        "byte_budget": stats["byte_budget"],
        "table_bytes": int(unit),
        "acquires": len(page_ms),
        "evictions": stats["evictions"],
        "page_ins": stats["page_ins"],
        "page_in_ms_mean": float(np.mean(paged)) if paged else 0.0,
        "page_in_ms_max": float(np.max(paged)) if paged else 0.0,
        "warm_ms_mean": float(warm["sum"] / max(1, warm["count"])),
        "executor_cache_entries": stats["executor_cache_entries"],
    }


def run(csv: bool = True) -> dict:
    """Run all three sections; returns the BENCH_PR10 payload dict."""
    out = {"geometry": {"n": _ROWS, "N": _DIM, "K": _K, "eps": _EPS,
                        "delta": _DELTA, "lanes": _LANES,
                        "queue_capacity": _QUEUE,
                        "hot_queue_capacity": _HOT_QUEUE,
                        "deadline_ms": _DEADLINE_MS, "iters": _ITERS,
                        "hot_rate": _HOT_RATE}}

    s = _skewed_run()
    fairness = {}
    for name, ts in s["tenants"].items():
        o = ts["outcomes"]
        answered = o["ok"] + o["degraded"]
        fairness[name] = {
            "requests": ts["requests"],
            "answered": answered,
            "answered_frac": answered / max(1, ts["requests"]),
            "shed": o["overloaded"] + o["rejected"],
            "p99_ms": float(ts["latency_ms"]["p99"]),
        }
    out["fairness"] = fairness

    iso = {}
    for name in ("c1", "c2"):
        base = _isolated_p99({"c1": 1, "c2": 2}[name])
        multi = fairness[name]["p99_ms"]
        iso[name] = {"isolated_p99_ms": base, "multi_p99_ms": multi,
                     "p99_ratio": multi / max(1e-9, base)}
    out["isolation"] = iso

    out["paging"] = _paging_run()

    if csv:
        for name, f in fairness.items():
            print(f"tenancy_fair_{name},answered={f['answered']}/"
                  f"{f['requests']},shed={f['shed']},"
                  f"p99={f['p99_ms']:.2f}ms")
        for name, r in iso.items():
            print(f"tenancy_iso_{name},"
                  f"isolated_p99={r['isolated_p99_ms']:.2f}ms,"
                  f"multi_p99={r['multi_p99_ms']:.2f}ms,"
                  f"ratio={r['p99_ratio']:.2f}")
        p = out["paging"]
        print(f"tenancy_paging,evictions={p['evictions']},"
              f"page_ins={p['page_ins']},"
              f"page_in_mean={p['page_in_ms_mean']:.2f}ms,"
              f"warm_mean={p['warm_ms_mean']:.1f}ms")
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
