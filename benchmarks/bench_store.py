"""PR-4 benchmark: live-corpus serving + update cost vs index rebuilds.

Emits the rows for ``BENCH_PR4.json`` (via `benchmarks.run`), quantifying
the paper's no-preprocessing claim on the serving stack (DESIGN.md §11):

* **mixed read/write stream** — the store-backed `MIPSServeEngine` under
  churn rates {0, 10, 50}% of arrivals (each churn event stages an upsert
  or a delete+append): query throughput, latency percentiles and sampled
  exact recall, on `simulate_stream`'s virtual clock.  The zero-rebuild
  claim is checked structurally: the whole sweep must report 0 schedule
  recalibrations (updates stay in the calibrated value range) — i.e. not
  a single new executable was compiled to absorb the churn;
* **update cost vs full rebuild** — amortized per-row upsert cost of the
  store (fp32, and int8 including dirty-tile re-quantization) against
  what the index baselines must pay to absorb *any* row change: a full
  `build_lsh` / `build_pca_tree` rebuild (their Table-1 preprocessing).
  Reported both as measured wall time and as the structural
  preprocess-multiply counts the baselines expose.

Geometry matches bench_serve (8192 x 1024) so rows are comparable with
BENCH_PR2.json; absolute CPU-container numbers track trends only.
"""

from __future__ import annotations

import time

import numpy as np

from repro.baselines.lsh_mips import build_lsh
from repro.baselines.pca_mips import build_pca_tree
from repro.launch.serve import MIPSServeEngine, simulate_stream
from repro.store import DynamicTableStore

_N_ARMS, _DIM, _K = 8192, 1024, 4
_REQUESTS = 192
_INTERARRIVAL_MS = 0.3
_CHURN_RATES = (0.0, 0.1, 0.5)
_UPSERT_ROWS = 128          # rows timed for the update-cost comparison


def _mixed_stream_row(table, queries, churn_rate: float) -> dict:
    store = DynamicTableStore(table, block=256, capacity_slack=1.25)
    eng = MIPSServeEngine(store, K=_K, eps=0.2, delta=0.1, value_range=8.0,
                          batch_size=8, deadline_ms=2.0, cache_entries=0,
                          recall_sample_rate=0.05)
    crng = np.random.default_rng(7)

    def churn(_eng, _i):
        if crng.random() >= churn_rate:
            return
        row = crng.normal(size=_DIM).astype(np.float32)
        live = store.live_ids()
        if crng.random() < 0.7:
            store.upsert(int(crng.choice(live)), row)
        elif store.free_rows > 0:
            store.delete(int(crng.choice(live)))
            store.append(row)

    eng.submit(queries[0], now=-1e3)     # warm the jit cache
    eng.drain(now=-1e3)
    stats = simulate_stream(eng, queries,
                            interarrival_ms=_INTERARRIVAL_MS,
                            churn=churn if churn_rate > 0 else None)
    return {
        "churn_rate": churn_rate,
        "updates_applied": stats["updates"]["applied"],
        "recalibrations": stats["updates"]["recalibrations"],
        "throughput_rps": stats["throughput_rps"],
        "latency_ms_p50": stats["latency_ms"]["p50"],
        "latency_ms_p95": stats["latency_ms"]["p95"],
        "recall_mean": stats["recall"]["mean"],
        "update_rows_per_s": stats["updates"]["rows_per_s"],
    }


def _update_cost(table) -> dict:
    rng = np.random.default_rng(1)
    out = {}
    for precision in ("fp32", "int8"):
        store = DynamicTableStore(table, block=256, capacity_slack=1.25,
                                  precision=precision)
        # one warm flush so jit compiles don't pollute the timing
        store.upsert(0, table[0])
        store.flush_updates()
        t0 = time.perf_counter()
        for i in range(_UPSERT_ROWS):
            store.upsert(int(rng.integers(0, _N_ARMS)),
                         rng.normal(size=_DIM).astype(np.float32))
            store.flush_updates()        # worst case: one flush per row
        dt = time.perf_counter() - t0
        out[precision] = {
            "upsert_ms_per_row": dt / _UPSERT_ROWS * 1e3,
            "rows_per_s": _UPSERT_ROWS / dt,
            "tiles_requantized": store.tiles_requantized,
        }
    # index baselines: absorbing any update means a full rebuild
    t0 = time.perf_counter()
    lsh = build_lsh(table, a=8, b=16)
    lsh_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    pca = build_pca_tree(table, depth=6)
    pca_ms = (time.perf_counter() - t0) * 1e3
    store_row = _DIM                       # multiplies touched per upsert
    out["rebuild"] = {
        "lsh_ms": lsh_ms,
        "lsh_preprocess_multiplies": lsh.preprocess_multiplies,
        "pca_ms": pca_ms,
        "pca_preprocess_multiplies": pca.preprocess_multiplies,
        "store_touched_multiplies_per_upsert": store_row,
        "lsh_rebuilds_per_store_upsert":
            lsh_ms / out["fp32"]["upsert_ms_per_row"],
        "pca_rebuilds_per_store_upsert":
            pca_ms / out["fp32"]["upsert_ms_per_row"],
    }
    return out


def run(csv: bool = True) -> dict:
    """Run the live-corpus sweep; returns the BENCH_PR4 payload dict."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(_N_ARMS, _DIM)).astype(np.float32)
    queries = rng.normal(size=(_REQUESTS, _DIM)).astype(np.float32)

    out = {"geometry": {"n": _N_ARMS, "N": _DIM, "K": _K,
                        "requests": _REQUESTS,
                        "interarrival_ms": _INTERARRIVAL_MS,
                        "upsert_rows_timed": _UPSERT_ROWS},
           "mixed_stream": [], "update_cost": {}}
    for rate in _CHURN_RATES:
        row = _mixed_stream_row(table, queries, rate)
        out["mixed_stream"].append(row)
        if csv:
            print(f"store_stream,churn={rate},"
                  f"rps={row['throughput_rps']:.0f}"
                  f";p95={row['latency_ms_p95']:.2f}ms"
                  f";updates={row['updates_applied']}"
                  f";recalib={row['recalibrations']}"
                  f";recall={row['recall_mean']:.2f}")
    out["update_cost"] = _update_cost(table)
    if csv:
        uc = out["update_cost"]
        print(f"store_upsert,fp32,"
              f"{uc['fp32']['upsert_ms_per_row']*1e3:.0f}us_per_row,"
              f"int8={uc['int8']['upsert_ms_per_row']*1e3:.0f}us")
        print(f"store_vs_rebuild,,lsh={uc['rebuild']['lsh_ms']:.0f}ms"
              f";pca={uc['rebuild']['pca_ms']:.0f}ms"
              f";lsh_rebuilds_per_upsert="
              f"{uc['rebuild']['lsh_rebuilds_per_store_upsert']:.0f}"
              f";pca_rebuilds_per_upsert="
              f"{uc['rebuild']['pca_rebuilds_per_store_upsert']:.0f}")
    return out
