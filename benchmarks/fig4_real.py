"""Paper Fig. 4 proxy: matrix-factorization embeddings (recommender MIPS).

Netflix/Yahoo-Music data are not available offline; we use the mf_dataset
generator (low-rank + heavy-tailed spectrum + noise), which reproduces the
qualitative structure of ALS item embeddings — the regime where the paper
reports BoundedME's largest wins.  Top-5, per the paper's fig-4 setting.
"""

from __future__ import annotations

import time

import numpy as np

from repro.baselines import (build_greedy, build_lsh, build_pca_tree,
                             exact_mips, greedy_mips, lsh_mips, pca_mips)
from repro.core import bounded_me, reward_matrix
from repro.data.synthetic import mf_dataset

N, DIM, K, QUERIES = 2000, 20_000, 5, 3


def run(csv: bool = True):
    rng = np.random.default_rng(0)
    V, _ = mf_dataset(N, DIM, rank=32, seed=0)
    queries = [mf_dataset(1, DIM, rank=32, seed=50 + i)[1]
               for i in range(QUERIES)]
    naive = N * DIM
    rows = []

    def prec(r, t):
        return len(set(np.asarray(r).tolist()) & set(t.tolist())) / K

    for eps in (0.05, 0.15, 0.4, 0.8):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            vr = float(np.abs(V).max() * np.abs(q).max())
            R = reward_matrix(V, q, rng)
            res = bounded_me(R, K=K, eps=eps * vr, delta=0.1,
                             value_range=2 * vr)
            precs.append(prec(res.topk, truth))
            speeds.append(naive / max(1, res.total_pulls))
        rows.append((f"boundedme_eps{eps}", np.mean(speeds),
                     np.mean(precs), (time.time() - t0) / QUERIES * 1e6))

    gidx = build_greedy(V)
    for budget in (50, 400):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            r = greedy_mips(gidx, q, K, budget=budget)
            precs.append(prec(r.topk, truth))
            speeds.append(naive / max(1, r.query_multiplies))
        rows.append((f"greedy_B{budget}", np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    lidx = build_lsh(V, a=6, b=16, seed=1)
    tree = build_pca_tree(V, depth=8)
    for name, fn in (("lsh_a6_b16", lambda q: lsh_mips(lidx, q, K)),
                     ("pca_spill0.1",
                      lambda q: pca_mips(tree, q, K, spill=0.1))):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            r = fn(q)
            precs.append(prec(r.topk, truth))
            speeds.append(naive / max(1, r.query_multiplies))
        rows.append((name, np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    if csv:
        print("name,us_per_call,derived")
        for name, sp, pr, us in rows:
            print(f"fig4_mf_{name},{us:.0f},speedup={sp:.2f};"
                  f"precision={pr:.2f}")
    return rows


if __name__ == "__main__":
    run()
