"""PR-1 perf benchmarks: fused cascade + batched decode vs the seed paths.

Emits the machine-readable rows for ``BENCH_PR1.json`` (via
`benchmarks.run`): per-benchmark ``us_per_call``, schedule pull-count
speedup, and kernel dispatch counts, so the perf trajectory stays
comparable across PRs.  The seed per-query vmap path (one
(T, dt, R, C)-materializing gather einsum per round, vmapped over the
batch) is reconstructed here verbatim as the frozen baseline.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundedme_jax import (_pad_operands, _run_blocked,
                                      _tile_major, bounded_me_decode,
                                      make_plan)

# acceptance geometry: B=32, n=32768, N=4096 (ISSUE 1)
_B, _N_ARMS, _DIM = 32, 32768, 4096


@functools.partial(jax.jit, static_argnames=("plan",))
def _seed_run_blocked(V, q, key, *, plan):
    """The PR-0 single-query path, frozen: per-round 4-D gather einsum."""
    R, C = plan.tile, plan.block
    V, q = _pad_operands(V, q, plan)
    V4 = _tile_major(V, plan)
    qb = q.reshape(plan.n_blocks, C)
    perm = jax.random.permutation(key, plan.n_blocks)
    arm_ids0 = jnp.arange(plan.n_tiles * R).reshape(plan.n_tiles, R)
    valid0 = (arm_ids0 < plan.n).astype(V.dtype)
    idx = jnp.arange(plan.n_tiles)
    sums = jnp.zeros((plan.n_tiles, R), dtype=jnp.float32)
    t_prev = 0
    neg = jnp.asarray(-jnp.inf, dtype=jnp.float32)
    for rnd in plan.schedule.rounds:
        if rnd.t_new > 0:
            cols = jax.lax.slice_in_dim(perm, t_prev, rnd.t_cum)
            qsel = qb[cols]
            Vsel = V4[idx[:, None], cols[None, :]]        # (T, dt, R, C)
            sums = sums + jnp.einsum("tbrc,bc->tr", Vsel, qsel,
                                     preferred_element_type=jnp.float32)
        t_prev = rnd.t_cum
        means = sums / jnp.float32(t_prev * C)
        tile_score = jnp.where(valid0[idx] > 0, means, neg).max(axis=1)
        _, keep = jax.lax.top_k(tile_score, rnd.n_keep)
        idx, sums = idx[keep], sums[keep]
    scores = sums / jnp.float32(max(1, t_prev) * C)
    flat = jnp.where(valid0[idx] > 0, scores, neg).reshape(-1)
    top_vals, top_pos = jax.lax.top_k(flat, plan.K)
    return arm_ids0[idx].reshape(-1)[top_pos], top_vals


def _seed_vmap_batched(V, Q, keys, *, plan):
    """The PR-0 batched decode path: vmap of the per-query cascade."""
    fn = functools.partial(_seed_run_blocked, plan=plan)
    return jax.vmap(fn, in_axes=(None, 0, 0))(V, Q, keys)


def _time_us(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv: bool = True) -> dict:
    out = {}
    rng = np.random.default_rng(0)

    # --- batched decode: new shared-perm MXU fallback vs seed vmap path ---
    plan = make_plan(_N_ARMS, _DIM, K=1, eps=0.1, delta=0.05,
                     value_range=4.0, tile=8, block=512)
    V = jnp.asarray(rng.normal(size=(_N_ARMS, _DIM)), jnp.float32)
    Q = jnp.asarray(rng.normal(size=(_B, _DIM)), jnp.float32)
    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, _B)

    us_new = _time_us(
        lambda: bounded_me_decode(V, Q, key, plan=plan, final_exact=False,
                                  use_pallas=False))
    us_seed = _time_us(
        lambda: _seed_vmap_batched(V, Q, keys, plan=plan), reps=1)
    speedup = us_seed / us_new
    out["decode_batched_fallback"] = {
        "us_per_call": us_new,
        "geometry": {"B": _B, "n": _N_ARMS, "N": _DIM, "block": 512}}
    out["seed_vmap_path"] = {"us_per_call": us_seed,
                             "geometry": out["decode_batched_fallback"]
                             ["geometry"]}
    out["decode_batched_vs_seed_vmap"] = {"speedup": speedup,
                                          "acceptance_min": 2.0}

    # --- fused cascade kernel: dispatch count + interpret-mode latency ---
    plan_s = make_plan(2048, _DIM, K=4, eps=0.3, delta=0.1, value_range=8.0,
                       tile=8, block=256)
    Vs = jnp.asarray(rng.normal(size=(2048, _DIM)), jnp.float32)
    qs = jnp.asarray(rng.normal(size=_DIM), jnp.float32)

    def fused(V, q, k):
        return _run_blocked(V, q, k, plan=plan_s, use_pallas=True)

    from repro.kernels.ops import count_pallas_calls
    n_disp = count_pallas_calls(jax.make_jaxpr(fused)(Vs, qs, key).jaxpr)
    us_fused = _time_us(lambda: fused(Vs, qs, key), reps=1)
    out["fused_cascade_single_query"] = {
        "us_per_call": us_fused,  # interpret mode on CPU: NOT a TPU number
        "dispatch_count": n_disp,
        "rounds": len(plan_s.schedule.rounds),
        "dispatch_count_per_round_path": len(plan_s.schedule.rounds),
        "backend": jax.default_backend()}

    # --- schedule-level pull savings at a non-saturated geometry ---
    plan_w = make_plan(_N_ARMS, 131072, K=1, eps=0.1, delta=0.05,
                       value_range=4.0, tile=8, block=512)
    out["pull_speedup"] = {
        "saturated_serving_geometry": plan.schedule.speedup,
        "wide_geometry_n131072": plan_w.schedule.speedup,
        "wide_total_pulls": plan_w.schedule.total_pulls,
        "wide_naive_pulls": plan_w.schedule.naive_pulls}

    if csv:
        print(f"decode_batched_fallback,{us_new:.0f},"
              f"B={_B};n={_N_ARMS};N={_DIM}")
        print(f"seed_vmap_path,{us_seed:.0f},same_geometry")
        print(f"decode_batched_vs_seed_vmap,,speedup={speedup:.2f}x"
              f";acceptance>=2x")
        print(f"fused_cascade_single_query,{us_fused:.0f},"
              f"dispatches={n_disp};rounds={len(plan_s.schedule.rounds)}"
              f";interpret={jax.default_backend() != 'tpu'}")
        print(f"pull_speedup,,wide={plan_w.schedule.speedup:.2f}x")
    return out
