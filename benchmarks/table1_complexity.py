"""Paper Table 1: measured complexity vs closed forms.

Checks BoundedME's measured pull counts against O(n sqrt(N)/eps
sqrt(log 1/delta)) scaling, the per-arm <= N cap (Corollary 2), and the
zero-preprocessing claim (vs each baseline's measured preprocessing cost).
"""

from __future__ import annotations

import math
import time

import numpy as np

from repro.baselines import build_greedy, build_lsh, build_pca_tree
from repro.core import bounded_me, bounded_se, make_schedule
from repro.data.synthetic import adversarial_dataset, gaussian_dataset


def run(csv: bool = True):
    rows = []
    # scaling in N (fix n, eps): pulls should grow ~ sqrt(N)
    n, eps = 500, 0.3
    base = None
    for N in (5_000, 20_000, 80_000):
        s = make_schedule(n, N, K=1, eps=eps, delta=0.1)
        if base is None:
            base = (N, s.total_pulls)
        pred = base[1] * math.sqrt(N / base[0])
        rows.append((f"scaling_N{N}", 0.0,
                     f"pulls={s.total_pulls};sqrtN_pred={pred:.0f};"
                     f"ratio={s.total_pulls / pred:.2f}"))
    # scaling in 1/eps (fix n, N)
    N = 50_000
    base = None
    for eps_i in (0.4, 0.2, 0.1):
        s = make_schedule(n, N, K=1, eps=eps_i, delta=0.1)
        if base is None:
            base = (eps_i, s.total_pulls)
        pred = base[1] * base[0] / eps_i
        rows.append((f"scaling_eps{eps_i}", 0.0,
                     f"pulls={s.total_pulls};inv_eps_pred={pred:.0f};"
                     f"ratio={s.total_pulls / pred:.2f}"))
    # Corollary 2: per-arm cap at N even for eps -> 0
    s = make_schedule(1000, 2000, K=1, eps=1e-6, delta=0.01)
    rows.append(("corollary2_cap", 0.0,
                 f"max_t={max(r.t_cum for r in s.rounds)};N=2000;"
                 f"capped={max(r.t_cum for r in s.rounds) <= 2000}"))
    # preprocessing: BoundedME 0 vs baselines measured
    V, _ = gaussian_dataset(1000, 4096, seed=0)
    t0 = time.time(); build_lsh(V, a=8, b=16); t_lsh = time.time() - t0
    t0 = time.time(); build_greedy(V); t_greedy = time.time() - t0
    t0 = time.time(); build_pca_tree(V, depth=6); t_pca = time.time() - t0
    rows.append(("preprocessing_s", 0.0,
                 f"boundedme=0.0;lsh={t_lsh:.2f};greedy={t_greedy:.2f};"
                 f"pca={t_pca:.2f}"))
    # beyond-paper: anytime BoundedSE vs BoundedME on easy vs adversarial
    rng = np.random.default_rng(0)
    means = np.full(400, 0.3); means[0] = 0.7
    R_easy = (rng.uniform(0, 1, (400, 4000)) < means[:, None]).astype(np.float32)
    # uniform pull order (the MIPS model); values stay adversarial
    R_adv = rng.permuted(adversarial_dataset(400, 4000, seed=9), axis=1)
    for tag, R in (("easy", R_easy), ("adversarial", R_adv)):
        me = bounded_me(R, K=1, eps=0.05, delta=0.1)
        se = bounded_se(R, K=1, eps=0.05, delta=0.1)
        rows.append((f"boundedse_{tag}", 0.0,
                     f"me_pulls={me.total_pulls};se_pulls={se.total_pulls};"
                     f"se_speedup={me.total_pulls / max(1, se.total_pulls):.2f}"))
    if csv:
        print("name,us_per_call,derived")
        for name, us, derived in rows:
            print(f"table1_{name},{us:.0f},{derived}")
    return rows


if __name__ == "__main__":
    run()
