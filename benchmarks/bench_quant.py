"""PR-3/PR-8 perf benchmarks: the quantized sampling precision ladder.

`run` emits the rows for ``BENCH_PR3.json`` (via `benchmarks.run`): for
each decode batch size B in {1, 8, 32}, wall time and throughput of the
batched decode path at ``precision='fp32'`` vs ``precision='int8'`` —
both the pure sampling phase (``final_exact=False``: cascade only, the
part whose memory traffic int8 halves) and the serving configuration
(``final_exact=True``: int8 replaces fp32 coverage completion with an
fp32 candidate rescore, so it wins twice).  The int8 timings *include*
the per-call table quantization (this path quantizes in-jit; a
production deployment would hoist it out of the dispatch — see
docs/TUNING.md), so the reported win is a lower bound.

`run_pr8` emits ``BENCH_PR8.json``: the full fp32/int8/int4/pq ladder
on a planted, pq-compressible workload (clustered subspaces + planted
self-similar winners), reporting bytes pulled per sampled coordinate,
total pulled sampling bytes, recall vs exact top-K, and wall time per
tier — the acceptance number is int4/pq pulling >= 2x fewer bytes per
pull than int8 at unchanged recall (DESIGN.md §10).

Numbers from this CPU container track the trend only; the HBM-traffic
reduction that motivates the quantized tiers (DESIGN.md §10) needs TPU
hardware to show its full effect.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundedme_jax import (bounded_me_decode, make_plan,
                                      measured_plan_quant_err)

# the PR-1 acceptance geometry (B=32, n=32768, N=4096) so the int8 rows
# are directly comparable with BENCH_PR1.json's decode numbers
_N_ARMS, _DIM, _K = 32768, 4096, 4
_BATCHES = (1, 8, 32)
_EPS, _DELTA, _VR, _BLOCK = 0.1, 0.05, 4.0, 512


def _time_ms(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def run(csv: bool = True) -> dict:
    """Run the int8-vs-fp32 sweep; returns the BENCH_PR3 payload dict."""
    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.normal(size=(_N_ARMS, _DIM)), jnp.float32)
    key = jax.random.PRNGKey(0)

    plans = {prec: make_plan(_N_ARMS, _DIM, K=_K, eps=_EPS, delta=_DELTA,
                             value_range=_VR, tile=8, block=_BLOCK,
                             precision=prec)
             for prec in ("fp32", "int8")}
    out = {
        "geometry": {"n": _N_ARMS, "N": _DIM, "K": _K, "eps": _EPS,
                     "delta": _DELTA, "block": _BLOCK},
        "plan": {prec: {"rounds": len(p.schedule.rounds),
                        "total_pulls": p.schedule.total_pulls,
                        "quant_err": p.quant_err,
                        "eps_effective": p.eps_effective}
                 for prec, p in plans.items()},
        "int8_vs_fp32": [],
    }
    for B in _BATCHES:
        Q = jnp.asarray(rng.normal(size=(B, _DIM)), jnp.float32)
        row = {"batch_size": B}
        for prec, plan in plans.items():
            ms_sampling = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=False, use_pallas=False))
            ms_serve = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False))
            row[prec] = {
                "sampling_ms": ms_sampling,
                "sampling_qps": B / (ms_sampling * 1e-3),
                "serve_ms": ms_serve,
                "serve_qps": B / (ms_serve * 1e-3),
            }
        row["sampling_speedup"] = (row["fp32"]["sampling_ms"]
                                   / row["int8"]["sampling_ms"])
        row["serve_speedup"] = (row["fp32"]["serve_ms"]
                                / row["int8"]["serve_ms"])
        out["int8_vs_fp32"].append(row)
        if csv:
            print(f"quant_decode,B={B},"
                  f"sampling_fp32={row['fp32']['sampling_ms']:.0f}ms"
                  f";sampling_int8={row['int8']['sampling_ms']:.0f}ms"
                  f";sampling_speedup={row['sampling_speedup']:.2f}x"
                  f";serve_speedup={row['serve_speedup']:.2f}x")

    # recall sanity at the bench eps: int8 answers stay eps_eff-optimal
    B = 8
    Q = jnp.asarray(rng.normal(size=(B, _DIM)), jnp.float32)
    ids, scores = bounded_me_decode(V, Q, key, plan=plans["int8"],
                                    final_exact=True, use_pallas=False)
    exact = np.asarray(V) @ np.asarray(Q).T / _DIM            # (n, B)
    kth = -np.sort(-exact, axis=0)[_K - 1]                    # (B,)
    worst = float(np.min(np.asarray(scores)[:, _K - 1] - kth))
    out["int8_suboptimality"] = {
        "worst_vs_kth_exact": worst,
        "eps_effective": plans["int8"].eps_effective,
        "within_guarantee": bool(worst >= -plans["int8"].eps_effective),
    }
    if csv:
        print(f"quant_recall,,worst_gap={worst:.5f}"
              f";eps_eff={plans['int8'].eps_effective:.4f}")
    return out


# ---------------------------------------------------------------------------
# PR-8: the full precision ladder on a planted, pq-compressible workload
# ---------------------------------------------------------------------------

_P8_N, _P8_DIM, _P8_K, _P8_B = 2048, 2048, 4, 8
_P8_EPS, _P8_DELTA, _P8_VR, _P8_BLOCK = 0.2, 0.05, 4.0, 512
_P8_SUBDIMS, _P8_CODES = 8, 16

# bytes pulled per sampled (row, coordinate) in the cascade's pull loop:
# fp32 word, int8 byte, packed nibble, one uint8 code per subdims-wide
# subspace (the per-(query, block) LUT build reads the codebook once and
# amortizes across all row tiles, so it is not per-pull traffic)
_BYTES_PER_COORD = {"fp32": 4.0, "int8": 1.0, "int4": 0.5,
                    "pq": 1.0 / _P8_SUBDIMS}


def _planted_workload(seed: int = 0):
    """Clustered table with a planted staircase top-K per query.

    Every `_P8_SUBDIMS`-wide subspace chunk is one of 4 dictionary atoms
    plus small noise — the compressible regime pq exists for.  Each of
    the B queries is built from its own chunk pattern, and its K true
    winners are planted rows sharing 100%, 97%, 94%, ... of that pattern
    (every planted row stays atom-structured, so pq compresses it like
    any other row).  Background rows match ~25% of chunks, leaving a
    ~0.5 margin below the K-th winner — far above every tier's widened
    eps budget, so recall is a sharp pass/fail across tiers rather than
    a measurement of near-tie shuffling inside the eps contract.
    """
    rng = np.random.default_rng(seed)
    n_chunks = _P8_DIM // _P8_SUBDIMS
    atoms = rng.normal(size=(4, _P8_SUBDIMS)).astype(np.float32)
    idx = rng.integers(0, 4, size=(_P8_N, n_chunks))
    patterns = rng.integers(0, 4, size=(_P8_B, n_chunks))
    for b in range(_P8_B):
        for j in range(_P8_K):              # winner j: flip 3%*j chunks
            row = b * _P8_K + j
            idx[row] = patterns[b]
            flips = rng.choice(n_chunks, size=(n_chunks * 3 * j) // 100,
                               replace=False)
            idx[row, flips] = (idx[row, flips] + 1
                               + rng.integers(0, 3, size=flips.size)) % 4
    V = (atoms[idx] + 0.01 * rng.normal(
        size=(_P8_N, n_chunks, _P8_SUBDIMS))
    ).reshape(_P8_N, _P8_DIM).astype(np.float32)
    Q = (atoms[patterns].reshape(_P8_B, _P8_DIM)
         + 0.01 * rng.normal(size=(_P8_B, _P8_DIM))).astype(np.float32)
    return V, Q


def run_pr8(csv: bool = True) -> dict:
    """Run the fp32/int8/int4/pq ladder sweep; returns BENCH_PR8 payload."""
    V_np, Q_np = _planted_workload()
    V = jnp.asarray(V_np)
    Q = jnp.asarray(Q_np)
    key = jax.random.PRNGKey(0)
    exact = V_np.astype(np.float64) @ Q_np.astype(np.float64).T / _P8_DIM
    truth = np.argsort(-exact, axis=0)[:_P8_K].T               # (B, K)

    out = {
        "geometry": {"n": _P8_N, "N": _P8_DIM, "K": _P8_K, "B": _P8_B,
                     "eps": _P8_EPS, "delta": _P8_DELTA,
                     "block": _P8_BLOCK, "pq_subdims": _P8_SUBDIMS,
                     "pq_codes": _P8_CODES},
        "tiers": {},
    }
    for prec in ("fp32", "int8", "int4", "pq"):
        qe = (measured_plan_quant_err(V, precision="pq", block=_P8_BLOCK,
                                      pq_subdims=_P8_SUBDIMS,
                                      pq_codes=_P8_CODES)
              if prec == "pq" else None)
        plan = make_plan(_P8_N, _P8_DIM, K=_P8_K, eps=_P8_EPS,
                         delta=_P8_DELTA, value_range=_P8_VR, tile=8,
                         block=_P8_BLOCK, precision=prec, quant_err=qe,
                         pq_subdims=_P8_SUBDIMS, pq_codes=_P8_CODES)
        ms = _time_ms(lambda: bounded_me_decode(
            V, Q, key, plan=plan, final_exact=True, use_pallas=False))
        ids, _ = bounded_me_decode(V, Q, key, plan=plan, final_exact=True,
                                   use_pallas=False)
        ids = np.asarray(ids)
        recall = float(np.mean([
            len(set(ids[b]) & set(truth[b])) / _P8_K
            for b in range(_P8_B)]))
        bpc = _BYTES_PER_COORD[prec]
        total_bytes = float(plan.schedule.total_pulls * plan.tile
                            * plan.block * bpc)
        out["tiers"][prec] = {
            "bytes_per_coord": bpc,
            "bytes_per_pull": bpc * plan.tile * plan.block,
            "total_sampling_bytes": total_bytes,
            "total_pulls": plan.schedule.total_pulls,
            "quant_err": plan.quant_err,
            "eps_effective": plan.eps_effective,
            "recall_at_k": recall,
            "serve_ms": ms,
        }
        if csv:
            print(f"quant_ladder,{prec},recall={recall:.3f}"
                  f";bytes_per_pull={bpc * plan.tile * plan.block:.0f}"
                  f";total_MB={total_bytes / 1e6:.2f}"
                  f";eps_eff={plan.eps_effective:.3f};ms={ms:.0f}")
    t = out["tiers"]
    out["acceptance"] = {
        "int4_vs_int8_bytes_per_pull": (t["int8"]["bytes_per_pull"]
                                        / t["int4"]["bytes_per_pull"]),
        "pq_vs_int8_bytes_per_pull": (t["int8"]["bytes_per_pull"]
                                      / t["pq"]["bytes_per_pull"]),
        "recall_unchanged": bool(
            t["int4"]["recall_at_k"] >= t["int8"]["recall_at_k"]
            and t["pq"]["recall_at_k"] >= t["int8"]["recall_at_k"]),
    }
    if csv:
        a = out["acceptance"]
        print(f"quant_ladder_accept,,int4_vs_int8="
              f"{a['int4_vs_int8_bytes_per_pull']:.1f}x"
              f";pq_vs_int8={a['pq_vs_int8_bytes_per_pull']:.1f}x"
              f";recall_unchanged={a['recall_unchanged']}")
    return out
