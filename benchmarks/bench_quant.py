"""PR-3 perf benchmark: int8 quantized sampling cascade vs fp32.

Emits the rows for ``BENCH_PR3.json`` (via `benchmarks.run`): for each
decode batch size B in {1, 8, 32}, wall time and throughput of the
batched decode path at ``precision='fp32'`` vs ``precision='int8'`` —
both the pure sampling phase (``final_exact=False``: cascade only, the
part whose memory traffic int8 halves) and the serving configuration
(``final_exact=True``: int8 replaces fp32 coverage completion with an
fp32 candidate rescore, so it wins twice).  The int8 timings *include*
the per-call table quantization (this path quantizes in-jit; a
production deployment would hoist it out of the dispatch — see
docs/TUNING.md), so the reported win is a lower bound.

Numbers from this CPU container track the trend only; the HBM-traffic
halving that motivates the int8 path (DESIGN.md §10) needs TPU hardware
to show its full effect.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundedme_jax import bounded_me_decode, make_plan

# the PR-1 acceptance geometry (B=32, n=32768, N=4096) so the int8 rows
# are directly comparable with BENCH_PR1.json's decode numbers
_N_ARMS, _DIM, _K = 32768, 4096, 4
_BATCHES = (1, 8, 32)
_EPS, _DELTA, _VR, _BLOCK = 0.1, 0.05, 4.0, 512


def _time_ms(fn, reps: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e3


def run(csv: bool = True) -> dict:
    """Run the int8-vs-fp32 sweep; returns the BENCH_PR3 payload dict."""
    rng = np.random.default_rng(0)
    V = jnp.asarray(rng.normal(size=(_N_ARMS, _DIM)), jnp.float32)
    key = jax.random.PRNGKey(0)

    plans = {prec: make_plan(_N_ARMS, _DIM, K=_K, eps=_EPS, delta=_DELTA,
                             value_range=_VR, tile=8, block=_BLOCK,
                             precision=prec)
             for prec in ("fp32", "int8")}
    out = {
        "geometry": {"n": _N_ARMS, "N": _DIM, "K": _K, "eps": _EPS,
                     "delta": _DELTA, "block": _BLOCK},
        "plan": {prec: {"rounds": len(p.schedule.rounds),
                        "total_pulls": p.schedule.total_pulls,
                        "quant_err": p.quant_err,
                        "eps_effective": p.eps_effective}
                 for prec, p in plans.items()},
        "int8_vs_fp32": [],
    }
    for B in _BATCHES:
        Q = jnp.asarray(rng.normal(size=(B, _DIM)), jnp.float32)
        row = {"batch_size": B}
        for prec, plan in plans.items():
            ms_sampling = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=False, use_pallas=False))
            ms_serve = _time_ms(lambda: bounded_me_decode(
                V, Q, key, plan=plan, final_exact=True, use_pallas=False))
            row[prec] = {
                "sampling_ms": ms_sampling,
                "sampling_qps": B / (ms_sampling * 1e-3),
                "serve_ms": ms_serve,
                "serve_qps": B / (ms_serve * 1e-3),
            }
        row["sampling_speedup"] = (row["fp32"]["sampling_ms"]
                                   / row["int8"]["sampling_ms"])
        row["serve_speedup"] = (row["fp32"]["serve_ms"]
                                / row["int8"]["serve_ms"])
        out["int8_vs_fp32"].append(row)
        if csv:
            print(f"quant_decode,B={B},"
                  f"sampling_fp32={row['fp32']['sampling_ms']:.0f}ms"
                  f";sampling_int8={row['int8']['sampling_ms']:.0f}ms"
                  f";sampling_speedup={row['sampling_speedup']:.2f}x"
                  f";serve_speedup={row['serve_speedup']:.2f}x")

    # recall sanity at the bench eps: int8 answers stay eps_eff-optimal
    B = 8
    Q = jnp.asarray(rng.normal(size=(B, _DIM)), jnp.float32)
    ids, scores = bounded_me_decode(V, Q, key, plan=plans["int8"],
                                    final_exact=True, use_pallas=False)
    exact = np.asarray(V) @ np.asarray(Q).T / _DIM            # (n, B)
    kth = -np.sort(-exact, axis=0)[_K - 1]                    # (B,)
    worst = float(np.min(np.asarray(scores)[:, _K - 1] - kth))
    out["int8_suboptimality"] = {
        "worst_vs_kth_exact": worst,
        "eps_effective": plans["int8"].eps_effective,
        "within_guarantee": bool(worst >= -plans["int8"].eps_effective),
    }
    if csv:
        print(f"quant_recall,,worst_gap={worst:.5f}"
              f";eps_eff={plans['int8'].eps_effective:.4f}")
    return out
