"""PR-6 runtime benchmark: sustained throughput, tail latency, shedding.

Emits the rows for ``BENCH_PR6.json`` (via `benchmarks.run`): the
continuous-batching `ServeRuntime` driven by the reproducible bursty
arrival trace (open loop, virtual clock — arrivals keep coming while the
executor is busy, so queues really grow), in three tables:

  * ``sustained`` — the same bursty stream served clean and under the
    deterministic fault schedule (latency spikes + transient/persistent
    dispatch faults): sustained rps, p50/p99, shed rate, availability,
    retry/failed-batch counters.  The with-faults row is the robustness
    headline: injected faults cost retries and latency, never a crash.
  * ``overload_sweep`` — offered load stepped past capacity with a
    degradation ladder configured: availability, shed rate, the fraction
    served degraded and the eps_served histogram per rung, showing
    accuracy being spent before availability (DESIGN.md §13).
  * ``admission_modes`` — the same overload with no ladder (reject-only)
    for the counterfactual, plus ``rung_costs``: the planned pull budget
    at each eps rung.  On CPU the per-dispatch wall-clock is launch-
    overhead dominated, so relaxing eps barely changes dispatch time and
    ladder availability matches reject-only; the compute the ladder
    sheds is visible in the rung pull budgets (the proxy that matters on
    an accelerator, where pulls ~ time).

Geometry is CPU-feasible on purpose; the *trends* (ladder engages before
shedding, faults cost latency not availability) are what is tracked
across PRs, not this container's absolute numbers.
"""

from __future__ import annotations

import numpy as np

from repro.launch.admission import PriorityClass
from repro.launch.faults import FaultInjector
from repro.launch.serve import ServeRuntime, simulate_stream

# high-dim geometry on purpose: the cascade saves *coordinate* pulls,
# so the eps ladder only has compute to shed when n_blocks is large
# (at 2048x4096/block=64 the pull budget drops ~3x from eps 0.4 to 3.2)
_N_ARMS, _DIM, _K = 2048, 4096, 4
_REQUESTS = 256
_LANES = 8
_QUEUE = 32
_EPS, _EPS_FLOOR = 0.4, 3.2
# generous per-request deadline: lets queues build under overload so the
# ladder engages (and degrades) before deadline expiry sheds the tail
_DEADLINE_MS = 200.0


def _make_runtime(table, *, eps_floor=None, injector=None,
                  queue_capacity=_QUEUE, metrics=None, tracer=None,
                  flight=None) -> ServeRuntime:
    rt = ServeRuntime(
        table, K=_K, eps=_EPS, delta=0.1, eps_floor=eps_floor,
        degrade_rungs=4, lanes=_LANES, batch_wait_ms=1.0,
        queue_capacity=queue_capacity, value_range=8.0, block=64,
        max_retries=2, retry_backoff_ms=0.5, fault_injector=injector,
        classes={"default": PriorityClass("default", priority=1,
                                          deadline_ms=_DEADLINE_MS)},
        cache_entries=0, recall_sample_rate=0.05,
        metrics=metrics, tracer=tracer, flight=flight)
    rt.warmup()                # compile off the virtual clock
    return rt


def _row(stats: dict) -> dict:
    o = stats["outcomes"]
    total = max(1, stats["requests"])
    return {
        "offered_rps": stats["trace"]["offered_rps"],
        "sustained_rps": stats["throughput_rps"],
        "availability": stats["availability"],
        "shed_rate": (o["overloaded"] + o["rejected"] + o["failed"])
        / total,
        "degraded_frac": o["degraded"] / total,
        "p50_ms": stats["latency_ms"]["p50"],
        "p99_ms": stats["latency_ms"]["p99"],
        "peak_queue_depth": stats["queue"]["peak_depth"],
        "served_per_rung": stats["degradation"]["served_per_rung"],
        "retries": stats["faults"]["retries"],
        "failed_batches": stats["faults"]["failed_batches"],
        "outcomes": dict(o),
    }


def run(csv: bool = True) -> dict:
    """Run the runtime scenarios; returns the BENCH_PR6 payload dict."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(_N_ARMS, _DIM)).astype(np.float32)
    queries = rng.normal(size=(_REQUESTS, _DIM)).astype(np.float32)

    out = {"geometry": {"n": _N_ARMS, "N": _DIM, "K": _K,
                        "requests": _REQUESTS, "lanes": _LANES,
                        "queue_capacity": _QUEUE, "eps": _EPS,
                        "eps_floor": _EPS_FLOOR,
                        "deadline_ms": _DEADLINE_MS},
           "sustained": [], "overload_sweep": [], "admission_modes": []}

    # -- sustained bursty load, clean vs injected faults ------------------
    for label, injector in (
            ("clean", None),
            ("faults", FaultInjector(7, latency_rate=0.08, latency_ms=5.0,
                                     error_rate=0.08,
                                     persistent_rate=0.25))):
        rt = _make_runtime(table, eps_floor=_EPS_FLOOR, injector=injector)
        stats = simulate_stream(rt, queries, pattern="bursty", seed=1,
                                open_loop=True, interarrival_ms=4.0)
        row = {"scenario": label, **_row(stats)}
        if injector is not None:
            row["injected"] = injector.stats()
        out["sustained"].append(row)
        if csv:
            print(f"sustained_{label},{row['sustained_rps']:.0f}rps,"
                  f"p99={row['p99_ms']:.2f}ms,"
                  f"shed={row['shed_rate']:.3f},"
                  f"avail={row['availability']:.3f}")

    # -- overload sweep: offered load vs the degradation ladder -----------
    for ia_ms in (4.0, 1.0, 0.25, 0.05):
        rt = _make_runtime(table, eps_floor=_EPS_FLOOR)
        stats = simulate_stream(rt, queries, pattern="bursty", seed=2,
                                open_loop=True, interarrival_ms=ia_ms)
        row = {"interarrival_ms": ia_ms, **_row(stats)}
        out["overload_sweep"].append(row)
        if csv:
            print(f"overload_ia{ia_ms},"
                  f"offered={row['offered_rps']:.0f}rps,"
                  f"avail={row['availability']:.3f},"
                  f"degraded={row['degraded_frac']:.3f},"
                  f"shed={row['shed_rate']:.3f}")

    # -- counterfactual: same overload with no ladder (reject-only) -------
    for label, floor in (("ladder", _EPS_FLOOR), ("reject_only", None)):
        rt = _make_runtime(table, eps_floor=floor)
        if label == "ladder":       # planned compute per rung (pull proxy)
            out["rung_costs"] = [
                {"eps": float(e),
                 "total_pulls": int(ex.plan.schedule.total_pulls)}
                for e, ex in zip(rt.ladder.eps_values, rt._rung_execs)]
        stats = simulate_stream(rt, queries, pattern="bursty", seed=2,
                                open_loop=True, interarrival_ms=0.25)
        out["admission_modes"].append({"mode": label, **_row(stats)})
        if csv:
            r = out["admission_modes"][-1]
            print(f"mode_{label},avail={r['availability']:.3f},"
                  f"degraded={r['degraded_frac']:.3f},"
                  f"shed={r['shed_rate']:.3f}")
    if csv:
        print("rung_costs," + ",".join(
            f"eps={c['eps']:.2f}:pulls={c['total_pulls']}"
            for c in out["rung_costs"]))
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
