"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark, then the
pull-loop roofline table (``results/roofline.md``).  Also writes the
machine-readable perf trajectories: ``BENCH_PR1.json`` (fused cascade /
batched decode: us_per_call, pull-count speedup, kernel dispatch counts),
``BENCH_PR2.json`` (serve-loop micro-batching: throughput vs batch
deadline at B in {1, 8, 32}, LRU hit rates), ``BENCH_PR3.json``
(int8 quantized sampling vs fp32 at B in {1, 8, 32}),
``BENCH_PR4.json`` (dynamic-store serving under churn + update cost vs
LSH/PCA full rebuilds), ``BENCH_PR5.json`` (adaptive early-exit mean
pulls + rounds_used histograms, easy vs hard workloads) and
``BENCH_PR6.json`` (continuous-batching runtime: sustained rps / p99 /
shed rate under bursty load with and without injected faults, plus the
overload sweep showing the eps degradation ladder engaging) and
``BENCH_PR7.json`` (coordinate-sampling pull mode: certified multiplies
+ wall time per pull mode over the d sweep, hybrid dispatch overhead,
and the pull-loop roofline's bytes-per-pull cells) and
``BENCH_PR8.json`` (the fp32/int8/int4/pq precision ladder on a planted
compressible workload: bytes per pull, total sampling bytes, recall and
wall time per tier) and ``BENCH_PR9.json`` (observability overhead:
sustained rps / p99 on the PR-6 bursty workload with instrumentation
off vs metrics-only vs metrics+trace+flight, plus the ns/op micro price
of the raw registry calls — gate: <= 3% on both) and ``BENCH_PR10.json``
(multi-tenant serving: per-tenant answered fraction / shed / p99 under
hot-tenant skew, cold-tenant p99 vs a dedicated isolated baseline — gate:
ratio <= 2x with the hot tenant throttled not starving — and the
eviction/page-in cost of memory-budgeted table residency) so numbers
stay comparable across PRs.
"""

from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(__file__))
BENCH_JSON = os.path.join(_ROOT, "BENCH_PR1.json")
BENCH2_JSON = os.path.join(_ROOT, "BENCH_PR2.json")
BENCH3_JSON = os.path.join(_ROOT, "BENCH_PR3.json")
BENCH4_JSON = os.path.join(_ROOT, "BENCH_PR4.json")
BENCH5_JSON = os.path.join(_ROOT, "BENCH_PR5.json")
BENCH6_JSON = os.path.join(_ROOT, "BENCH_PR6.json")
BENCH7_JSON = os.path.join(_ROOT, "BENCH_PR7.json")
BENCH8_JSON = os.path.join(_ROOT, "BENCH_PR8.json")
BENCH9_JSON = os.path.join(_ROOT, "BENCH_PR9.json")
BENCH10_JSON = os.path.join(_ROOT, "BENCH_PR10.json")


def main() -> None:
    from benchmarks import (bench_adaptive, bench_coord, bench_fused,
                            bench_obs, bench_quant, bench_runtime,
                            bench_serve, bench_store, bench_tenancy,
                            fig1_guarantee, fig23_synthetic, fig4_real,
                            roofline, table1_complexity)
    print("== fused cascade / batched decode (PR 1) ==")
    import jax
    meta = {"backend": jax.default_backend(),
            "devices": jax.device_count()}
    payload = {"meta": meta, "benchmarks": bench_fused.run()}
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench] wrote {BENCH_JSON}")
    print("== serve-loop micro-batching (PR 2) ==")
    payload2 = {"meta": meta, "benchmarks": bench_serve.run()}
    with open(BENCH2_JSON, "w") as f:
        json.dump(payload2, f, indent=2)
    print(f"[bench] wrote {BENCH2_JSON}")
    print("== int8 quantized sampling vs fp32 (PR 3) ==")
    payload3 = {"meta": meta, "benchmarks": bench_quant.run()}
    with open(BENCH3_JSON, "w") as f:
        json.dump(payload3, f, indent=2)
    print(f"[bench] wrote {BENCH3_JSON}")
    print("== dynamic table store: churn + update cost (PR 4) ==")
    payload4 = {"meta": meta, "benchmarks": bench_store.run()}
    with open(BENCH4_JSON, "w") as f:
        json.dump(payload4, f, indent=2)
    print(f"[bench] wrote {BENCH4_JSON}")
    print("== adaptive early-exit cascade (PR 5) ==")
    payload5 = {"meta": meta, "benchmarks": bench_adaptive.run()}
    with open(BENCH5_JSON, "w") as f:
        json.dump(payload5, f, indent=2)
    print(f"[bench] wrote {BENCH5_JSON}")
    print("== continuous-batching runtime under overload/faults (PR 6) ==")
    payload6 = {"meta": meta, "benchmarks": bench_runtime.run()}
    with open(BENCH6_JSON, "w") as f:
        json.dump(payload6, f, indent=2)
    print(f"[bench] wrote {BENCH6_JSON}")
    print("== coordinate pull mode + roofline (PR 7) ==")
    payload7 = {"meta": meta, "benchmarks": bench_coord.run(),
                "roofline": roofline.run()}
    with open(BENCH7_JSON, "w") as f:
        json.dump(payload7, f, indent=2)
    print(f"[bench] wrote {BENCH7_JSON}")
    print("== precision ladder: int4 + pq vs int8/fp32 (PR 8) ==")
    payload8 = {"meta": meta, "benchmarks": bench_quant.run_pr8()}
    with open(BENCH8_JSON, "w") as f:
        json.dump(payload8, f, indent=2)
    print(f"[bench] wrote {BENCH8_JSON}")
    print("== observability overhead: off vs metrics vs trace (PR 9) ==")
    payload9 = {"meta": meta, "benchmarks": bench_obs.run()}
    with open(BENCH9_JSON, "w") as f:
        json.dump(payload9, f, indent=2)
    print(f"[bench] wrote {BENCH9_JSON}")
    print("== multi-tenant fairness / paging / isolation (PR 10) ==")
    payload10 = {"meta": meta, "benchmarks": bench_tenancy.run()}
    with open(BENCH10_JSON, "w") as f:
        json.dump(payload10, f, indent=2)
    print(f"[bench] wrote {BENCH10_JSON}")
    print("== table1: complexity/guarantees ==")
    table1_complexity.run()
    print("== fig1: guarantee validation (adversarial) ==")
    fig1_guarantee.run()
    print("== fig2: synthetic gaussian ==")
    fig23_synthetic.run("gaussian")
    print("== fig3: synthetic uniform ==")
    fig23_synthetic.run("uniform")
    print("== fig4: real-world proxy (MF embeddings) ==")
    fig4_real.run()
    print("== pull-loop roofline (results/roofline.md) ==")
    md = roofline.table(payload7["roofline"])
    res_dir = os.path.join(_ROOT, "results")
    os.makedirs(res_dir, exist_ok=True)
    with open(os.path.join(res_dir, "roofline.md"), "w") as f:
        f.write("# Pull-loop roofline (v5e constants, row vs coord)\n\n")
        f.write(md + "\n")
    print(md)


if __name__ == '__main__':
    main()
