"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark, then the
roofline table from the dry-run artifacts (if present).  Also writes
``BENCH_PR1.json`` (per-benchmark us_per_call, pull-count speedup, kernel
dispatch counts) so the perf trajectory is machine-comparable across PRs.
"""

from __future__ import annotations

import json
import os
import sys

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_PR1.json")


def main() -> None:
    from benchmarks import (bench_fused, fig1_guarantee, fig23_synthetic,
                            fig4_real, table1_complexity)
    print("== fused cascade / batched decode (PR 1) ==")
    import jax
    payload = {
        "meta": {"backend": jax.default_backend(),
                 "devices": jax.device_count()},
        "benchmarks": bench_fused.run(),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench] wrote {BENCH_JSON}")
    print("== table1: complexity/guarantees ==")
    table1_complexity.run()
    print("== fig1: guarantee validation (adversarial) ==")
    fig1_guarantee.run()
    print("== fig2: synthetic gaussian ==")
    fig23_synthetic.run("gaussian")
    print("== fig3: synthetic uniform ==")
    fig23_synthetic.run("uniform")
    print("== fig4: real-world proxy (MF embeddings) ==")
    fig4_real.run()
    print("== roofline (from dry-run artifacts) ==")
    try:
        from benchmarks import roofline
        roofline.main()
    except Exception as e:  # dry-run may not have been executed yet
        print(f"roofline skipped: {e}")


if __name__ == '__main__':
    main()
