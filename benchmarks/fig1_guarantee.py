"""Paper Fig. 1: validate the (eps, delta) guarantee on adversarial data.

For each (eps, delta): run BoundedME on fresh adversarial datasets and
report the (1-delta)-percentile of the observed suboptimalities.  The
theorem holds iff that percentile stays below eps.  Scaled-down shapes
(n=2000, N=20000) keep CPU runtime sane; the paper used (1e4, 1e5).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import bounded_me
from repro.data.synthetic import adversarial_dataset

N_ARMS, N_REWARDS, TRIALS = 2000, 20_000, 10


def run(csv: bool = True):
    rows = []
    for eps in (0.1, 0.2, 0.3, 0.45, 0.6):
        for delta in (0.05, 0.1, 0.2, 0.3):
            subopts = []
            t0 = time.time()
            pulls = 0
            for t in range(TRIALS):
                R = adversarial_dataset(N_ARMS, N_REWARDS, seed=1000 + t)
                means = R.mean(axis=1)
                res = bounded_me(R, K=1, eps=eps, delta=delta)
                subopts.append(means.max() - means[res.topk[0]])
                pulls += res.total_pulls
            q = float(np.quantile(subopts, 1.0 - delta))
            us = (time.time() - t0) / TRIALS * 1e6
            ok = q < eps
            rows.append((eps, delta, q, ok, pulls / TRIALS, us))
    if csv:
        print("name,us_per_call,derived")
        for eps, delta, q, ok, pulls, us in rows:
            print(f"fig1_eps{eps}_delta{delta},{us:.0f},"
                  f"subopt_q={q:.4f};holds={ok};pulls={pulls:.0f}")
    holds = all(r[3] for r in rows)
    print(f"# Theorem-1 guarantee holds for all {len(rows)} (eps,delta) "
          f"pairs: {holds}")
    return rows


if __name__ == "__main__":
    run()
