"""Paper Figs. 2-3: precision vs online speedup, synthetic data.

Sweeps each method's knob and reports (speedup, precision@K) pairs:
  * BOUNDEDME — eps knob (the paper's contribution: an explicit guarantee)
  * LSH-MIPS  — (a, b) grid        * GREEDY-MIPS — budget B
  * PCA-MIPS  — tree depth/spill
Speedup is FLOP-count based (naive nN multiplies / method query multiplies)
— the quantity the theory bounds; preprocessing is ignored (favouring the
baselines), exactly as in the paper.  Scaled shapes for CPU runtime.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.baselines import (build_greedy, build_lsh, build_pca_tree,
                             exact_mips, greedy_mips, lsh_mips, pca_mips)
from repro.core import bounded_me, reward_matrix
from repro.data.synthetic import gaussian_dataset, uniform_dataset

N, DIM, K, QUERIES = 2000, 20_000, 5, 3


def precision(returned, truth) -> float:
    return len(set(np.asarray(returned).tolist())
               & set(truth.tolist())) / len(truth)


def run(dist: str = "gaussian", csv: bool = True):
    gen = gaussian_dataset if dist == "gaussian" else uniform_dataset
    rng = np.random.default_rng(0)
    V, _ = gen(N, DIM, seed=0)
    queries = [gen(1, DIM, seed=100 + i)[1] for i in range(QUERIES)]
    naive = N * DIM
    rows = []

    for eps in (0.05, 0.1, 0.2, 0.4, 0.7, 1.0):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            vr = float(np.abs(V).max() * np.abs(q).max())
            R = reward_matrix(V, q, rng)
            res = bounded_me(R, K=K, eps=eps * vr, delta=0.1,
                             value_range=2 * vr)
            precs.append(precision(res.topk, truth))
            speeds.append(naive / max(1, res.total_pulls))
        rows.append((f"boundedme_eps{eps}", np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    lsh_idx = {}
    for a, b in ((12, 8), (8, 8), (6, 16), (4, 32)):
        if (a, b) not in lsh_idx:
            lsh_idx[(a, b)] = build_lsh(V, a=a, b=b, seed=1)
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            r = lsh_mips(lsh_idx[(a, b)], q, K)
            precs.append(precision(r.topk, truth))
            speeds.append(naive / max(1, r.query_multiplies))
        rows.append((f"lsh_a{a}_b{b}", np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    gidx = build_greedy(V)
    for budget in (20, 100, 400, 1600):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            r = greedy_mips(gidx, q, K, budget=budget)
            precs.append(precision(r.topk, truth))
            speeds.append(naive / max(1, r.query_multiplies))
        rows.append((f"greedy_B{budget}", np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    tree = build_pca_tree(V, depth=8)
    for spill in (0.0, 0.05, 0.2, 0.5):
        precs, speeds, t0 = [], [], time.time()
        for q in queries:
            truth = exact_mips(V, q, K).topk
            r = pca_mips(tree, q, K, spill=spill)
            precs.append(precision(r.topk, truth))
            speeds.append(naive / max(1, r.query_multiplies))
        rows.append((f"pca_spill{spill}", np.mean(speeds), np.mean(precs),
                     (time.time() - t0) / QUERIES * 1e6))

    if csv:
        print("name,us_per_call,derived")
        for name, sp, pr, us in rows:
            print(f"fig23_{dist}_{name},{us:.0f},"
                  f"speedup={sp:.2f};precision={pr:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="gaussian",
                    choices=["gaussian", "uniform"])
    args = ap.parse_args()
    run(args.dist)
