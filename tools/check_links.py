#!/usr/bin/env python
"""Doc link checker for the markdown suite (ISSUE 3 satellite).

Every relative markdown link and every backtick-quoted repo path
mentioned in the audited docs must exist on disk, so README/DESIGN/docs
can't drift from the tree they describe.  Pure stdlib (no deps, runs in
milliseconds before the CI environment installs anything):

    python tools/check_links.py          # exit 0 = all targets exist

Checked per file:
  * inline markdown links ``[text](target)`` with a relative target
    (http(s)/mailto and pure #anchors are skipped; a target's own
    #fragment is stripped before the existence check);
  * backtick-quoted paths that look like repo files (contain a '/' and
    end in a known source/doc extension), e.g. `repro/core/bounds.py` —
    resolved against the repo root, `src/`, and the referencing file's
    directory.

Run by CI (docs job) and by tests/test_docs.py.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# the documentation surface whose references must stay live
AUDITED_DOCS = [
    "README.md",
    "DESIGN.md",
    "EXPERIMENTS.md",
    "ROADMAP.md",
    "docs/KERNEL.md",
    "docs/TUNING.md",
    "docs/OBSERVABILITY.md",
]

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_TICK_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+"
                        r"\.(?:py|md|json|yml|yaml|txt))`")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def _exists(target: str, base: Path) -> bool:
    """True if ``target`` resolves against the doc's dir, repo root or src/."""
    for root in (base, REPO, REPO / "src", REPO / "src" / "repro"):
        if (root / target).exists():
            return True
    return False


def check() -> list:
    """Return human-readable problems for broken doc references."""
    problems = []
    for rel in AUDITED_DOCS:
        path = REPO / rel
        if not path.exists():
            problems.append(f"{rel}: audited doc missing")
            continue
        text = path.read_text()
        for m in _MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_SKIP_PREFIXES):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            if not _exists(target, path.parent):
                line = text.count("\n", 0, m.start()) + 1
                problems.append(f"{rel}:{line}: broken link -> {target}")
        for m in _TICK_PATH.finditer(text):
            target = m.group(1)
            if not _exists(target, path.parent):
                line = text.count("\n", 0, m.start()) + 1
                problems.append(f"{rel}:{line}: dangling path "
                                f"reference -> {target}")
    return problems


def main() -> int:
    problems = check()
    if problems:
        print(f"doc links: {len(problems)} problem(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"doc links OK: {len(AUDITED_DOCS)} docs, all referenced "
          f"paths exist")
    return 0


if __name__ == "__main__":
    sys.exit(main())
