#!/usr/bin/env python
"""Validate serve-CLI observability artifacts (ISSUE 9 satellite).

Pure stdlib (no jax), so CI can validate the files emitted by the
fault-injection smoke run in milliseconds::

    python tools/check_obs_artifacts.py \
        --metrics out/metrics.prom --trace out/trace.json \
        --flight out/flight.json

Checks, per artifact (schemas in docs/OBSERVABILITY.md):

* ``--metrics`` — Prometheus text exposition (``.prom``/``.txt``): every
  sample line parses, every family has HELP/TYPE headers, histogram
  ``_bucket`` series are cumulative, end at ``le="+Inf"`` and agree with
  ``_count``; when the runtime's ``serve_outcomes_total`` family is
  present, its outcome labels must come from the closed `STATUSES` set
  and sum to ``serve_requests_total`` (every request got exactly one
  typed outcome — the --check-outcomes contract, re-verified from the
  exported counters).  JSON snapshots: the ``{"metrics": [...]}`` shape
  with per-row values/cells.
* ``--trace`` — Chrome trace-event JSON: a ``traceEvents`` list where
  every event carries ``ph``/``name``/``pid``/``tid``, complete (``X``)
  spans carry ``ts``/``dur >= 0``, and every per-request track's events
  nest inside that request's enclosing ``request rid=N`` span.
* ``--flight`` — flight-recorder dump: required payload keys, event
  ``seq`` strictly increasing, ring size within ``capacity``.

Exit 0 = all provided artifacts valid; any problem prints and exits 1.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from typing import List

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)$')

#: the runtime's closed typed-outcome set (launch/admission.STATUSES,
#: duplicated here so the check stays stdlib-only)
_STATUSES = ("ok", "degraded", "rejected", "overloaded", "failed")


def check_metrics(path: str, expect_tenants=None) -> List[str]:
    """Problems in a metrics artifact (Prometheus text or JSON).

    ``expect_tenants`` (optional list of tenant names) additionally
    requires the multi-tenant runtime's tenant labeling: every expected
    tenant must appear as a ``tenant`` label value on
    ``serve_requests_total``, and no serve counter may carry a tenant
    outside the expected set (a tenant the registry never registered
    would mean requests were routed to a ghost table).
    """
    problems: List[str] = []
    expect_tenants = list(expect_tenants or [])
    seen_tenants: set = set()
    with open(path) as f:
        text = f.read()
    if not path.endswith((".prom", ".txt")):
        try:
            snap = json.loads(text)
        except ValueError as e:
            return [f"{path}: not JSON: {e}"]
        if not isinstance(snap.get("metrics"), list):
            return [f"{path}: missing top-level 'metrics' list"]
        for m in snap["metrics"]:
            for key in ("name", "kind", "help", "labels", "values"):
                if key not in m:
                    problems.append(f"{path}: metric entry missing "
                                    f"{key!r}: {m.get('name', '?')}")
            if (expect_tenants and m.get("name") == "serve_requests_total"
                    and "tenant" in m.get("labels", [])):
                for row in m.get("values", []):
                    tn = row.get("labels", {}).get("tenant")
                    if tn is not None:
                        seen_tenants.add(tn)
        problems.extend(_tenant_coverage(path, expect_tenants,
                                         seen_tenants))
        return problems

    helped, typed = set(), set()
    series: dict = {}
    outcomes: dict = {}
    requests_total = 0.0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            helped.add(line.split()[2])
            continue
        if line.startswith("# TYPE "):
            typed.add(line.split()[2])
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"{path}:{ln}: unparseable sample: {line!r}")
            continue
        try:
            float(m.group("value").replace("+Inf", "inf")
                  .replace("-Inf", "-inf"))
        except ValueError:
            problems.append(f"{path}:{ln}: non-numeric value: {line!r}")
        name = m.group("name")
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if base not in helped and name not in helped:
            problems.append(f"{path}:{ln}: {name} has no # HELP header")
        if name == "serve_outcomes_total":
            om = re.search(r'outcome="([^"]*)"', m.group("labels") or "")
            if om:
                outcomes[om.group(1)] = (outcomes.get(om.group(1), 0.0)
                                         + float(m.group("value")))
        elif name == "serve_requests_total":
            requests_total += float(m.group("value"))
            tm = re.search(r'tenant="([^"]*)"', m.group("labels") or "")
            if tm:
                seen_tenants.add(tm.group(1))
        if name.endswith("_bucket"):
            labels = m.group("labels") or ""
            key = re.sub(r'le="[^"]*",?', "", labels)
            series.setdefault((base, key), []).append(
                (ln, labels, float(m.group("value"))))
    for (base, key), rows in series.items():
        vals = [v for _, _, v in rows]
        if vals != sorted(vals):
            problems.append(f"{path}: {base}{{{key}}} buckets are not "
                            f"cumulative: {vals}")
        if 'le="+Inf"' not in rows[-1][1]:
            problems.append(f"{path}: {base}{{{key}}} does not end at "
                            f'le="+Inf"')
    if not series and "_bucket" in text:
        problems.append(f"{path}: bucket lines present but none parsed")
    if not helped:
        problems.append(f"{path}: no # HELP headers (not exposition "
                        f"format?)")
    if outcomes:
        bad = sorted(set(outcomes) - set(_STATUSES))
        if bad:
            problems.append(f"{path}: serve_outcomes_total has outcomes "
                            f"outside the closed set: {bad}")
        if abs(sum(outcomes.values()) - requests_total) > 1e-9:
            problems.append(
                f"{path}: outcome counters sum to "
                f"{sum(outcomes.values()):g} but serve_requests_total is "
                f"{requests_total:g} (every request must get exactly one "
                f"typed outcome)")
    problems.extend(_tenant_coverage(path, expect_tenants, seen_tenants))
    return problems


def _tenant_coverage(path: str, expected: List[str],
                     seen: set) -> List[str]:
    """Both directions of the --expect-tenants check (no-op when the
    expectation is empty)."""
    problems: List[str] = []
    if not expected:
        return problems
    missing = sorted(set(expected) - seen)
    if missing:
        problems.append(
            f"{path}: serve_requests_total has no tenant label rows for "
            f"{missing} (expected tenants {sorted(expected)}, saw "
            f"{sorted(seen)})")
    extra = sorted(seen - set(expected))
    if extra:
        problems.append(
            f"{path}: serve_requests_total has unexpected tenants "
            f"{extra} — requests were routed to a table the spec never "
            f"declared")
    return problems


def check_trace(path: str) -> List[str]:
    """Problems in a Chrome trace-event JSON artifact."""
    problems: List[str] = []
    with open(path) as f:
        try:
            tr = json.load(f)
        except ValueError as e:
            return [f"{path}: not JSON: {e}"]
    evs = tr.get("traceEvents")
    if not isinstance(evs, list):
        return [f"{path}: missing 'traceEvents' list"]
    if not evs:
        problems.append(f"{path}: empty traceEvents")
    enclosing: dict = {}
    for i, ev in enumerate(evs):
        for key in ("ph", "name", "pid", "tid"):
            if key not in ev:
                problems.append(f"{path}: event {i} missing {key!r}")
        if ev.get("ph") == "X":
            if "ts" not in ev or "dur" not in ev:
                problems.append(f"{path}: X event {i} "
                                f"({ev.get('name')}) missing ts/dur")
            elif ev["dur"] < 0:
                problems.append(f"{path}: X event {i} has dur < 0")
            if str(ev.get("name", "")).startswith("request rid="):
                enclosing[ev["tid"]] = (ev["ts"], ev["ts"] + ev["dur"])
        elif ev.get("ph") == "i" and "ts" not in ev:
            problems.append(f"{path}: instant event {i} missing ts")
    for i, ev in enumerate(evs):
        if ev.get("ph") not in ("X", "i"):
            continue
        span = enclosing.get(ev.get("tid"))
        if span is None or str(ev.get("name", "")).startswith("request "):
            continue
        t0, t1 = span
        if ev["ts"] < t0 - 1e-6 or ev["ts"] + ev.get("dur", 0) > t1 + 1e-6:
            problems.append(
                f"{path}: event {i} ({ev['name']}) escapes its "
                f"enclosing request span on tid {ev['tid']}")
    od = tr.get("otherData", {})
    for key in ("n_requests_seen", "n_requests_sampled"):
        if key not in od:
            problems.append(f"{path}: otherData missing {key!r}")
    return problems


def check_flight(path: str) -> List[str]:
    """Problems in a flight-recorder dump."""
    problems: List[str] = []
    with open(path) as f:
        try:
            fl = json.load(f)
        except ValueError as e:
            return [f"{path}: not JSON: {e}"]
    for key in ("reason", "seq", "capacity", "n_recorded", "n_dumps",
                "events"):
        if key not in fl:
            problems.append(f"{path}: payload missing {key!r}")
    evs = fl.get("events", [])
    if len(evs) > fl.get("capacity", 0):
        problems.append(f"{path}: {len(evs)} events exceed capacity "
                        f"{fl.get('capacity')}")
    seqs = [e.get("seq") for e in evs]
    if any(s is None for s in seqs):
        problems.append(f"{path}: event missing 'seq'")
    elif seqs != sorted(seqs) or len(set(seqs)) != len(seqs):
        problems.append(f"{path}: event seqs not strictly increasing: "
                        f"{seqs}")
    for i, e in enumerate(evs):
        if "kind" not in e or "t" not in e:
            problems.append(f"{path}: event {i} missing kind/t")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", default=None,
                    help="metrics snapshot (.prom/.txt exposition or JSON)")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON")
    ap.add_argument("--flight", default=None,
                    help="flight-recorder dump JSON")
    ap.add_argument("--expect-tenants", default=None,
                    help="comma-separated tenant names the --metrics "
                         "artifact must carry as tenant label values on "
                         "serve_requests_total (multi-tenant runs)")
    args = ap.parse_args()
    if not (args.metrics or args.trace or args.flight):
        ap.error("nothing to check: pass --metrics / --trace / --flight")
    if args.expect_tenants and not args.metrics:
        ap.error("--expect-tenants requires --metrics: tenant labels "
                 "live in the metrics snapshot")
    expected = ([t.strip() for t in args.expect_tenants.split(",")
                 if t.strip()] if args.expect_tenants else None)
    problems: List[str] = []
    checked = []
    if args.metrics:
        problems.extend(check_metrics(args.metrics,
                                      expect_tenants=expected))
        checked.append(args.metrics)
    for path, fn in ((args.trace, check_trace),
                     (args.flight, check_flight)):
        if path:
            problems.extend(fn(path))
            checked.append(path)
    if problems:
        print(f"obs artifacts: {len(problems)} problem(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    print(f"obs artifacts OK: {', '.join(checked)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
