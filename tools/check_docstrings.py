#!/usr/bin/env python
"""Doc-coverage check for the public API surface (ISSUE 2 satellite).

Pure-AST (no jax import, so it runs in milliseconds anywhere, including the
CI container before deps install): every public module, class and function
in the audited modules must carry a docstring, and the named public API
entry points must document their contract keywords (shapes, the eps/delta
knob, return structure).

    python tools/check_docstrings.py          # exit 0 = covered

Run by CI and by tests/test_docs.py so the suite fails when a public
symbol loses its docstring.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"

# modules whose whole public surface must be documented
AUDITED_MODULES = [
    "core/mips.py",
    "core/boundedme_jax.py",
    "core/bounds.py",
    "core/quantize.py",
    "core/schedule.py",
    "distributed/sharding.py",
    "distributed/specs.py",
    "kernels/ops.py",
    "kernels/fused_cascade.py",
    "launch/serve.py",
    "launch/engine.py",
    "launch/admission.py",
    "launch/tenancy.py",
    "launch/faults.py",
    "launch/mesh.py",
    "models/steps.py",
    "obs/__init__.py",
    "obs/metrics.py",
    "obs/trace.py",
    "obs/flight.py",
    "store/__init__.py",
    "store/dynamic_table.py",
    "store/sharded_table.py",
]

# entry points whose docstrings must mention their contract:
# {module: {qualname: [required substrings (case-insensitive)]}}
API_CONTRACTS = {
    "core/mips.py": {
        "mips_topk": ["eps", "delta", "(n, N)", "ids", "scores"],
        "sharded_mips_topk": ["eps", "delta", "shard", "(B, N)", "mesh"],
        "nns_topk": ["reduction"],
    },
    "core/boundedme_jax.py": {
        "bounded_me_decode": ["(B, N)", "eps, delta", "k_out", "plan",
                              "adaptive", "rounds_used", "returns"],
        "make_plan": ["range_mode", "precision", "bound", "pull_mode",
                      "coord_block", "hybrid"],
        "choose_pull_mode": ["row_margin", "total_multiplies", "hybrid"],
    },
    "core/bounds.py": {
        "quantization_error": ["symmetric", "value_range", "bias"],
        "bernstein_radius": ["empirical", "variance", "m >= N"],
        "m_required_eb": ["binary search", "[1, N]"],
        "coord_radius": ["d_blocks", "quant_err", "without replacement"],
        "coord_m_required": ["d_blocks", "eps", "full coverage"],
    },
    "core/quantize.py": {
        "quantize_tiles": ["(n_tiles, n_blocks", "int8", "scale"],
        "quantize_blocks": ["int8", "block"],
        "quantize_tiles_int4": ["nibble", "pack", "even", "scale"],
        "pack_int4": ["low nibble", "high nibble", "C/2"],
        "unpack_int4": ["sign", "inverse"],
        "pq_train": ["codebook", "deterministic", "subdims", "lloyd"],
        "pq_encode": ["codes", "uint8", "argmin", "codebook"],
        "pq_tile_dot": ["lut", "kernel", "fallback"],
        "measured_quant_err": ["safety", "max", "calibration",
                               "block-mean"],
    },
    "core/schedule.py": {
        "flatten_schedule": ["FlatSchedule"],
        "make_schedule": ["quant_err", "bound", "pull_mode", "pull_width"],
        "Schedule.total_coords": ["pull_width", "cost"],
        "cert_coeffs": ["a_l", "b_l", "union bound", "quant_err"],
        "pulls_through_round": ["rounds_used"],
    },
    "distributed/sharding.py": {
        "sharded_bounded_me_decode": ["eps", "delta", "shard", "merge",
                                      "gap", "ragged", "precision",
                                      "adaptive", "returns"],
        "make_shard_plan": ["union bound", "k_out", "pad"],
        "dispatch_lane_stats": ["occupancy", "executed_pull_frac",
                                "lanes", "adaptive"],
    },
    "kernels/ops.py": {
        "fused_cascade": ["k_out", "n_valid", "vscale", "cert"],
        "fused_cascade_batched": ["k_out", "n_valid"],
    },
    "store/dynamic_table.py": {
        "DynamicTableStore": ["capacity", "version", "n_valid",
                              "swap", "int8", "int4", "pq"],
        "DynamicTableStore.flush_updates": ["rows touched", "version",
                                            "dirty"],
        "DynamicTableStore.delete": ["swap", "prefix"],
        "DynamicTableStore.grow": ["recompil"],
        "DynamicTableStore.refresh_codebook": ["frozen", "retrain",
                                               "version",
                                               "recalibrat"],
        "DynamicTableStore.codebook": ["frozen", "snapshot"],
    },
    "store/sharded_table.py": {
        "ShardedTableStore": ["shard", "n_valid", "capacity", "merge"],
        "ShardedTableStore.n_valid_vector": ["per-shard"],
    },
    "launch/serve.py": {
        "arrival_trace": ["uniform", "poisson", "bursty", "seed"],
        "simulate_stream": ["virtual", "open_loop", "trace"],
    },
    "launch/engine.py": {
        "MIPSServeEngine.apply_updates": ["version", "recall",
                                          "value range", "recompile"],
        "QuantizedLRU.invalidate": ["version", "salt"],
        "CascadeExecutor.dispatch": ["lanes", "seconds", "rounds_used"],
        "ServeRuntime.submit": ["admission", "poison", "never raises"],
        "ServeRuntime.poll": ["work conservation", "batch_wait",
                              "expired"],
        "ServeRuntime.stats": ["p50", "p95", "p99", "outcomes",
                               "eps_served"],
    },
    "launch/admission.py": {
        "AdmissionController.admit": ["overloaded", "displac",
                                      "quarantine"],
        "AdmissionController.validate": ["poison", "NaN"],
        "AdmissionController.take": ["deadline", "expire", "priority"],
        "DegradationLadder": ["eps_floor", "rung", "eps_served"],
        "ServeResult": ["eps_served", "degraded", "never"],
    },
    "launch/tenancy.py": {
        "TableRegistry": ["byte", "budget", "lru", "pinned", "evict",
                          "salt"],
        "TableRegistry.register": ["budget", "evict", "never ooms"],
        "TableRegistry.evict": ["page", "bit-identical", "pinned"],
        "TableRegistry.executors": ["salt", "grow", "refresh_codebook",
                                    "page-in", "sync_store", "rebuild"],
        "TenantConfig": ["bit-identical", "weight", "deadline",
                         "pinned"],
        "MultiTenantRuntime": ["deficit", "round-robin", "tenant",
                               "isolation", "bit-identical", "starv"],
        "MultiTenantRuntime.submit": ["tenant", "admission", "poison",
                                      "never raises"],
        "MultiTenantRuntime.poll": ["deficit", "backlogged", "skew"],
        "MultiTenantRuntime.stats": ["tenants", "registry", "outcomes"],
    },
    "launch/faults.py": {
        "FaultInjector": ["seed", "latency", "persistent", "flush"],
        "FaultInjector.attach": ["fault_hook", "staged", "intact"],
        "FaultInjector.stats": ["seen", "rates", "milliseconds",
                                "legacy"],
    },
    "obs/metrics.py": {
        "MetricsRegistry": ["adopt", "get-or-create", "snapshot"],
        "MetricsRegistry.adopt": ["reference", "collision", "no-op"],
        "Counter.seed": ["row order", "0"],
        "Histogram": ["bucket", "upper bound", "+Inf"],
        "summarize_latencies": ["percentile", "milliseconds", "empty",
                                "keys"],
        "null_registry": ["no-op", "off"],
    },
    "obs/trace.py": {
        "SpanTracer": ["chrome", "perfetto", "reservoir", "virtual"],
        "SpanTracer.request_begin": ["reservoir", "sampled", "no-op"],
        "SpanTracer.export": ["unclosed", "enclosing"],
    },
    "obs/flight.py": {
        "FlightRecorder": ["ring", "dump", "overwrites", "path"],
        "FlightRecorder.dump": ["reason", "none"],
    },
}


def _public_defs(tree: ast.Module):
    """Yield (qualname, node) for public module-level defs and methods."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield node.name, node
        elif isinstance(node, ast.ClassDef):
            if node.name.startswith("_"):
                continue
            yield node.name, node
            for sub in node.body:
                if (isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and not sub.name.startswith("_")):
                    yield f"{node.name}.{sub.name}", sub


def check() -> list:
    """Return a list of human-readable violations (empty = covered)."""
    problems = []
    for rel in AUDITED_MODULES:
        path = SRC / rel
        if not path.exists():
            problems.append(f"{rel}: audited module missing")
            continue
        tree = ast.parse(path.read_text())
        if not ast.get_docstring(tree):
            problems.append(f"{rel}: missing module docstring")
        docs = {}
        for qual, node in _public_defs(tree):
            doc = ast.get_docstring(node)
            docs[qual] = doc or ""
            if not doc:
                problems.append(f"{rel}:{node.lineno}: {qual} has no "
                                f"docstring")
        for qual, needles in API_CONTRACTS.get(rel, {}).items():
            if qual not in docs:
                problems.append(f"{rel}: contract symbol {qual} not found")
                continue
            low = docs[qual].lower()
            for needle in needles:
                if needle.lower() not in low:
                    problems.append(
                        f"{rel}: {qual} docstring must mention "
                        f"{needle!r} (shapes/knobs contract)")
    return problems


def main() -> int:
    problems = check()
    if problems:
        print(f"doc coverage: {len(problems)} problem(s)")
        for p in problems:
            print(f"  {p}")
        return 1
    n = len(AUDITED_MODULES)
    print(f"doc coverage OK: {n} modules, all public symbols documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
