"""Public MIPS / NNS API, single-device and sharded.

``mips_topk`` is the user-facing entry point: zero preprocessing, explicit
(eps, delta) suboptimality knob (Motivation I + II).  ``sharded_mips_topk``
runs the identical static schedule independently on each shard of an
arm-sharded store (e.g. a vocab-sharded unembedding) and merges with a
single all-gather — the distributed form used inside `decode_step`.  The
multi-device *serving* hot path (shared permutation, bound gaps, ragged
shard support) is ``sharded_bounded_me_decode``, re-exported here from
`repro.distributed.sharding` (DESIGN.md §7).
"""

from __future__ import annotations

import functools
import weakref
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.boundedme_jax import (BlockedPlan, bounded_me_batched,
                                      bounded_me_blocked, choose_pull_mode,
                                      make_plan)
from repro.distributed.sharding import sharded_bounded_me_decode

__all__ = ["mips_topk", "nns_topk", "sharded_mips_topk", "exact_topk",
           "sharded_bounded_me_decode", "default_value_range",
           "table_abs_max", "choose_pull_mode"]


def exact_topk(V, q, K: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exhaustive baseline: full matvec + top_k.  Scores are (q.v)/N."""
    scores = (V @ q).astype(jnp.float32) / jnp.float32(V.shape[1])
    vals, ids = jax.lax.top_k(scores, K)
    return ids, vals


class _TableMaxCache:
    """Host-side cache of max|V| per table object.

    The fallback product-range bound needs an O(nN) reduction over the
    table; before PR 1 it was re-issued on every `mips_topk` call, which
    dominated the hot path for repeated queries against the same store.
    Keyed by ``id(table)`` with a weakref guard against id reuse; the rare
    non-weakref-able table type falls back to a strong ref, so the dict is
    evicted FIFO past ``_CAP`` tables to bound that case.
    """

    _CAP = 16

    def __init__(self):
        self._entries = {}

    def get(self, V) -> float:
        key = id(V)
        hit = self._entries.get(key)
        if hit is not None:
            ref, vmax = hit
            if ref() is not None:
                return vmax
            del self._entries[key]
        vmax = float(jnp.max(jnp.abs(jnp.asarray(V))))
        try:
            ref = weakref.ref(V)
        except TypeError:                    # non-weakref-able table type
            ref = (lambda strong=V: strong)  # strong ref; FIFO-evicted
        if len(self._entries) >= self._CAP:
            self._entries.pop(next(iter(self._entries)))
        self._entries[key] = (ref, vmax)
        return vmax


_TABLE_MAX = _TableMaxCache()


def table_abs_max(V) -> float:
    """max|V_ij| as a host float, computed once per table and cached."""
    return _TABLE_MAX.get(V)


def default_value_range(V, q) -> float:
    """Conservative data-derived product range 2 max|q| max|V|.

    The per-table reduction is cached host-side; the per-query max is O(N)
    and cheap.  Hot-path callers (serving loops, benchmarks) should still
    pass an explicit ``value_range`` bound instead — this helper exists for
    the zero-configuration path only (the paper assumes rewards in [0, 1]).
    """
    vr = 2.0 * float(jnp.max(jnp.abs(jnp.asarray(q)))) * table_abs_max(V)
    return max(vr, 1e-12)


def mips_topk(V, q, K: int = 1, *, method: str = "boundedme",
              eps: float = 0.05, delta: float = 0.05,
              value_range: Optional[float] = None,
              key: Optional[jax.Array] = None, tile: int = 8,
              block: int = 512, final_exact: bool = False,
              use_pallas: bool = False, precision: str = "fp32",
              adaptive: bool = False, bound: str = "hoeffding",
              pull_mode: str = "row", coord_block: int = 128,
              quant_err: Optional[float] = None,
              pq_subdims: int = 8, pq_codes: int = 16):
    """Top-K maximum inner product search over the rows of ``V``.

    Zero preprocessing: ``V`` can be hot-swapped between calls with no
    index rebuild (the per-table max used by the default ``value_range`` is
    the only cached state, keyed on object identity).

    Args:
      V: (n, N) float array — the item/arm matrix, rows are arms.
      q: (N,) float query.
      K: number of results, 1 <= K <= n.
      method: 'boundedme' (the paper's bandit) or 'exact' (full matvec
        baseline; ignores every knob below).
      eps / delta: suboptimality knob — returned arms are eps-optimal on
        the mean-product scale (q . v)/N with probability >= 1 - delta,
        at block-mean granularity on this path (DESIGN.md §3/§9).
      value_range: a-priori bound on per-coordinate products q_j * v_ij
        (the paper's rewards-in-[0, 1] assumption generalized).  Defaults
        to the conservative data-derived `default_value_range`; hot-path
        callers should pass an explicit bound.
      key: PRNG key for the block permutation (default PRNGKey(0)).
      tile / block: TPU geometry — arm-tile rows (elimination granularity)
        and coordinate-block width (pull granularity).
      final_exact: exactly rescore the final survivors so returned scores
        carry no estimation error.
      use_pallas: run the fused single-dispatch kernel (TPU; interpret
        mode elsewhere — slow, tests only).
      precision: 'fp32' (default), 'int8', 'int4' or 'pq' — the quantized
        tiers run every sampling round on compressed tiles under
        quantization-widened confidence bounds (DESIGN.md §10): int8/int4
        on a scalar integer grid (int4 nibble-packed, half the bytes per
        pull), 'pq' on per-subspace k-means codes (LUT tile-dots,
        ``block/pq_subdims`` bytes per pull).  Combine with
        ``final_exact`` for fp32-exact returned scores.
      quant_err: measured per-pull error bound on the block-mean scale
        (see `make_measured_plan`); None selects the worst-case default
        for int8/int4 and auto-calibration on ``V`` for 'pq'.
      pq_subdims / pq_codes: product-quantization subspace width and
        codebook size (precision='pq' only).
      adaptive: certify early exit per query at round boundaries
        (DESIGN.md §12): easy queries stop pulling as soon as their top-K
        is certified inside the same (eps, delta) contract.  The default
        False is bit-identical to the non-adaptive cascade.  This simple
        API discards the per-query ``rounds_used`` diagnostic — call
        `bounded_me_blocked`/`bounded_me_decode` directly to observe it.
      bound: certification radius family, 'hoeffding' (default; reuses
        the schedule's own events) or 'bernstein' (variance-aware
        empirical-Bernstein radii; reserves half of each round's delta
        budget and carries running mean/M2 accumulators).
      pull_mode: reward stream (DESIGN.md §14) — 'row' (default; pulls
        are ``block``-wide feature blocks per arm tile), 'coord' (the
        BanditMIPS coordinate estimator: narrow ``coord_block``-wide
        feature tiles sampled without replacement under a shared
        per-query permutation, making certified pull cost sublinear in
        d), or 'hybrid' (prices both candidate plans and dispatches to
        the cheaper via `choose_pull_mode`; row wins ties within a 10%
        multiply margin — the decision rule is documented in TUNING.md).
      coord_block: feature-tile width of the 'coord' estimator (default
        128, the TPU lane width).

    Returns:
      ``(ids (K,) int32, scores (K,) f32)``; scores estimate (q . v)/N.

    Raises:
      ValueError: unknown ``method``.
    """
    if method == "exact":
        return exact_topk(V, q, K)
    if method != "boundedme":
        raise ValueError(f"unknown method {method!r}")
    if key is None:
        key = jax.random.PRNGKey(0)
    if value_range is None:
        value_range = default_value_range(V, q)
    out = bounded_me_blocked(
        V, q, key, K=K, eps=eps, delta=delta, value_range=value_range,
        tile=tile, block=block, final_exact=final_exact,
        use_pallas=use_pallas, precision=precision, adaptive=adaptive,
        bound=bound, pull_mode=pull_mode, coord_block=coord_block,
        quant_err=quant_err, pq_subdims=pq_subdims, pq_codes=pq_codes)
    return out[0], out[1]


def nns_topk(V, q, K: int = 1, **kw):
    """Nearest-neighbor search via the paper's reduction f(i,j) = -(q_j-v_ij)^2.

    We expand -(q-v)^2 = 2 q.v - |v|^2 - |q|^2 and search the augmented MIPS
    instance [v, |v|^2-free form]: rows [sqrt(2) v_i ; -|v_i|^2-as-coord]
    against query [sqrt(2) q ; 1].  This keeps the reward-list structure (one
    extra coordinate) rather than materializing (q-v)^2.
    """
    V = jnp.asarray(V)
    q = jnp.asarray(q)
    aug_V = jnp.concatenate([jnp.sqrt(2.0) * V,
                             -jnp.sum(V * V, axis=1, keepdims=True)], axis=1)
    aug_q = jnp.concatenate([jnp.sqrt(2.0) * q, jnp.ones((1,), q.dtype)])
    return mips_topk(aug_V, aug_q, K, **kw)


def sharded_mips_topk(table, queries, keys, K: int, *, mesh,
                      model_axis: str = "model",
                      batch_axes=None, n_valid: Optional[int] = None,
                      plan: Optional[BlockedPlan] = None, eps: float = 0.05,
                      delta: float = 0.05, value_range: float = 4.0,
                      tile: int = 8, block: int = 512,
                      final_exact: bool = True,
                      use_pallas: Optional[bool] = None,
                      precision: str = "fp32",
                      pull_mode: str = "row", coord_block: int = 128,
                      quant_err: Optional[float] = None,
                      pq_subdims: int = 8, pq_codes: int = 16):
    """Distributed batched MIPS via shard_map: shard-local bandits, K-merge.

    ``table`` (n, N) is sharded on rows over ``model_axis``; each shard runs
    the *identical* static BoundedME schedule on its n/shards arms (delta
    split across shards by union bound), then only the K local winners +
    scores are all-gathered and the global top-K taken.  Collective traffic
    is O(shards*K) floats per query versus the involuntary O(pulled-bytes)
    replication GSPMD produces for a vocab-sharded gather (measured 54.5 GB
    -> ~100 KB on command-r decode_32k; EXPERIMENTS.md §Perf iteration 1).

    Each shard serves its whole query batch with a single dispatch: one
    batched fused-cascade `pallas_call` on TPU (``use_pallas=None`` =>
    auto), or one vmapped scan program otherwise.

    Args:
      table: (n, N) float arm matrix; n must divide evenly by the
        ``model_axis`` extent (asserted) — use `sharded_bounded_me_decode`
        for ragged tables.
      queries: (B, N) query batch; keys: (B,) per-query PRNG keys (each
        query samples its own block permutation — contrast with the
        shared-permutation decode engine).
      K / eps / delta / value_range / tile / block / final_exact /
        precision / pull_mode / coord_block: as in `mips_topk`; delta is
        split across shards by union bound (each quantized shard plan
        widens its own bounds).  ``precision='int4'``/``'pq'`` work
        shard-locally too (each shard packs/trains in-trace on its own
        rows); 'pq' requires an explicit ``quant_err`` — calibrate with
        `measured_plan_quant_err` on a representative shard, or hand in a
        pre-built ``plan``.  The pull-mode choice is shard-local — each
        shard prices its own (n_local, N) geometry — while the exact
        cross-shard K-merge is untouched by the pull mode.
      mesh / model_axis / batch_axes: device mesh, arm-sharding axis name,
        and optional query-batch sharding axes.
      n_valid: real row count when ``table`` carries padding rows (e.g. a
        padded vocab); padding is masked out of the merge.

    Returns:
      ``(ids (B, K) int32, scores (B, K) f32)``.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    if use_pallas is None:
        from repro.kernels import ops as _kops
        use_pallas = _kops.on_tpu()
    n_shards = mesh.shape[model_axis]
    n, N = table.shape
    assert n % n_shards == 0, (n, n_shards)
    n_local = n // n_shards
    if plan is None:
        plan = make_plan(n_local, N, K=K, eps=eps, delta=delta / n_shards,
                         value_range=value_range, tile=tile, block=block,
                         precision=precision, pull_mode=pull_mode,
                         coord_block=coord_block, quant_err=quant_err,
                         pq_subdims=pq_subdims, pq_codes=pq_codes)

    def local(table_l, q_l, keys_l):
        ids, scores = bounded_me_batched(table_l, q_l, keys_l, plan=plan,
                                         final_exact=final_exact,
                                         use_pallas=use_pallas)  # (B_loc, K)
        shard = jax.lax.axis_index(model_axis)
        gids = ids + shard * n_local
        if n_valid is not None and n_valid < n:
            # vocab-padding rows (zeros) must never win the merge
            scores = jnp.where(gids < n_valid, scores, -jnp.inf)
        all_ids = jax.lax.all_gather(gids, model_axis, axis=1)
        all_sc = jax.lax.all_gather(scores, model_axis, axis=1)
        all_ids = all_ids.reshape(ids.shape[0], -1)
        all_sc = all_sc.reshape(ids.shape[0], -1)
        vals, pos = jax.lax.top_k(all_sc, K)
        return jnp.take_along_axis(all_ids, pos, axis=1), vals

    q_spec = P(batch_axes, None)
    k_spec = P(batch_axes, None)
    out_spec = (P(batch_axes, None), P(batch_axes, None))
    fn = shard_map_compat(local, mesh=mesh,
                          in_specs=(P(model_axis, None), q_spec, k_spec),
                          out_specs=out_spec)
    return fn(table, queries, keys)
