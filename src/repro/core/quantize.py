"""Quantizers for the sampling cascade (DESIGN.md §10): int8, int4, PQ.

The BoundedME sampling rounds only need inner-product *estimates*, so the
pull arithmetic can run at reduced precision provided the per-pull error
is folded into the confidence radii (`make_schedule(quant_err=...)`).
This module holds the codecs every execution path shares:

  * **int8** — the item matrix is quantized **per (R, C) tile** of its
    tile-major layout (one f32 scale per (arm-tile, coordinate-block)
    cell, so a single huge-magnitude row only coarsens its own tile) and
    queries **per coordinate block**.  Worst-case error bound:
    `repro.core.bounds.quantization_error(value_range)`.
  * **int4** — same per-cell symmetric scheme on a 15-level grid, with
    two signed nibbles packed per byte (`pack_int4`/`unpack_int4`), so a
    pulled tile moves HALF the int8 bytes.  Queries stay int8 (W4A8);
    every pull unpacks the nibbles and runs the same exact integer dot.
  * **pq** — per-subspace product quantization: each coordinate block is
    split into ``subdims``-wide slices, a per-(block, subspace) k-means
    codebook (`pq_train`) maps every slice to one of ``n_codes`` uint8
    codes (`pq_encode`), and a pull becomes a query-side LUT build plus
    per-row code lookups (`pq_tile_dot`) — ``C / subdims`` bytes per row
    per pull instead of ``C``.  There is no closed-form error bound;
    callers feed the schedule the **measured** bound below.

`measured_quant_err` calibrates a per-pull (block-mean scale) error bound
for ANY tier by replaying the tier's exact pull arithmetic against
held-out queries and taking the max observed |q·v − q·v̂| / C, inflated by
a safety factor — the measured-vs-worst-case error model of DESIGN.md §10.

Each dequantization uses the identical elementary float ops in the
identical order across the fused kernel and the jnp fallbacks; the shared
helpers `unpack_int4` and `pq_tile_dot` are *called from both paths*, so
the arithmetic cannot drift and the paths stay bit-exact in interpret
mode (tests/test_quantized.py, tests/test_fuzz_cascade.py).

Rounding is deterministic round-half-to-even (`jnp.round`) and k-means
initialization is strided over data order (no RNG), so repeated
quantization of the same table is reproducible across calls and hosts.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["INT8_LEVELS", "INT4_LEVELS", "quantize_tiles", "quantize_blocks",
           "pack_int4", "unpack_int4", "quantize_tiles_int4",
           "dequantize_tiles_int4", "pq_train", "pq_encode", "pq_decode",
           "pq_tile_dot", "measured_quant_err"]

# symmetric signed quantization grids: levels per sign
INT8_LEVELS = 127
INT4_LEVELS = 7


def _scale_of(amax: jnp.ndarray, levels: int = INT8_LEVELS) -> jnp.ndarray:
    """Per-cell scale max|x| / levels; all-zero cells get scale 1 (codes 0)."""
    return jnp.where(amax > 0, amax / levels, 1.0).astype(jnp.float32)


def quantize_tiles(V4: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile symmetric int8 quantization of a tile-major item matrix.

    Args:
      V4: (n_tiles, n_blocks, R, C) float tile-major table
        (`boundedme_jax._tile_major` layout).

    Returns:
      ``(V8 (n_tiles, n_blocks, R, C) int8, vscale (n_tiles, n_blocks)
      f32)`` with ``V4 ~= V8 * vscale[:, :, None, None]`` and per-entry
      reconstruction error at most ``vscale/2`` (round-to-nearest).  The
      scales ride alongside the block permutation into the kernel as a
      VMEM-resident operand.  The current decode paths quantize in-jit
      (once per traced dispatch, O(nN) per flush); hoisting the table
      quantization out of the dispatch is recorded as the next win in
      docs/TUNING.md.
    """
    amax = jnp.max(jnp.abs(V4), axis=(2, 3))
    vscale = _scale_of(amax)
    V8 = jnp.round(V4 / vscale[:, :, None, None])
    V8 = jnp.clip(V8, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8)
    return V8, vscale


def quantize_blocks(qb: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization of blocked queries.

    Args:
      qb: (n_blocks, C) blocked query, or (B, n_blocks, C) blocked batch.

    Returns:
      ``(q8 int8, qscale f32)`` with qscale shaped (n_blocks,) or
      (B, n_blocks) — one scale per coordinate block (per query in the
      batched case), computed at dispatch time (queries arrive per
      request; only the table's scales are precomputed).  Shared by the
      int8 AND int4 table tiers (W4A8: 4-bit weights, 8-bit activations).
    """
    amax = jnp.max(jnp.abs(qb), axis=-1)
    qscale = _scale_of(amax)
    q8 = jnp.round(qb / qscale[..., None])
    q8 = jnp.clip(q8, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8)
    return q8, qscale


# ---------------------------------------------------------------------------
# int4: two signed nibbles per byte (DESIGN.md §10, the W4A8 tier)
# ---------------------------------------------------------------------------


def pack_int4(x8: jnp.ndarray) -> jnp.ndarray:
    """Pack int4-valued int8 codes two-per-byte along the last axis.

    Layout is **half-split**, not interleaved: byte ``k`` of the packed
    array carries column ``k`` of the input in its low nibble and column
    ``k + C/2`` in its high nibble, so `unpack_int4`'s concatenate
    restores natural column order with no lane interleave (the
    TPU-friendly choice — no strided shuffles inside the kernel body).

    Args:
      x8: (..., C) int8 array with values in [-8, 7] and C even.

    Returns:
      (..., C // 2) int8 packed bytes; ``unpack_int4(pack_int4(x)) == x``
      exactly (the round-trip identity tests/test_quantized.py asserts).
    """
    x8 = x8.astype(jnp.int8)
    h = x8.shape[-1] // 2
    lo, hi = x8[..., :h], x8[..., h:]
    return jax.lax.bitwise_or(jax.lax.bitwise_and(lo, jnp.int8(0x0F)),
                              jax.lax.shift_left(hi, jnp.int8(4)))


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """Unpack two-per-byte signed nibbles back to (..., C) int8 codes.

    Exact inverse of `pack_int4`.  Sign extension is pure arithmetic shift (``(p << 4) >> 4`` for the
    low nibble, ``p >> 4`` for the high); this exact function runs inside
    the fused kernel's pull step AND the jnp fallbacks, which is what
    keeps the two paths bit-exact (DESIGN.md §10).
    """
    p = packed.astype(jnp.int8)
    four = jnp.int8(4)
    hi = jax.lax.shift_right_arithmetic(p, four)
    lo = jax.lax.shift_right_arithmetic(jax.lax.shift_left(p, four), four)
    return jnp.concatenate([lo, hi], axis=-1)


def quantize_tiles_int4(V4: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile symmetric int4 quantization, nibble-packed two-per-byte.

    Same per-(arm-tile, coordinate-block) cell scheme as `quantize_tiles`
    on the 15-level int4 grid (scale = max|x| / 7).

    Args:
      V4: (n_tiles, n_blocks, R, C) float tile-major table; C must be
        even (`make_plan` enforces ``block % 2 == 0`` for int4 plans).

    Returns:
      ``(P4 (n_tiles, n_blocks, R, C // 2) int8 packed nibbles, vscale
      (n_tiles, n_blocks) f32)`` — half the int8 shadow's bytes, which is
      the point: per-pull HBM traffic halves again (DESIGN.md §10).
    """
    amax = jnp.max(jnp.abs(V4), axis=(2, 3))
    vscale = _scale_of(amax, INT4_LEVELS)
    Vq = jnp.round(V4 / vscale[:, :, None, None])
    Vq = jnp.clip(Vq, -INT4_LEVELS, INT4_LEVELS).astype(jnp.int8)
    return pack_int4(Vq), vscale


def dequantize_tiles_int4(P4: jnp.ndarray, vscale: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the f32 tile-major table from a packed int4 shadow."""
    return unpack_int4(P4).astype(jnp.float32) * vscale[:, :, None, None]


# ---------------------------------------------------------------------------
# Product quantization: per-(block, subspace) k-means codebooks
# ---------------------------------------------------------------------------


def pq_train(V4: jnp.ndarray, *, n_codes: int = 16, subdims: int = 8,
             iters: int = 8) -> jnp.ndarray:
    """Train per-(coordinate-block, subspace) k-means codebooks.

    Each coordinate block's C columns split into ``S = C / subdims``
    slices; for every (block, slice) pair the rows of the whole table
    (all tiles) form the training set of one ``n_codes``-centroid Lloyd
    k-means.  Deterministic and jax-traceable: strided data-order
    initialization (no RNG), a fixed ``iters`` Lloyd iterations, and
    empty clusters keep their previous centroid — the same input always
    yields the same codebook, on host or in-jit, which the store's
    bit-identity contract relies on (DESIGN.md §11).

    Args:
      V4: (n_tiles, n_blocks, R, C) float tile-major table; C must be a
        multiple of ``subdims`` (`make_plan` enforces it for pq plans).
      n_codes: codebook size (1..256; codes are uint8).
      subdims: subspace width w — smaller w means more subspaces, i.e.
        tighter reconstruction at more bytes per row (the error
        monotonicity tests/test_quantized.py asserts).
      iters: Lloyd iterations (fixed count, so the fn is jit-traceable).

    Returns:
      ``codebook (n_blocks, S, n_codes, subdims) f32`` — the VMEM-resident
      kernel operand (`pq_tile_dot` builds a per-query LUT from it).
    """
    T, Bn, R, C = V4.shape
    w = int(subdims)
    if C % w != 0:
        raise ValueError(f"block width {C} not divisible by subdims {w}")
    if not 1 <= int(n_codes) <= 256:
        raise ValueError(f"n_codes must be in [1, 256], got {n_codes}")
    S = C // w
    n = T * R
    # (Bn, S, n, w): every row-slice of the table, grouped by subspace
    X = (jnp.asarray(V4, jnp.float32).transpose(1, 0, 2, 3)
         .reshape(Bn, n, S, w).transpose(0, 2, 1, 3))
    stride = max(1, n // int(n_codes))
    idx = (jnp.arange(int(n_codes)) * stride) % n   # strided data-order init
    cb = X[:, :, idx, :]                            # (Bn, S, n_codes, w)
    x2 = jnp.sum(X * X, axis=-1)                    # (Bn, S, n)
    for _ in range(int(iters)):
        c2 = jnp.sum(cb * cb, axis=-1)              # (Bn, S, n_codes)
        d = (x2[..., None] - 2.0 * jnp.einsum("bsnw,bskw->bsnk", X, cb)
             + c2[:, :, None, :])
        a = jnp.argmin(d, axis=-1)                  # (Bn, S, n)
        onehot = jax.nn.one_hot(a, int(n_codes), dtype=jnp.float32)
        counts = jnp.sum(onehot, axis=2)            # (Bn, S, n_codes)
        sums = jnp.einsum("bsnk,bsnw->bskw", onehot, X)
        cb = jnp.where(counts[..., None] > 0,
                       sums / jnp.maximum(counts[..., None], 1.0), cb)
    return cb


def pq_encode(V4: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Assign every (row, block, subspace) slice its nearest codeword.

    Per-cell independent (each code depends only on its own row slice and
    the codebook), so re-encoding one dirty arm-tile against a *frozen*
    codebook is bit-identical to encoding the whole updated table — the
    store's dirty-tile contract (DESIGN.md §11).  Ties break to the
    lowest code index (`jnp.argmin` semantics), deterministically.

    Args:
      V4: (n_tiles, n_blocks, R, C) float tile-major table.
      codebook: (n_blocks, S, n_codes, w) from `pq_train` (frozen).

    Returns:
      ``codes (n_tiles, n_blocks, R, S) uint8`` — the kernel's streamed
      table operand: ``S = C / w`` bytes per row per pull.
    """
    T, Bn, R, C = V4.shape
    _, S, n_codes, w = codebook.shape
    X = jnp.asarray(V4, jnp.float32).reshape(T, Bn, R, S, w)
    c2 = jnp.sum(codebook * codebook, axis=-1)        # (Bn, S, n_codes)
    x2 = jnp.sum(X * X, axis=-1)                      # (T, Bn, R, S)
    d = (x2[..., None]
         - 2.0 * jnp.einsum("tbrsw,bskw->tbrsk", X, codebook)
         + c2[None, :, None, :, :])
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def pq_decode(codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the f32 tile-major table v̂ from codes + codebook."""
    T, Bn, R, S = codes.shape
    w = codebook.shape[-1]
    cb_b = jnp.broadcast_to(codebook[None, :, None],
                            (T, Bn, R) + codebook.shape[1:])
    picked = jnp.take_along_axis(
        cb_b, codes[..., None, None].astype(jnp.int32), axis=-2)[..., 0, :]
    return picked.reshape(T, Bn, R, S * w)


def pq_tile_dot(codes: jnp.ndarray, qcol: jnp.ndarray,
                cb: jnp.ndarray) -> jnp.ndarray:
    """The pq pull step: LUT build + per-row code lookups, one block.

    Computes ``out[..., r] = sum_s lut[s, codes[..., r, s]]`` with
    ``lut[s, k] = <qcol slice s, codeword k>`` — the query-vs-codeword
    inner products, built once per pull and shared by every row of the
    tile.  The lookup is a one-hot compare-and-reduce (no gather), so the
    op set is identical inside the Pallas kernel body and the jnp
    fallbacks: both paths call THIS function, which is what keeps them
    bit-exact (DESIGN.md §10).

    Args:
      codes: (..., R, S) uint8 codes of one (tile, block) cell (leading
        axes optional — the fallbacks batch over tiles).
      qcol: (C,) f32 query block, C = S * w.
      cb: (S, n_codes, w) f32 codebook slice of this coordinate block.

    Returns:
      (..., R) f32 partial inner products of this pull.
    """
    S, n_codes, w = cb.shape
    lut = jnp.sum(qcol.reshape(S, 1, w).astype(jnp.float32) * cb,
                  axis=-1)                               # (S, n_codes)
    ks = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_codes), 2)
    onehot = (codes[..., None].astype(jnp.int32) == ks).astype(jnp.float32)
    return jnp.sum(onehot * lut, axis=(-2, -1))


# ---------------------------------------------------------------------------
# Measured error calibration (DESIGN.md §10, measured-vs-worst-case)
# ---------------------------------------------------------------------------


def measured_quant_err(V4: jnp.ndarray, quantized: Tuple, *, precision: str,
                       queries: Optional[jnp.ndarray] = None,
                       n_queries: int = 32, seed: int = 0,
                       safety: float = 2.0) -> float:
    """Measured per-pull inner-product error bound for a quantized tier.

    Replays the tier's EXACT pull arithmetic — including query-side int8
    quantization on the int8/int4 tiers — against calibration queries and
    returns ``safety * max |q·v − q·v̂| / C`` over every (query, tile,
    block) cell and row: a block-mean-scale bias bound that feeds
    ``make_schedule(quant_err=...)`` directly, with NO further CLT
    rescale (the measurement already lives on the block-mean scale).
    The safety factor covers calibration-to-serving distribution shift;
    conservativeness of the inflated bound on fresh query draws is
    asserted empirically by tests/test_guarantees.py (DESIGN.md §10).

    Args:
      V4: (n_tiles, n_blocks, R, C) f32 tile-major reference table.
      quantized: the tier's artifacts — ``(V8, vscale)`` for 'int8',
        ``(P4, vscale)`` (nibble-packed) for 'int4', ``(codes,
        codebook)`` for 'pq'.
      precision: 'int8' | 'int4' | 'pq'.
      queries: optional (n_q, n_blocks, C) calibration query blocks;
        defaults to ``n_queries`` standard-normal draws from ``seed``.
        Pass traffic-shaped queries when you have them — the bound is
        only as representative as its calibration distribution.
      safety: multiplicative inflation of the observed max (default 2.0).

    Returns:
      The inflated bound as a host float (>= 0), on the block-mean scale.
    """
    V4 = jnp.asarray(V4, jnp.float32)
    T, Bn, R, C = V4.shape
    if queries is None:
        queries = jax.random.normal(jax.random.PRNGKey(seed),
                                    (int(n_queries), Bn, C), jnp.float32)
    Qb = jnp.asarray(queries, jnp.float32)
    true = jnp.einsum("tbrc,qbc->qtbr", V4, Qb,
                      preferred_element_type=jnp.float32)
    if precision in ("int8", "int4"):
        Vq, vscale = quantized
        Vi = unpack_int4(Vq) if precision == "int4" else Vq
        q8, qscale = quantize_blocks(Qb)
        raw = jnp.einsum("tbrc,qbc->qtbr", Vi.astype(jnp.int32),
                         q8.astype(jnp.int32))
        scl = vscale[None, :, :, None] * qscale[:, None, :, None]
        est = raw.astype(jnp.float32) * scl
    elif precision == "pq":
        codes, cb = quantized
        _, S, n_codes, w = cb.shape
        lut = jnp.einsum("qbsw,bskw->qbsk",
                         Qb.reshape(Qb.shape[0], Bn, S, w), cb)
        lut_b = jnp.broadcast_to(lut[:, None, :, None],
                                 (Qb.shape[0], T, Bn, R, S, n_codes))
        picked = jnp.take_along_axis(
            lut_b, codes[None, ..., None].astype(jnp.int32),
            axis=-1)[..., 0]
        est = jnp.sum(picked, axis=-1)                   # (q, T, Bn, R)
    else:
        raise ValueError(f"no measured error model for precision "
                         f"{precision!r} (expected 'int8', 'int4' or 'pq')")
    err = float(jnp.max(jnp.abs(true - est))) / float(C)
    return float(safety) * err
