"""Symmetric int8 quantization for the sampling cascade (DESIGN.md §10).

The BoundedME sampling rounds only need inner-product *estimates*, so the
pull arithmetic can run in int8 provided the worst-case quantization error
is folded into the confidence radii (`repro.core.bounds.quantization_error`
-> `make_schedule(quant_err=...)`).  This module holds the quantizers both
execution paths share:

  * the item matrix is quantized **per (R, C) tile** of its tile-major
    layout — one f32 scale per (arm-tile, coordinate-block) cell, so a
    single huge-magnitude row only coarsens its own tile, never the whole
    table;
  * queries are quantized **per coordinate block** — one f32 scale per
    block (per query in the batched case).

Each pull then dequantizes its int32 tile-dot with the *scalar*
``vscale[tile, col] * qscale[col]`` before accumulating in f32; the fused
kernel and the jnp fallback perform the identical elementary float ops in
the identical order, which is what keeps the two paths bit-exact in
interpret mode (tests/test_quantized.py).

Rounding is deterministic round-half-to-even (`jnp.round`) so repeated
quantization of the same table is reproducible across calls and hosts.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["INT8_LEVELS", "quantize_tiles", "quantize_blocks"]

# symmetric signed-int8 quantization grid: 127 levels per sign
INT8_LEVELS = 127


def _scale_of(amax: jnp.ndarray) -> jnp.ndarray:
    """Per-cell scale max|x| / 127; all-zero cells get scale 1 (codes 0)."""
    return jnp.where(amax > 0, amax / INT8_LEVELS, 1.0).astype(jnp.float32)


def quantize_tiles(V4: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile symmetric int8 quantization of a tile-major item matrix.

    Args:
      V4: (n_tiles, n_blocks, R, C) float tile-major table
        (`boundedme_jax._tile_major` layout).

    Returns:
      ``(V8 (n_tiles, n_blocks, R, C) int8, vscale (n_tiles, n_blocks)
      f32)`` with ``V4 ~= V8 * vscale[:, :, None, None]`` and per-entry
      reconstruction error at most ``vscale/2`` (round-to-nearest).  The
      scales ride alongside the block permutation into the kernel as a
      VMEM-resident operand.  The current decode paths quantize in-jit
      (once per traced dispatch, O(nN) per flush); hoisting the table
      quantization out of the dispatch is recorded as the next win in
      docs/TUNING.md.
    """
    amax = jnp.max(jnp.abs(V4), axis=(2, 3))
    vscale = _scale_of(amax)
    V8 = jnp.round(V4 / vscale[:, :, None, None])
    V8 = jnp.clip(V8, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8)
    return V8, vscale


def quantize_blocks(qb: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8 quantization of blocked queries.

    Args:
      qb: (n_blocks, C) blocked query, or (B, n_blocks, C) blocked batch.

    Returns:
      ``(q8 int8, qscale f32)`` with qscale shaped (n_blocks,) or
      (B, n_blocks) — one scale per coordinate block (per query in the
      batched case), computed at dispatch time (queries arrive per
      request; only the table's scales are precomputed).
    """
    amax = jnp.max(jnp.abs(qb), axis=-1)
    qscale = _scale_of(amax)
    q8 = jnp.round(qb / qscale[..., None])
    q8 = jnp.clip(q8, -INT8_LEVELS, INT8_LEVELS).astype(jnp.int8)
    return q8, qscale
