"""Core of the paper: MAB-BP bounds, schedules, BoundedME, MIPS API."""

from repro.core.bounds import (
    rho_m, u_term, m_required, deviation_bound, hoeffding_required,
    lil_required,
)
from repro.core.schedule import Round, Schedule, make_schedule
from repro.core.boundedme import BoundedMEResult, bounded_me, reward_matrix
from repro.core.boundedme_jax import (
    BlockedPlan, make_plan, bounded_me_blocked, bounded_me_batched,
)
from repro.core.mips import mips_topk, nns_topk, sharded_mips_topk, exact_topk
from repro.core.median_elim import median_elimination, successive_elimination
from repro.core.bounded_se import bounded_se

__all__ = [
    "rho_m", "u_term", "m_required", "deviation_bound", "hoeffding_required",
    "lil_required", "Round", "Schedule", "make_schedule", "BoundedMEResult",
    "bounded_me", "reward_matrix", "BlockedPlan", "make_plan",
    "bounded_me_blocked", "bounded_me_batched", "mips_topk", "nns_topk",
    "sharded_mips_topk", "exact_topk", "median_elimination",
    "successive_elimination", "bounded_se",
]
