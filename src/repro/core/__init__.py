"""Core of the paper: MAB-BP bounds, schedules, BoundedME, MIPS API."""

from repro.core.bounds import (
    rho_m, u_term, m_required, deviation_bound, hoeffding_required,
    lil_required, quantization_error,
)
from repro.core.quantize import (
    INT8_LEVELS, quantize_blocks, quantize_tiles,
)
from repro.core.schedule import (
    FlatSchedule, Round, Schedule, flatten_schedule, make_schedule,
)
from repro.core.boundedme import BoundedMEResult, bounded_me, reward_matrix
from repro.core.boundedme_jax import (
    BlockedPlan, make_plan, bounded_me_blocked, bounded_me_batched,
    bounded_me_decode,
)
from repro.core.mips import (
    default_value_range, exact_topk, mips_topk, nns_topk,
    sharded_bounded_me_decode, sharded_mips_topk,
)
from repro.core.median_elim import median_elimination, successive_elimination
from repro.core.bounded_se import bounded_se

__all__ = [
    "rho_m", "u_term", "m_required", "deviation_bound", "hoeffding_required",
    "lil_required", "quantization_error", "INT8_LEVELS", "quantize_blocks",
    "quantize_tiles", "Round", "Schedule", "FlatSchedule", "make_schedule",
    "flatten_schedule", "BoundedMEResult", "bounded_me", "reward_matrix",
    "BlockedPlan", "make_plan", "bounded_me_blocked", "bounded_me_batched",
    "bounded_me_decode", "mips_topk", "nns_topk", "sharded_mips_topk",
    "sharded_bounded_me_decode", "exact_topk", "default_value_range",
    "median_elimination",
    "successive_elimination", "bounded_se",
]
