"""BoundedME, TPU-native: blocked pulls, tile elimination, static schedule.

This is the optimized JAX/Pallas path (DESIGN.md §3).  The elimination
schedule is computed at *trace time* (it is data-independent), so the whole
bandit compiles to a fixed cascade of gather + tile-matmul + top_k ops with
static shapes — jit/pjit/vmap-able and shardable.

Adaptations versus the reference (`repro.core.boundedme`):
  * a pull = one coordinate *block* of ``block`` (default 512) entries,
    computed as an MXU tile-dot; the without-replacement bound applies with
    N -> N//block and block-mean rewards;
  * arms are eliminated in *tiles* of ``tile`` (default 8) rows ranked by
    the tile-max empirical mean (the running empirical argmax always
    survives); the reference path keeps exact per-arm semantics;
  * one shared random block permutation per query (uniform without
    replacement marginally per arm; contiguity for HBM).
"""

from __future__ import annotations

import dataclasses
import math
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import Schedule, make_schedule

__all__ = ["BlockedPlan", "make_plan", "bounded_me_blocked", "bounded_me_batched"]


@dataclasses.dataclass(frozen=True)
class BlockedPlan:
    """Static geometry + schedule for the blocked TPU path."""

    n: int              # true number of arms
    N: int              # true vector dimension
    K: int
    tile: int           # arm-tile rows (elimination granularity)
    block: int          # coordinate-block width (pull granularity)
    n_tiles: int        # padded arm tiles
    n_blocks: int       # padded coordinate blocks
    schedule: Schedule  # over (n_tiles "arms", n_blocks "rewards", K_tiles)

    @property
    def k_tiles(self) -> int:
        # keep K whole tiles: in the worst case each top-K arm sits in its
        # own tile, so ceil(K/tile) tiles could lose true winners
        return min(self.n_tiles, self.K)

    @property
    def total_multiplies(self) -> int:
        """FLOP-level sample complexity of the blocked schedule."""
        per_pull = self.tile * self.block
        return self.schedule.total_pulls * per_pull

    @property
    def naive_multiplies(self) -> int:
        return self.n * self.N

    @property
    def speedup(self) -> float:
        return self.naive_multiplies / max(1, self.total_multiplies)


def make_plan(n: int, N: int, K: int = 1, eps: float = 0.1, delta: float = 0.05,
              value_range: float = 1.0, tile: int = 8, block: int = 512,
              range_mode: str = "clt") -> BlockedPlan:
    """Build the static plan.

    range_mode:
      * 'exact' — block means are bounded by the per-coordinate product range
        (strictly valid, maximally conservative: blocking buys no statistical
        tightening, only MXU efficiency);
      * 'clt' (default) — block means of ``block`` weakly-dependent products
        concentrate ~ range/sqrt(block); the (eps, delta) knob is then
        calibrated on this tighter effective range.  This is a modeling
        assumption (same spirit as the paper's rewards-in-[0,1] assumption)
        and is validated empirically by the fig-1 harness.
    """
    block = min(block, N)
    tile = min(tile, n)
    n_tiles = -(-n // tile)
    n_blocks = -(-N // block)
    k_tiles = min(n_tiles, K)
    if range_mode == "clt":
        eff_range = value_range / math.sqrt(block)
    elif range_mode == "exact":
        eff_range = value_range
    else:
        raise ValueError(f"unknown range_mode {range_mode!r}")
    sched = make_schedule(n_tiles, n_blocks, K=k_tiles, eps=eps, delta=delta,
                          value_range=eff_range)
    return BlockedPlan(n=n, N=N, K=K, tile=tile, block=block, n_tiles=n_tiles,
                       n_blocks=n_blocks, schedule=sched)


def _pad_operands(V: jnp.ndarray, q: jnp.ndarray, plan: BlockedPlan
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-pad to (n_tiles*tile, n_blocks*block).

    Zero coordinate padding rescales every arm's blocked mean by the same
    N/(n_blocks*block) factor — the top-K ranking is unchanged.  Zero arm
    padding is masked out of every top-k via the validity mask.
    """
    n_pad = plan.n_tiles * plan.tile - V.shape[0]
    c_pad = plan.n_blocks * plan.block - V.shape[1]
    if n_pad or c_pad:
        V = jnp.pad(V, ((0, n_pad), (0, c_pad)))
    if c_pad:
        q = jnp.pad(q, (0, c_pad))
    return V, q


@functools.partial(jax.jit, static_argnames=("plan", "final_exact", "use_pallas"))
def _run_blocked(V: jnp.ndarray, q: jnp.ndarray, key: jax.Array, *,
                 plan: BlockedPlan, final_exact: bool = False,
                 use_pallas: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (topk_ids (K,), topk_scores (K,)) — scores are mean products."""
    R, C = plan.tile, plan.block
    V, q = _pad_operands(V, q, plan)
    # tile-major layout: (n_tiles, n_blocks, tile, block)
    V4 = V.reshape(plan.n_tiles, R, plan.n_blocks, C).transpose(0, 2, 1, 3)
    qb = q.reshape(plan.n_blocks, C)
    perm = jax.random.permutation(key, plan.n_blocks)

    arm_ids0 = jnp.arange(plan.n_tiles * R).reshape(plan.n_tiles, R)
    valid0 = (arm_ids0 < plan.n).astype(V.dtype)

    idx = jnp.arange(plan.n_tiles)
    sums = jnp.zeros((plan.n_tiles, R), dtype=jnp.float32)
    t_prev = 0
    neg = jnp.asarray(-jnp.inf, dtype=jnp.float32)

    if use_pallas:
        from repro.kernels import ops as _kops

    for rnd in plan.schedule.rounds:
        if rnd.t_new > 0:
            cols = jax.lax.slice_in_dim(perm, t_prev, rnd.t_cum)  # static slice
            qsel = qb[cols]                                        # (dt, C)
            if use_pallas:
                part = _kops.gather_block_dot(V4, idx, cols, qsel)
            else:
                Vsel = V4[idx[:, None], cols[None, :]]             # (T, dt, R, C)
                part = jnp.einsum("tbrc,bc->tr", Vsel, qsel,
                                  preferred_element_type=jnp.float32)
            sums = sums + part
        t_prev = rnd.t_cum
        means = sums / jnp.float32(t_prev * C)
        valid = valid0[idx]
        tile_score = jnp.where(valid > 0, means, neg).max(axis=1)
        _, keep = jax.lax.top_k(tile_score, rnd.n_keep)            # static size
        idx, sums = idx[keep], sums[keep]

    valid = valid0[idx]
    if final_exact:
        # exact rescore of the few survivors: (T_f*R, N) x (N,)
        Vfin = V4[idx].transpose(0, 2, 1, 3).reshape(idx.shape[0] * R, -1)
        scores = (Vfin @ q).astype(jnp.float32) / jnp.float32(plan.N)
        scores = scores.reshape(idx.shape[0], R)
    else:
        scores = sums / jnp.float32(max(1, t_prev) * C)
    flat = jnp.where(valid > 0, scores, neg).reshape(-1)
    top_vals, top_pos = jax.lax.top_k(flat, plan.K)
    arm_ids = arm_ids0[idx].reshape(-1)[top_pos]
    # undo the zero-padding rescale so scores estimate (q . v)/N
    scale = (plan.n_blocks * C) / plan.N
    return arm_ids, top_vals * jnp.float32(scale)


def bounded_me_blocked(V, q, key, *, K: int = 1, eps: float = 0.1,
                       delta: float = 0.05, value_range: float = 1.0,
                       tile: int = 8, block: int = 512,
                       final_exact: bool = False, use_pallas: bool = False,
                       plan: Optional[BlockedPlan] = None):
    """Top-K MIPS over rows of ``V`` for query ``q`` (single query).

    Returns ``(ids (K,), scores (K,), plan)`` where scores estimate
    ``(q . v)/N``.  All shapes are static; safe under jit/pjit.
    """
    n, N = V.shape
    if plan is None:
        plan = make_plan(n, N, K=K, eps=eps, delta=delta,
                         value_range=value_range, tile=tile, block=block)
    ids, scores = _run_blocked(jnp.asarray(V), jnp.asarray(q), key, plan=plan,
                               final_exact=final_exact, use_pallas=use_pallas)
    return ids, scores, plan


def bounded_me_batched(V, Q, keys, *, plan: BlockedPlan,
                       final_exact: bool = False, use_pallas: bool = False):
    """vmapped BoundedME over a batch of queries ``Q`` (B, N)."""
    fn = functools.partial(_run_blocked, plan=plan, final_exact=final_exact,
                           use_pallas=use_pallas)
    return jax.vmap(fn, in_axes=(None, 0, 0))(jnp.asarray(V), jnp.asarray(Q),
                                              keys)
