"""BoundedME, TPU-native: blocked pulls, tile elimination, static schedule.

This is the optimized JAX/Pallas path (DESIGN.md §3).  The elimination
schedule is computed at *trace time* (it is data-independent), so the whole
bandit compiles to a fixed cascade of gather + tile-matmul + top_k ops with
static shapes — jit/pjit/vmap-able and shardable.

Two execution strategies share the same static plan:

  * ``use_pallas=True`` — the whole cascade (every pull round, every tile
    elimination, the final top-K) runs as ONE fused Pallas kernel
    (`repro.kernels.fused_cascade`): dispatch count per query is 1
    regardless of round count, and the accumulator/survivor state stays
    on-chip across rounds;
  * ``use_pallas=False`` — a pure-jnp fallback that walks the same
    flattened schedule with a `lax.scan` over each round's coordinate
    blocks.  It gathers one (T, R, C) slab per block and never materializes
    the old (T, dt, R, C) per-round gather.

Adaptations versus the reference (`repro.core.boundedme`):
  * a pull = one coordinate *block* of ``block`` (default 512) entries,
    computed as an MXU tile-dot; the without-replacement bound applies with
    N -> N//block and block-mean rewards;
  * arms are eliminated in *tiles* of ``tile`` (default 8) rows ranked by
    the tile-max empirical mean (the running empirical argmax always
    survives); the reference path keeps exact per-arm semantics;
  * one shared random block permutation per query (uniform without
    replacement marginally per arm; contiguity for HBM).

``precision='int8'`` (DESIGN.md §10) runs every sampling round in int8:
the table is quantized per (tile, block) cell (`repro.core.quantize`),
pulls run int8 x int8 -> int32 and dequantize into the f32 accumulator,
and the schedule's confidence radii are widened by the worst-case
quantization bias (`make_schedule(quant_err=...)`) so the (eps, delta)
calibration survives.  ``precision='int4'`` halves the pulled bytes again
(nibble-packed tiles, W4A8 dots under the 15-level worst-case bias), and
``precision='pq'`` replaces scalar codes with per-subspace product
quantization (uint8 codes + LUT tile-dots, `pq_subdims` bytes -> 1): pq
has no closed-form bias bound, so its plans carry the **measured**
per-pull error (`measured_plan_quant_err` / `make_measured_plan`),
inflated by a safety factor, into ``make_schedule(quant_err=...)`` —
``Schedule.eps_effective`` stays honest either way.  The final top-K
candidates are always rescored in fp32 against the unquantized table when
``final_exact=True``, so returned scores carry no quantization error at
all, on every tier.
"""

from __future__ import annotations

import dataclasses
import math
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bounds
from repro.core.quantize import (measured_quant_err, pq_encode, pq_tile_dot,
                                 pq_train, quantize_blocks, quantize_tiles,
                                 quantize_tiles_int4, unpack_int4)
from repro.core.schedule import (Schedule, cert_coeffs, flatten_schedule,
                                 make_schedule)

__all__ = ["BlockedPlan", "make_plan", "choose_pull_mode",
           "measured_plan_quant_err", "make_measured_plan",
           "bounded_me_blocked", "bounded_me_batched", "bounded_me_decode"]


@dataclasses.dataclass(frozen=True)
class BlockedPlan:
    """Static geometry + schedule for the blocked TPU path."""

    n: int              # true number of arms
    N: int              # true vector dimension
    K: int
    tile: int           # arm-tile rows (elimination granularity)
    block: int          # coordinate-block width (pull granularity)
    n_tiles: int        # padded arm tiles
    n_blocks: int       # padded coordinate blocks
    schedule: Schedule  # over (n_tiles "arms", n_blocks "rewards", K_tiles)
    precision: str = "fp32"   # sampling arithmetic:
    #                           'fp32' | 'int8' | 'int4' | 'pq' (§10)
    pull_mode: str = "row"    # resolved reward stream: 'row' | 'coord' (§14)
    pq_subdims: int = 8       # pq subspace width w (codes per row = block/w)
    pq_codes: int = 16        # pq codebook size (uint8 codes, <= 256)

    @property
    def k_tiles(self) -> int:
        """Arm tiles that must survive to the final round: min(n_tiles, K).

        In the worst case each of the top-K arms sits in its own tile, so
        min(n_tiles, K) tiles must survive to the end (ceil(K/tile) would
        lose winners under adversarial placement).
        """
        return min(self.n_tiles, self.K)

    @property
    def k_out_cap(self) -> int:
        """Widest final extraction the cascade supports (`k_out` upper bound).

        The final top-K scans the ``n_final`` surviving tiles, i.e.
        ``n_final * tile`` candidate rows; no more than that many candidates
        exist to extract (padding rows included — callers mask those).
        """
        n_final = (self.schedule.rounds[-1].n_keep if self.schedule.rounds
                   else self.n_tiles)
        return n_final * self.tile

    @property
    def quant_err(self) -> float:
        """Per-block-mean quantization bias the schedule absorbs (0 = fp32)."""
        return self.schedule.quant_err

    @property
    def eps_effective(self) -> float:
        """Honest end-to-end eps bound incl. quantization (== eps at fp32).

        See `Schedule.eps_effective` and DESIGN.md §10: rounds whose
        budget absorbs the int8 bias stay eps_l-correct; saturated rounds
        contribute at most ``2 * quant_err`` each.
        """
        return self.schedule.eps_effective

    @property
    def total_multiplies(self) -> int:
        """FLOP-level sample complexity of the blocked schedule."""
        per_pull = self.tile * self.block
        return self.schedule.total_pulls * per_pull

    @property
    def naive_multiplies(self) -> int:
        """FLOPs of the exhaustive (n x N) matvec baseline."""
        return self.n * self.N

    @property
    def speedup(self) -> float:
        """FLOP-level speedup of the blocked schedule over exhaustive."""
        return self.naive_multiplies / max(1, self.total_multiplies)


def choose_pull_mode(row_plan: BlockedPlan, coord_plan: BlockedPlan, *,
                     row_margin: float = 0.10) -> str:
    """The hybrid dispatcher's decision rule (DESIGN.md §14, TUNING.md).

    Given the two fully priced candidate plans for the same
    ``(n, d, K, eps, delta)`` query geometry, returns ``'row'`` or
    ``'coord'`` — whichever plan's certified ``total_multiplies`` (the
    width-weighted cost `Schedule.total_coords` times the arm-tile rows)
    is cheaper.  Row pulls are wider MXU tile-dots with better hardware
    utilization per multiply, so row mode is preferred whenever it is
    within ``row_margin`` (default 10%) of the coordinate plan; coord
    mode must beat row by more than the margin to win.  By construction
    the hybrid plan is therefore never more than ``row_margin`` worse
    than the better single mode — in multiplies, before hardware
    effects that favor the row shape further.
    """
    if not 0.0 <= row_margin:
        raise ValueError(f"row_margin must be >= 0, got {row_margin}")
    row_cost = row_plan.total_multiplies
    coord_cost = coord_plan.total_multiplies
    return "row" if row_cost <= coord_cost * (1.0 + row_margin) else "coord"


def make_plan(n: int, N: int, K: int = 1, eps: float = 0.1, delta: float = 0.05,
              value_range: float = 1.0, tile: int = 8, block: int = 512,
              range_mode: str = "clt",
              precision: str = "fp32",
              bound: str = "hoeffding",
              pull_mode: str = "row",
              coord_block: int = 128,
              quant_err: Optional[float] = None,
              pq_subdims: int = 8,
              pq_codes: int = 16) -> BlockedPlan:
    """Build the static plan.

    pull_mode:
      * 'row' (default) — pulls sample whole feature blocks of width
        ``min(block, N)`` per arm tile; per-pull cost grows with d until
        the block cap.
      * 'coord' — the BanditMIPS coordinate estimator (DESIGN.md §14):
        pulls sample *narrow* feature blocks of width ``min(coord_block,
        N)`` without replacement under a shared per-query permutation,
        so the schedule's reward population is ``n_blocks = ceil(N /
        coord_block)`` and the certified pull cost becomes sublinear in
        d.  Same kernel, same bounds — only the block geometry changes.
      * 'hybrid' — prices BOTH candidate plans and returns the cheaper
        by `choose_pull_mode` (row preferred within a 10% multiply
        margin, since row pulls are wider MXU tile-dots); the returned
        plan's ``pull_mode`` is the resolved concrete mode.

    range_mode:
      * 'exact' — block means are bounded by the per-coordinate product range
        (strictly valid, maximally conservative: blocking buys no statistical
        tightening, only MXU efficiency);
      * 'clt' (default) — block means of ``block`` weakly-dependent products
        concentrate ~ range/sqrt(block); the (eps, delta) knob is then
        calibrated on this tighter effective range.  This is a modeling
        assumption (same spirit as the paper's rewards-in-[0,1] assumption)
        and is validated empirically by the fig-1 harness.

    precision:
      * 'fp32' (default) — sampling rounds pull fp32 tiles;
      * 'int8' — sampling rounds pull int8-quantized tiles and the
        schedule's confidence radii are widened by the worst-case
        quantization bias (`bounds.quantization_error`, scaled like the
        value range under ``range_mode``), so the (eps, delta) calibration
        survives quantization (DESIGN.md §10).  Final candidates are
        rescored in fp32 whenever ``final_exact=True``.
      * 'int4' — nibble-packed tiles (half the int8 bytes per pull) under
        the 15-level worst-case bias by default; ``block`` must be even.
      * 'pq' — per-subspace product quantization (``block / pq_subdims``
        bytes per row per pull).  No closed-form bias exists, so a
        **measured** ``quant_err`` is REQUIRED — pass the output of
        `measured_plan_quant_err`, or build the plan with
        `make_measured_plan` which calibrates it for you.

    quant_err:
      Explicit per-pull bias bound on the block-mean scale (what
      `measured_quant_err` returns).  When given it feeds
      ``make_schedule(quant_err=...)`` as-is — NO ``range_mode`` rescale,
      the measurement already lives on the block-mean scale — and
      overrides the tier's worst-case default.  The measured-vs-worst-case
      trade is DESIGN.md §10: measured bounds are far tighter (so rounds
      keep their full deviation budget) but only as representative as the
      calibration queries; the safety factor covers the gap.

    bound:
      * 'hoeffding' (default) — the adaptive path certifies early exit
        with the schedule's own Hoeffding–Serfling radii (zero extra delta
        cost; the round plan is identical to the non-adaptive one);
      * 'bernstein' — certification uses the variance-aware empirical
        Bernstein–Serfling radii with per-tile running mean/M2
        accumulators (`repro.core.schedule.cert_coeffs`, DESIGN.md §12).
    """
    if pull_mode == "hybrid":
        kwargs = dict(K=K, eps=eps, delta=delta, value_range=value_range,
                      tile=tile, range_mode=range_mode, precision=precision,
                      bound=bound, coord_block=coord_block,
                      quant_err=quant_err, pq_subdims=pq_subdims,
                      pq_codes=pq_codes)
        row_plan = make_plan(n, N, block=block, pull_mode="row", **kwargs)
        coord_plan = make_plan(n, N, block=block, pull_mode="coord", **kwargs)
        winner = choose_pull_mode(row_plan, coord_plan)
        return row_plan if winner == "row" else coord_plan
    if pull_mode == "coord":
        if coord_block < 1:
            raise ValueError(f"coord_block must be >= 1, got {coord_block}")
        block = coord_block       # narrow feature tiles: N becomes d_blocks
    elif pull_mode != "row":
        raise ValueError(f"unknown pull_mode {pull_mode!r} "
                         f"(expected 'row', 'coord' or 'hybrid')")
    block = min(block, N)
    tile = min(tile, n)
    n_tiles = -(-n // tile)
    n_blocks = -(-N // block)
    k_tiles = min(n_tiles, K)
    if precision not in ("fp32", "int8", "int4", "pq"):
        raise ValueError(f"unknown precision {precision!r} "
                         f"(expected 'fp32', 'int8', 'int4' or 'pq')")
    if precision == "int4" and block % 2 != 0:
        raise ValueError(f"precision='int4' needs an even pull width to "
                         f"nibble-pack, got block={block}")
    if precision == "pq":
        if not 1 <= pq_subdims or block % pq_subdims != 0:
            raise ValueError(f"precision='pq' needs pull width divisible "
                             f"by pq_subdims, got block={block}, "
                             f"pq_subdims={pq_subdims}")
        if not 1 <= pq_codes <= 256:
            raise ValueError(f"pq_codes must be in [1, 256], got {pq_codes}")
        if quant_err is None:
            raise ValueError(
                "precision='pq' has no closed-form error bound: pass "
                "quant_err=measured_plan_quant_err(V, precision='pq', ...) "
                "or build the plan with make_measured_plan(V, ...)")
    if quant_err is not None:
        if quant_err < 0:
            raise ValueError(f"quant_err must be >= 0, got {quant_err}")
        qerr = float(quant_err)  # measured, already on the block-mean scale
    elif precision in ("int8", "int4"):
        qerr = bounds.quantization_error(value_range,
                                         bits=8 if precision == "int8" else 4)
    else:
        qerr = 0.0
    if range_mode == "clt":
        eff_range = value_range / math.sqrt(block)
        if quant_err is None:
            qerr = qerr / math.sqrt(block)   # the bias concentrates like the
            # products themselves: rounding errors are weakly dependent across
            # the block, so the block-mean bias shrinks ~ 1/sqrt(block) under
            # the same modeling assumption as eff_range.  A measured qerr is
            # NOT rescaled: it is already a block-mean quantity.
    elif range_mode == "exact":
        eff_range = value_range
    else:
        raise ValueError(f"unknown range_mode {range_mode!r}")
    sched = make_schedule(n_tiles, n_blocks, K=k_tiles, eps=eps, delta=delta,
                          value_range=eff_range, quant_err=qerr, bound=bound,
                          pull_mode=pull_mode, pull_width=block)
    return BlockedPlan(n=n, N=N, K=K, tile=tile, block=block, n_tiles=n_tiles,
                       n_blocks=n_blocks, schedule=sched, precision=precision,
                       pull_mode=pull_mode, pq_subdims=pq_subdims,
                       pq_codes=pq_codes)


def _pad_operands(V: jnp.ndarray, q: jnp.ndarray, plan: BlockedPlan
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Zero-pad to (n_tiles*tile, n_blocks*block).

    Zero coordinate padding rescales every arm's blocked mean by the same
    N/(n_blocks*block) factor — the top-K ranking is unchanged.  Zero arm
    padding is masked out of every top-k via the validity mask.
    """
    n_pad = plan.n_tiles * plan.tile - V.shape[0]
    c_pad = plan.n_blocks * plan.block - V.shape[-1]
    if n_pad or c_pad:
        V = jnp.pad(V, ((0, n_pad), (0, c_pad)))
    if c_pad:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, c_pad)])
    return V, q


def _tile_major(V: jnp.ndarray, plan: BlockedPlan) -> jnp.ndarray:
    """(n_tiles*R, n_blocks*C) -> (n_tiles, n_blocks, R, C)."""
    R, C = plan.tile, plan.block
    return V.reshape(plan.n_tiles, R, plan.n_blocks, C).transpose(0, 2, 1, 3)


def _quantize_table(V4: jnp.ndarray, plan: BlockedPlan) -> Tuple:
    """The plan's table artifacts ``(Vq, vaux)`` (DESIGN.md §10).

    ``(V8, vscale)`` for int8, ``(P4 packed, vscale)`` for int4,
    ``(codes, codebook)`` for pq (codebook trained in-trace — the
    deterministic `pq_train`, so repeated calls agree bit-for-bit).
    Jit-traceable; the decode path skips it when a store hands in its
    incrementally maintained shadow instead.
    """
    if plan.precision == "int8":
        return quantize_tiles(V4)
    if plan.precision == "int4":
        return quantize_tiles_int4(V4)
    if plan.precision == "pq":
        cb = pq_train(V4, n_codes=plan.pq_codes, subdims=plan.pq_subdims)
        return pq_encode(V4, cb), cb
    raise ValueError(f"no table quantizer for precision {plan.precision!r}")


def measured_plan_quant_err(V, *, precision: str, tile: int = 8,
                            block: int = 512, pq_subdims: int = 8,
                            pq_codes: int = 16, n_queries: int = 32,
                            seed: int = 0, safety: float = 2.0) -> float:
    """Calibrate the measured per-pull error bound for a (table, geometry).

    Pads and tiles ``V`` exactly as the cascade will (``block`` is the
    EFFECTIVE pull width — pass ``coord_block`` when calibrating a coord
    plan), builds the tier's quantized artifacts, and returns
    `repro.core.quantize.measured_quant_err` over ``n_queries``
    calibration draws: the ``quant_err=`` value `make_plan` feeds to
    ``make_schedule`` (DESIGN.md §10, measured-vs-worst-case).

    Args:
      V: (n, N) item matrix (host or device array).
      precision: 'int8' | 'int4' | 'pq'.
      safety: multiplicative inflation of the observed max error
        (default 2.0) — the conservativeness margin
        tests/test_guarantees.py checks empirically.
    """
    V = jnp.asarray(V, jnp.float32)
    n, N = V.shape
    block = min(block, N)
    if precision == "int4" and block % 2 != 0:
        raise ValueError(f"precision='int4' needs an even pull width, "
                         f"got block={block}")
    if precision == "pq" and block % pq_subdims != 0:
        raise ValueError(f"precision='pq' needs pull width divisible by "
                         f"pq_subdims, got block={block}, "
                         f"pq_subdims={pq_subdims}")
    # geometry-only fp32 plan: same padding and tiling as the real one
    geo = make_plan(n, N, tile=tile, block=block, precision="fp32")
    Vp, _ = _pad_operands(V, jnp.zeros((N,), jnp.float32), geo)
    V4 = _tile_major(Vp, geo)
    if precision == "int8":
        quant = quantize_tiles(V4)
    elif precision == "int4":
        quant = quantize_tiles_int4(V4)
    elif precision == "pq":
        cb = pq_train(V4, n_codes=pq_codes, subdims=pq_subdims)
        quant = (pq_encode(V4, cb), cb)
    else:
        raise ValueError(f"no measured error model for precision "
                         f"{precision!r} (expected 'int8', 'int4' or 'pq')")
    return measured_quant_err(V4, quant, precision=precision,
                              n_queries=n_queries, seed=seed, safety=safety)


def make_measured_plan(V, K: int = 1, eps: float = 0.1, delta: float = 0.05,
                       value_range: float = 1.0, tile: int = 8,
                       block: int = 512, range_mode: str = "clt",
                       precision: str = "pq", bound: str = "hoeffding",
                       pull_mode: str = "row", coord_block: int = 128,
                       pq_subdims: int = 8, pq_codes: int = 16,
                       n_queries: int = 32, seed: int = 0,
                       safety: float = 2.0) -> BlockedPlan:
    """`make_plan` with a measured (not worst-case) quantization bias.

    Calibrates `measured_plan_quant_err` on ``V`` at the plan's actual
    pull width and passes it as ``quant_err`` — the required entry point
    for ``precision='pq'`` and the tighter-bounds option for
    'int8'/'int4' (DESIGN.md §10).  ``pull_mode='hybrid'`` measures the
    error at EACH candidate width (row pulls see ``block``-wide slices,
    coord pulls ``coord_block``-wide — different codebooks, different
    bias), prices both plans with their own measured bound, and keeps the
    `choose_pull_mode` winner.
    """
    n, N = jnp.asarray(V).shape
    if precision == "fp32":
        raise ValueError("precision='fp32' has no quantization error to "
                         "measure; use make_plan")
    kwargs = dict(K=K, eps=eps, delta=delta, value_range=value_range,
                  tile=tile, block=block, range_mode=range_mode,
                  precision=precision, bound=bound, coord_block=coord_block,
                  pq_subdims=pq_subdims, pq_codes=pq_codes)
    if pull_mode == "hybrid":
        mkwargs = dict(kwargs, n_queries=n_queries, seed=seed, safety=safety)
        row_plan = make_measured_plan(V, pull_mode="row", **mkwargs)
        coord_plan = make_measured_plan(V, pull_mode="coord", **mkwargs)
        winner = choose_pull_mode(row_plan, coord_plan)
        return row_plan if winner == "row" else coord_plan
    width = coord_block if pull_mode == "coord" else block
    qerr = measured_plan_quant_err(V, precision=precision, tile=tile,
                                   block=width, pq_subdims=pq_subdims,
                                   pq_codes=pq_codes, n_queries=n_queries,
                                   seed=seed, safety=safety)
    return make_plan(n, N, pull_mode=pull_mode, quant_err=qerr, **kwargs)


def _fused_call(V4, qb_or_Qb, perm_or_perms, *, plan: BlockedPlan,
                final_exact: bool, batched: bool, k_out: Optional[int] = None,
                n_valid=None, vscale=None, qscale=None, codebook=None,
                adaptive: bool = False):
    """Dispatch the whole cascade as exactly one Pallas kernel launch.

    On the quantized tiers (``vscale``/``qscale`` for int8/int4,
    ``codebook`` for pq) ``final_exact`` never appends coverage steps:
    exactness comes from the caller's fp32 candidate rescore instead of
    in-kernel coverage completion, so the flat schedule stays at the
    sampling pull count (DESIGN.md §10).  The adaptive path (DESIGN.md
    §12) does the same — coverage steps can't be skipped by a mid-flight
    certification, so exactness always comes from the candidate rescore —
    and passes the per-round certification coefficients; the kernel then
    returns a third ``rounds_used`` output.  ``plan.precision='int4'``
    ships the table nibble-packed (last dim C/2) with
    ``packed_int4=True``; the kernel unpacks inside the pull step.
    """
    from repro.kernels import ops as _kops

    quantized = plan.precision != "fp32"
    flat = flatten_schedule(
        plan.schedule,
        final_coverage=final_exact and not quantized and not adaptive)
    slotcode, rmeta = flat.packed()
    bpos = jnp.asarray(flat.bpos)
    fn = _kops.fused_cascade_batched if batched else _kops.fused_cascade
    cols = perm_or_perms[..., bpos] if batched else perm_or_perms[bpos]
    cert = (jnp.asarray(cert_coeffs(plan.schedule)) if adaptive else None)
    return fn(V4, qb_or_Qb, jnp.asarray(slotcode), jnp.asarray(rmeta), cols,
              n_arms=plan.n, K=plan.K, t_final=flat.t_final,
              n_final=flat.n_final, k_out=k_out, n_valid=n_valid,
              vscale=vscale, qscale=qscale, codebook=codebook,
              packed_int4=plan.precision == "int4", cert=cert, k_cert=plan.K,
              track_var=adaptive and plan.schedule.bound == "bernstein")


def _scan_pulls(sums, V4, qb, idx, cols, vscale=None, qscale=None,
                sums2=None, codebook=None, packed_int4=False):
    """One round of pulls as a scan over its coordinate blocks.

    Gathers a single (T, R, C) slab per block — the (T, dt, R, C) gather of
    the pre-fused implementation never exists.  Accumulation order (blocks
    in permutation order) matches the fused kernel's grid order, which is
    what keeps the two paths bitwise-comparable in interpret mode.

    With ``vscale``/``qscale`` (int8/int4 operands, DESIGN.md §10) each
    block's tile-dot runs int8 x int8 -> int32 — exact — and is
    dequantized with the same scalar product and the same two float ops
    per entry as the fused kernel's pull step, preserving bitwise parity;
    ``packed_int4`` first sign-extends the nibbles with the SAME
    `unpack_int4` the kernel calls.  With ``codebook`` (pq) the slab
    holds uint8 codes, ``qb`` stays f32, and the block-dot is the shared
    `pq_tile_dot` LUT walk — again literally the kernel's function.

    With ``sums2`` (the adaptive 'bernstein' path, DESIGN.md §12) a
    running sum of squared block-dots rides along — the same ``part *
    part`` elementwise product the kernel accumulates — and the function
    returns ``(sums, sums2)`` instead of ``sums``.
    """
    quantized = vscale is not None
    track = sums2 is not None

    def body(carry, col):
        s = carry[0] if track else carry
        if codebook is not None:
            part = pq_tile_dot(V4[idx, col], qb[col], codebook[col])
        elif quantized:
            slab = V4[idx, col]
            if packed_int4:
                slab = unpack_int4(slab)
            raw = jnp.einsum("trc,c->tr", slab, qb[col],
                             preferred_element_type=jnp.int32)
            scl = vscale[idx, col] * qscale[col]            # (T,)
            part = raw.astype(jnp.float32) * scl[:, None]
        else:
            part = jnp.einsum("trc,c->tr", V4[idx, col], qb[col],
                              preferred_element_type=jnp.float32)
        if track:
            return (s + part, carry[1] + part * part), None
        return s + part, None

    out, _ = jax.lax.scan(body, (sums, sums2) if track else sums, cols)
    return out


def _cert_fire(mu, rad, valid, K):
    """Certification predicate of the adaptive early exit (DESIGN.md §12).

    ``mu``/``rad``/``valid``: (..., T, R) post-elimination survivor means,
    radii and validity masks.  Fires (True) when the top-``K``-by-mean
    valid rows' lower bounds ``mu - rad`` clear every other valid row's
    upper bound ``mu + rad`` — on the confidence event those K rows' true
    means then dominate every other survivor's, so the eventual top-K
    extraction is already certified (suboptimality 0 <= eps).  With fewer
    than K valid rows the comparison set is empty and the predicate fires
    trivially (`-inf >= -inf`).  Row enumeration order matches the
    kernel's slot-major certification buffers, so tie-breaks agree
    bitwise.
    """
    neg = jnp.float32(-jnp.inf)
    lead = mu.shape[:-2]
    bufM = jnp.where(valid, mu, neg).reshape(*lead, -1)
    bufU = jnp.where(valid, mu + rad, neg).reshape(*lead, -1)
    bufL = jnp.where(valid, mu - rad, neg).reshape(*lead, -1)
    _, pos = jax.lax.top_k(bufM, K)
    minlb = jnp.min(jnp.take_along_axis(bufL, pos, axis=-1), axis=-1)
    if lead:
        bufU = bufU.at[jnp.arange(lead[0])[:, None], pos].set(neg)
    else:
        bufU = bufU.at[pos].set(neg)
    return minlb >= jnp.max(bufU, axis=-1)


def _cert_update(mu, v, valid, cert, l, t_cum, K, active, rounds_used,
                 t_stop):
    """One round-boundary certification step of the jnp fallbacks.

    Evaluates the per-row radius ``a_l sqrt(max(v, 0)) + b_l`` (``v`` is
    None on the variance-free 'hoeffding' family), runs `_cert_fire` over
    the post-elimination survivors, and advances the per-query
    ``(active, rounds_used, t_stop)`` state — the same bookkeeping the
    fused kernel's ``_certify`` block performs in SMEM.  Shared by
    `_run_blocked` (scalar state) and `_run_decode` ((B,) state); the ops
    are rank-polymorphic, which keeps both paths bitwise-identical to the
    kernel.
    """
    if v is not None:
        rad = (jnp.float32(cert[l, 0]) * jnp.sqrt(jnp.maximum(v, 0.0))
               + jnp.float32(cert[l, 1]))
    else:
        rad = jnp.full_like(mu, jnp.float32(cert[l, 1]))
    fire = _cert_fire(mu, rad, valid, K)
    fire_now = jnp.logical_and(active, fire)
    rounds_used = jnp.where(fire_now, l + 1, rounds_used)
    t_stop = jnp.where(fire_now, t_cum, t_stop)
    active = jnp.logical_and(active, jnp.logical_not(fire))
    return active, rounds_used, t_stop


def _rescore_rows(Vp, Qp, ids, n_valid, *, plan: BlockedPlan, batched: bool):
    """fp32-exact rescore + descending re-sort of cascade candidates (§10).

    ``Vp``/``Qp`` are the zero-padded operands, so each gathered row's
    inner product equals the unpadded one and dividing by the true ``N``
    lands directly on (q . v)/N — no padding rescale needed.  Rows at or
    past ``n_valid`` (tile/caller padding the masked extraction may emit
    as filler) are pinned to -inf so they can never re-enter the top-K.
    """
    neg = jnp.float32(-jnp.inf)
    safe = jnp.clip(ids, 0, Vp.shape[0] - 1)
    if batched:
        scores = jnp.einsum("bkc,bc->bk", Vp[safe], Qp,
                            preferred_element_type=jnp.float32)
    else:
        scores = jnp.dot(Vp[safe], Qp, preferred_element_type=jnp.float32)
    scores = jnp.where(ids < n_valid, scores / jnp.float32(plan.N), neg)
    vals, pos = jax.lax.top_k(scores, ids.shape[-1])
    ids = (jnp.take_along_axis(ids, pos, axis=-1) if batched
           else ids[pos])
    return ids, vals


@functools.partial(jax.jit, static_argnames=("plan", "final_exact",
                                             "use_pallas", "adaptive"))
def _run_blocked(V: jnp.ndarray, q: jnp.ndarray, key: jax.Array, *,
                 plan: BlockedPlan, final_exact: bool = False,
                 use_pallas: bool = False,
                 adaptive: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (topk_ids (K,), topk_scores (K,)) — scores are mean products.

    With ``adaptive`` a third output ``rounds_used`` (int32 scalar) rides
    along and pulls freeze at the first certified round (DESIGN.md §12).
    """
    R, C = plan.tile, plan.block
    V, q = _pad_operands(jnp.asarray(V), jnp.asarray(q), plan)
    V4 = _tile_major(V, plan)
    qb = q.reshape(plan.n_blocks, C)
    perm = jax.random.permutation(key, plan.n_blocks)
    # undo the zero-padding rescale so scores estimate (q . v)/N
    scale = (plan.n_blocks * C) / plan.N
    quantized = plan.precision != "fp32"
    track_var = adaptive and plan.schedule.bound == "bernstein"
    if quantized:
        Vq, vaux = _quantize_table(V4, plan)
        if plan.precision == "pq":          # pq queries stay f32 (LUT walk)
            q_in, vscale, qscale, codebook = qb, None, None, vaux
        else:
            q_in, qscale = quantize_blocks(qb)
            vscale, codebook = vaux, None

    if use_pallas:
        rounds_used = None
        if quantized:
            out = _fused_call(Vq, q_in, perm, plan=plan,
                              final_exact=final_exact, batched=False,
                              vscale=vscale, qscale=qscale,
                              codebook=codebook, adaptive=adaptive)
        else:
            out = _fused_call(V4, qb, perm, plan=plan,
                              final_exact=final_exact, batched=False,
                              adaptive=adaptive)
        if adaptive:
            ids, vals, rounds_used = out
        else:
            ids, vals = out
        if final_exact and (quantized or adaptive):
            ids, vals = _rescore_rows(V, q, ids, plan.n, plan=plan,
                                      batched=False)
        else:
            vals = vals * jnp.float32(scale)
        return (ids, vals, rounds_used) if adaptive else (ids, vals)

    arm_ids0 = jnp.arange(plan.n_tiles * R).reshape(plan.n_tiles, R)
    valid0 = (arm_ids0 < plan.n).astype(jnp.float32)

    idx = jnp.arange(plan.n_tiles)
    sums = jnp.zeros((plan.n_tiles, R), dtype=jnp.float32)
    sums2 = jnp.zeros_like(sums) if track_var else None
    t_prev = 0
    neg = jnp.asarray(-jnp.inf, dtype=jnp.float32)
    n_rounds = len(plan.schedule.rounds)
    if adaptive:
        cert = cert_coeffs(plan.schedule)
        t_last = plan.schedule.rounds[-1].t_cum if n_rounds else 0
        active = jnp.asarray(True)
        t_stop = jnp.asarray(t_last, jnp.int32)
        rounds_used = jnp.asarray(n_rounds, jnp.int32)

    for l, rnd in enumerate(plan.schedule.rounds):
        if rnd.t_new > 0:
            cols = jax.lax.slice_in_dim(perm, t_prev, rnd.t_cum)  # static
            if quantized:
                new = _scan_pulls(sums, Vq, q_in, idx, cols, vscale, qscale,
                                  sums2=sums2, codebook=codebook,
                                  packed_int4=plan.precision == "int4")
            else:
                new = _scan_pulls(sums, V4, qb, idx, cols, sums2=sums2)
            if track_var:
                new, new2 = new
                sums2 = jnp.where(active, new2, sums2)
            if adaptive:   # a certified query's accumulator stays frozen
                sums = jnp.where(active, new, sums)
            else:
                sums = new
        t_prev = rnd.t_cum
        denom = jnp.float32(t_prev * C)
        means = sums / denom
        valid = valid0[idx]
        tile_score = jnp.where(valid > 0, means, neg).max(axis=1)
        _, keep = jax.lax.top_k(tile_score, rnd.n_keep)            # static
        idx, sums = idx[keep], sums[keep]
        if track_var:
            sums2 = sums2[keep]
        if adaptive:
            mu = sums / denom
            v = (sums2 / (denom * jnp.float32(C)) - mu * mu
                 if track_var else None)
            active, rounds_used, t_stop = _cert_update(
                mu, v, valid0[idx] > 0, cert, l, rnd.t_cum, plan.K,
                active, rounds_used, t_stop)

    valid = valid0[idx]
    if final_exact and not quantized and not adaptive:
        # exact rescore of the few survivors: (T_f*R, N') x (N',); divide by
        # the padded width N' = n_blocks*C so the caller-side rescale by
        # N'/N lands on (q . v)/N (dividing by N here double-counted the
        # rescale whenever N % block != 0)
        Vfin = V4[idx].transpose(0, 2, 1, 3).reshape(idx.shape[0] * R, -1)
        scores = (Vfin @ q).astype(jnp.float32) / jnp.float32(
            plan.n_blocks * C)
        scores = scores.reshape(idx.shape[0], R)
    elif adaptive:
        # normalize by the query's ACTUAL pull count (frozen at t_stop)
        scores = sums / (jnp.maximum(t_stop, 1) * C).astype(jnp.float32)
    else:
        # int8 + final_exact rescoring happens on the candidates below —
        # coverage completion in int8 would still carry quantization bias
        scores = sums / jnp.float32(max(1, t_prev) * C)
    flat = jnp.where(valid > 0, scores, neg).reshape(-1)
    top_vals, top_pos = jax.lax.top_k(flat, plan.K)
    arm_ids = arm_ids0[idx].reshape(-1)[top_pos]
    if final_exact and (quantized or adaptive):
        arm_ids, top_vals = _rescore_rows(V, q, arm_ids, plan.n, plan=plan,
                                          batched=False)
    else:
        top_vals = top_vals * jnp.float32(scale)
    return (arm_ids, top_vals, rounds_used) if adaptive else (arm_ids,
                                                              top_vals)


def bounded_me_blocked(V, q, key, *, K: int = 1, eps: float = 0.1,
                       delta: float = 0.05, value_range: float = 1.0,
                       tile: int = 8, block: int = 512,
                       final_exact: bool = False, use_pallas: bool = False,
                       precision: str = "fp32", adaptive: bool = False,
                       bound: str = "hoeffding",
                       pull_mode: str = "row", coord_block: int = 128,
                       quant_err: Optional[float] = None,
                       pq_subdims: int = 8, pq_codes: int = 16,
                       plan: Optional[BlockedPlan] = None):
    """Top-K MIPS over rows of ``V`` for query ``q`` (single query).

    Returns ``(ids (K,), scores (K,), plan)`` where scores estimate
    ``(q . v)/N``.  All shapes are static; safe under jit/pjit.  With
    ``use_pallas=True`` the entire cascade is one kernel dispatch.
    ``precision='int8'``/``'int4'`` sample on a scalar integer grid under
    quantization-widened bounds; ``'pq'`` samples product-quantized codes
    — with ``quant_err=None`` the pq plan is auto-calibrated on ``V`` via
    `make_measured_plan` (DESIGN.md §10).  ``final_exact`` then rescores
    the winners in fp32 on every quantized tier.
    ``adaptive=True`` certifies early exit at round boundaries under the
    plan's ``bound`` radius family and returns a 4-tuple
    ``(ids, scores, rounds_used, plan)`` (DESIGN.md §12);
    ``adaptive=False`` is bit-identical to not passing it.
    ``pull_mode`` selects the reward stream — 'row', 'coord' (narrow
    ``coord_block``-wide feature tiles, DESIGN.md §14) or 'hybrid'
    (cheaper of the two by `choose_pull_mode`).  When ``plan`` is given
    its own precision/bound/pull_mode win.
    """
    n, N = V.shape
    if plan is None:
        kwargs = dict(K=K, eps=eps, delta=delta, value_range=value_range,
                      tile=tile, block=block, precision=precision,
                      bound=bound, pull_mode=pull_mode,
                      coord_block=coord_block, pq_subdims=pq_subdims,
                      pq_codes=pq_codes)
        if precision == "pq" and quant_err is None:
            plan = make_measured_plan(V, **kwargs)
        else:
            plan = make_plan(n, N, quant_err=quant_err, **kwargs)
    out = _run_blocked(jnp.asarray(V), jnp.asarray(q), key, plan=plan,
                       final_exact=final_exact, use_pallas=use_pallas,
                       adaptive=adaptive)
    return (*out, plan)


@functools.partial(jax.jit, static_argnames=("plan", "final_exact",
                                             "adaptive"))
def _run_batched_fused(V, Q, keys, *, plan: BlockedPlan, final_exact: bool,
                       adaptive: bool = False):
    """Per-query-key batch as ONE batched kernel dispatch (B in the grid)."""
    C = plan.block
    B = Q.shape[0]
    V, Q = _pad_operands(jnp.asarray(V), jnp.asarray(Q), plan)
    V4 = _tile_major(V, plan)
    Qb = Q.reshape(B, plan.n_blocks, C)
    perms = jax.vmap(
        lambda k: jax.random.permutation(k, plan.n_blocks))(keys)
    scale = (plan.n_blocks * C) / plan.N
    rounds_used = None
    if plan.precision != "fp32":
        Vq, vaux = _quantize_table(V4, plan)
        if plan.precision == "pq":
            Q_in, vscale, qscale, codebook = Qb, None, None, vaux
        else:
            Q_in, qscale = quantize_blocks(Qb)
            vscale, codebook = vaux, None
        out = _fused_call(Vq, Q_in, perms, plan=plan,
                          final_exact=final_exact, batched=True,
                          vscale=vscale, qscale=qscale, codebook=codebook,
                          adaptive=adaptive)
    else:
        out = _fused_call(V4, Qb, perms, plan=plan,
                          final_exact=final_exact, batched=True,
                          adaptive=adaptive)
    if adaptive:
        ids, vals, rounds_used = out
    else:
        ids, vals = out
    if final_exact and (plan.precision != "fp32" or adaptive):
        ids, vals = _rescore_rows(V, Q, ids, plan.n, plan=plan, batched=True)
    else:
        vals = vals * jnp.float32(scale)
    return (ids, vals, rounds_used) if adaptive else (ids, vals)


def bounded_me_batched(V, Q, keys, *, plan: BlockedPlan,
                       final_exact: bool = False, use_pallas: bool = False,
                       adaptive: bool = False):
    """BoundedME over a batch of queries ``Q`` (B, N) with per-query keys.

    Results match a loop of single-query calls with the same keys.  With
    ``use_pallas=True`` the whole batch is ONE batched fused-kernel dispatch
    (query axis in the grid); otherwise the scan fallback is vmapped.  For
    the decode serving hot path prefer `bounded_me_decode`, which shares the
    block permutation across the batch so early rounds become dense MXU
    tile-matmuls even without Pallas.  ``adaptive=True`` appends a
    per-query ``rounds_used (B,)`` output (DESIGN.md §12).
    """
    if use_pallas:
        return _run_batched_fused(jnp.asarray(V), jnp.asarray(Q), keys,
                                  plan=plan, final_exact=final_exact,
                                  adaptive=adaptive)
    fn = functools.partial(_run_blocked, plan=plan, final_exact=final_exact,
                           use_pallas=False, adaptive=adaptive)
    return jax.vmap(fn, in_axes=(None, 0, 0))(jnp.asarray(V), jnp.asarray(Q),
                                              keys)


@functools.partial(jax.jit, static_argnames=("plan", "final_exact",
                                             "use_pallas", "k_out",
                                             "adaptive"))
def _run_decode(V, Q, key, n_valid, Vq=None, vaux=None, *,
                plan: BlockedPlan, final_exact: bool,
                use_pallas: bool, k_out: int, adaptive: bool = False):
    R, C = plan.tile, plan.block
    B = Q.shape[0]
    V, Q = _pad_operands(jnp.asarray(V), jnp.asarray(Q), plan)
    V4 = _tile_major(V, plan)
    Qb = Q.reshape(B, plan.n_blocks, C)
    # ONE permutation shared by the whole batch: identical pull columns per
    # round let round pulls fuse into (n_tiles*R, C) x (C, B) MXU matmuls
    # (marginally each query still samples uniformly without replacement)
    perm = jax.random.permutation(key, plan.n_blocks)
    scale = (plan.n_blocks * C) / plan.N
    quantized = plan.precision != "fp32"
    is_pq = plan.precision == "pq"
    packed4 = plan.precision == "int4"
    track_var = adaptive and plan.schedule.bound == "bernstein"
    if quantized:
        if Vq is None:
            Vq, vaux = _quantize_table(V4, plan)
        if is_pq:                    # pq queries stay f32 (LUT walk)
            vscale, qscale, codebook, Q8 = None, None, vaux, None
        else:
            Q8, qscale = quantize_blocks(Qb)     # per query: (B, n_blocks)
            vscale, codebook = vaux, None

    if use_pallas:
        rounds_used = None
        perms = jnp.broadcast_to(perm, (B, plan.n_blocks))
        if quantized:
            out = _fused_call(Vq, Qb if is_pq else Q8, perms, plan=plan,
                              final_exact=final_exact, batched=True,
                              k_out=k_out, n_valid=n_valid,
                              vscale=vscale, qscale=qscale,
                              codebook=codebook, adaptive=adaptive)
        else:
            out = _fused_call(V4, Qb, perms, plan=plan,
                              final_exact=final_exact, batched=True,
                              k_out=k_out, n_valid=n_valid,
                              adaptive=adaptive)
        if adaptive:
            ids, vals, rounds_used = out
        else:
            ids, vals = out
        if final_exact and (quantized or adaptive):
            ids, vals = _rescore_rows(V, Q, ids, n_valid, plan=plan,
                                      batched=True)
        else:
            vals = vals * jnp.float32(scale)
        return (ids, vals, rounds_used) if adaptive else (ids, vals)

    arm_ids0 = jnp.arange(plan.n_tiles * R).reshape(plan.n_tiles, R)
    valid0 = (arm_ids0 < n_valid).astype(jnp.float32)
    brange = jnp.arange(B)[:, None]

    idx = jnp.broadcast_to(jnp.arange(plan.n_tiles), (B, plan.n_tiles))
    sums = jnp.zeros((B, plan.n_tiles, R), dtype=jnp.float32)
    sums2 = jnp.zeros_like(sums) if track_var else None
    t_prev = 0
    neg = jnp.asarray(-jnp.inf, dtype=jnp.float32)
    n_rounds = len(plan.schedule.rounds)
    if adaptive:
        cert = cert_coeffs(plan.schedule)
        t_last = plan.schedule.rounds[-1].t_cum if n_rounds else 0
        active = jnp.ones((B,), bool)
        t_stop = jnp.full((B,), t_last, jnp.int32)
        rounds_used = jnp.full((B,), n_rounds, jnp.int32)
        gate = lambda new, old: jnp.where(active[:, None, None], new, old)

    for l, rnd in enumerate(plan.schedule.rounds):
        if rnd.t_new > 0:
            cols = jax.lax.slice_in_dim(perm, t_prev, rnd.t_cum)   # (dt,)
            Qsrc = Q8 if (quantized and not is_pq) else Qb
            qsel = jnp.moveaxis(Qsrc[:, cols], 0, 1)               # (dt,B,C)
            if B * rnd.n_arms >= plan.n_tiles:
                # early rounds: survivor union ~ every tile, so a dense
                # (n_tiles*R, C) x (C, B) tile-matmul per block beats any
                # gather; eliminated tiles accumulate garbage that is never
                # read back (survivor gathers go through `idx`)
                if is_pq:
                    def dense(s, xs):
                        col, qcol = xs           # qcol: (B, C) f32
                        # vmap of the SHARED per-query LUT walk keeps the
                        # per-slice arithmetic identical to the kernel's
                        part = jax.vmap(
                            lambda qq: pq_tile_dot(Vq[:, col], qq,
                                                   codebook[col]))(qcol)
                        if track_var:
                            return ((s[0] + part, s[1] + part * part),
                                    None)
                        return s + part, None
                elif quantized:
                    def dense(s, xs):
                        col, qcol = xs
                        slab = Vq[:, col]
                        if packed4:
                            slab = unpack_int4(slab)
                        raw = jnp.einsum("trc,bc->btr", slab, qcol,
                                         preferred_element_type=jnp.int32)
                        scl = (vscale[:, col][None, :, None]
                               * qscale[:, col][:, None, None])  # (B, T, 1)
                        part = raw.astype(jnp.float32) * scl
                        if track_var:
                            return ((s[0] + part, s[1] + part * part),
                                    None)
                        return s + part, None
                else:
                    def dense(s, xs):
                        col, qcol = xs
                        part = jnp.einsum("trc,bc->btr", V4[:, col], qcol,
                                          preferred_element_type=jnp.float32)
                        if track_var:
                            return ((s[0] + part, s[1] + part * part),
                                    None)
                        return s + part, None
                carry = (sums, sums2) if track_var else sums
                new, _ = jax.lax.scan(dense, carry, (cols, qsel))
                if track_var:
                    new, new2 = new
                    sums2 = gate(new2, sums2)
                if adaptive:   # certified queries' accumulators stay frozen
                    sums = gate(new, sums)
                else:
                    sums = new
            else:
                # late rounds: few survivors per query — per-query gather
                # scans, sequential over the batch to bound the working set
                if is_pq:
                    def one(args):
                        idx_i, Qb_i = args
                        s0 = jnp.zeros((rnd.n_arms, R), jnp.float32)
                        s20 = jnp.zeros_like(s0) if track_var else None
                        return _scan_pulls(s0, Vq, Qb_i, idx_i, cols,
                                           sums2=s20, codebook=codebook)
                    parts = jax.lax.map(one, (idx, Qb))        # (B, T, R)
                elif quantized:
                    def one(args):
                        idx_i, Q8_i, qs_i = args
                        s0 = jnp.zeros((rnd.n_arms, R), jnp.float32)
                        s20 = jnp.zeros_like(s0) if track_var else None
                        return _scan_pulls(s0, Vq, Q8_i, idx_i, cols,
                                           vscale, qs_i, sums2=s20,
                                           packed_int4=packed4)
                    parts = jax.lax.map(one, (idx, Q8, qscale))  # (B, T, R)
                else:
                    def one(args):
                        idx_i, Qb_i = args
                        s0 = jnp.zeros((rnd.n_arms, R), jnp.float32)
                        s20 = jnp.zeros_like(s0) if track_var else None
                        return _scan_pulls(s0, V4, Qb_i, idx_i, cols,
                                           sums2=s20)
                    parts = jax.lax.map(one, (idx, Qb))        # (B, T, R)
                if track_var:
                    parts, parts2 = parts
                    sums2 = gate(sums2.at[brange, idx].add(parts2), sums2)
                if adaptive:
                    sums = gate(sums.at[brange, idx].add(parts), sums)
                else:
                    sums = sums.at[brange, idx].add(parts)
        t_prev = rnd.t_cum
        denom = jnp.float32(t_prev * C)
        means = jnp.take_along_axis(sums, idx[..., None], axis=1)
        means = means / denom
        valid = valid0[idx]
        tile_score = jnp.where(valid > 0, means, neg).max(axis=-1)  # (B, T)
        _, keep = jax.lax.top_k(tile_score, rnd.n_keep)
        idx = jnp.take_along_axis(idx, keep, axis=1)
        if adaptive:
            mu = jnp.take_along_axis(sums, idx[..., None], axis=1) / denom
            v = (jnp.take_along_axis(sums2, idx[..., None], axis=1)
                 / (denom * jnp.float32(C)) - mu * mu
                 if track_var else None)
            active, rounds_used, t_stop = _cert_update(
                mu, v, valid0[idx] > 0, cert, l, rnd.t_cum, plan.K,
                active, rounds_used, t_stop)

    valid = valid0[idx]
    if final_exact and not quantized and not adaptive:
        Vfin = V4[idx]                                 # (B, Tf, nb, R, C)
        scores = jnp.einsum("btnrc,bnc->btr", Vfin, Qb,
                            preferred_element_type=jnp.float32)
        scores = scores / jnp.float32(plan.n_blocks * C)
    elif adaptive:
        # normalize by each query's ACTUAL pull count (frozen at t_stop)
        scores = jnp.take_along_axis(sums, idx[..., None], axis=1)
        scores = scores / (jnp.maximum(t_stop, 1)[:, None, None]
                           * C).astype(jnp.float32)
    else:
        # the int8 + final_exact rescore runs on the k_out candidates below
        scores = jnp.take_along_axis(sums, idx[..., None], axis=1)
        scores = scores / jnp.float32(max(1, t_prev) * C)
    flat = jnp.where(valid > 0, scores, neg).reshape(B, -1)
    top_vals, top_pos = jax.lax.top_k(flat, k_out)
    arm_ids = jnp.take_along_axis(arm_ids0[idx].reshape(B, -1), top_pos,
                                  axis=1)
    if final_exact and (quantized or adaptive):
        arm_ids, top_vals = _rescore_rows(V, Q, arm_ids, n_valid, plan=plan,
                                          batched=True)
    else:
        top_vals = top_vals * jnp.float32(scale)
    return (arm_ids, top_vals, rounds_used) if adaptive else (arm_ids,
                                                              top_vals)


def bounded_me_decode(V, Q, key, *, plan: BlockedPlan,
                      final_exact: bool = True,
                      use_pallas: Optional[bool] = None,
                      k_out: Optional[int] = None,
                      n_valid=None, quantized=None,
                      adaptive: bool = False):
    """Batched-decode BoundedME: one dispatch for a whole (B, N) batch.

    The serving hot path (DESIGN.md §3).  All queries share one block
    permutation so every round's pulls are identical columns across the
    batch: with ``use_pallas`` the batched fused kernel serves the batch in
    a single `pallas_call`; the jnp fallback turns early rounds into dense
    (n_tiles*R, C) x (C, B) MXU tile-matmuls instead of the per-query
    gather einsum the vmapped path pays.  Survivor sets and eliminations
    stay fully per-query.

    Args:
      V: (n, N) item/arm matrix (rows are arms); any float dtype.
      Q: (B, N) query batch, same trailing dim as ``V``.
      key: PRNG key for the shared block permutation.
      plan: static :class:`BlockedPlan` from :func:`make_plan` — carries the
        (eps, delta) calibration and the sampling ``precision``; must match
        ``V``'s (n, N).  With ``plan.precision='int8'`` every sampling
        round pulls int8 tiles under quantization-widened confidence
        bounds (DESIGN.md §10).
      final_exact: make the returned scores exact mean products (q . v)/N
        instead of block-mean estimates — via in-cascade coverage
        completion at fp32, or via an fp32 rescore of the ``k_out``
        candidates on the int8 path (which never pays coverage pulls).
      use_pallas: force/deny the fused kernel (default: auto, TPU only).
      k_out: how many candidates to return per query (default ``plan.K``).
        The cascade still targets ``plan.K`` (the elimination keeps
        ``plan.k_tiles`` tiles); ``k_out`` only widens the final extraction
        so shard-local callers get a threshold candidate for bound gaps.
        Must satisfy ``plan.K <= k_out <= plan.k_out_cap``.
      n_valid: rows >= n_valid are masked out of every ranking *inside*
        the cascade (default ``plan.n``): caller-padding rows (padded
        vocab, ragged shard) and a dynamic store's dead suffix
        (DESIGN.md §11) can then never occupy survivor or candidate
        slots.  Accepts a traced scalar (per-shard under shard_map, or a
        live-row count that changes between calls without recompiling).
      quantized: optional pre-quantized table operands matching the
        plan's tier — ``(V8, vscale)`` for int8
        (`repro.core.quantize.quantize_tiles` layout), ``(P4, vscale)``
        nibble-packed for int4 (`quantize_tiles_int4`), ``(codes,
        codebook)`` for pq (`pq_encode`/`pq_train`).  When given, the
        in-jit table quantization (and pq codebook training) is skipped —
        this is how a `DynamicTableStore`'s incrementally re-encoded
        shadow reaches the kernel; results are bit-identical to
        quantizing ``V`` in-jit because per-(tile, block) cells (and pq
        code assignments against a frozen codebook) are computed
        independently.  Queries are always quantized in-jit on the
        int8/int4 tiers (they arrive per request); pq queries stay f32.

      adaptive: certify early exit per query at round boundaries under the
        plan's ``bound`` radius family (DESIGN.md §12): a certified
        query's remaining pulls become masked no-ops and a third output
        reports its ``rounds_used``.  ``adaptive=False`` (default) is
        bit-identical to the pre-adaptive decode path.  On the int8 path
        certification radii carry the schedule's ``quant_err`` bias — the
        *eps_effective* calibration — so quantization error is still
        absorbed.

    Returns:
      ``(ids (B, k_out) int32, scores (B, k_out) f32)`` sorted by descending
      score.  Entries past the number of real arms (if ``n < k_out``) carry
      ``-inf`` scores and padding ids.  With ``adaptive=True`` a third
      element ``rounds_used (B,) int32`` is appended — the per-query count
      of elimination rounds that actually pulled (the histogram input for
      `benchmarks/bench_adaptive` and the serve engine's stats).
    """
    if use_pallas is None:
        from repro.kernels import ops as _kops
        use_pallas = _kops.on_tpu()
    if k_out is None:
        k_out = plan.K
    if not plan.K <= k_out <= plan.k_out_cap:
        raise ValueError(f"k_out={k_out} outside [K={plan.K}, "
                         f"k_out_cap={plan.k_out_cap}]")
    if n_valid is None:
        n_valid = plan.n
    if quantized is not None and plan.precision == "fp32":
        raise ValueError("pre-quantized operands need a quantized plan "
                         "(precision 'int8', 'int4' or 'pq')")
    Vq, vaux = quantized if quantized is not None else (None, None)
    return _run_decode(jnp.asarray(V), jnp.asarray(Q), key,
                       jnp.asarray(n_valid, jnp.int32), Vq, vaux,
                       plan=plan, final_exact=final_exact,
                       use_pallas=use_pallas, k_out=k_out,
                       adaptive=adaptive)
