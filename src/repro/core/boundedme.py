"""Reference BoundedME (Algorithm 1) — exact per-arm semantics.

This is the paper-faithful implementation used to validate Theorem 1 and as
the correctness oracle for the TPU-optimized path (`boundedme_jax`).  It is a
host-side numpy loop over rounds; the rewards are presented as a matrix in
*oracle order*: pulling arm ``i`` for the ``t``-th time returns ``R[i, t-1]``.

* For MIPS, build ``R`` with :func:`reward_matrix` (a fresh random coordinate
  permutation per query = uniform sampling without replacement).
* For the paper's adversarial experiment (Fig. 1), pass rows sorted
  descending (1-rewards returned before 0-rewards).

Only *consumed* entries count toward the reported sample complexity; the
fast path never materializes ``R`` at all.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.schedule import Schedule, make_schedule

__all__ = ["BoundedMEResult", "bounded_me", "reward_matrix"]


@dataclasses.dataclass
class BoundedMEResult:
    topk: np.ndarray            # (K,) arm indices, best-first by empirical mean
    means: np.ndarray           # (K,) empirical means at termination
    total_pulls: int            # consumed rewards (the sample complexity)
    rounds: int
    schedule: Schedule


def reward_matrix(V: np.ndarray, q: np.ndarray,
                  rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """MIPS reward lists in oracle order: a shared random coordinate order.

    Sharing one permutation across arms keeps each arm's pulls a uniform
    without-replacement sample (the guarantee never uses cross-arm
    independence) while making the fast path's memory access contiguous.
    """
    rng = np.random.default_rng() if rng is None else rng
    perm = rng.permutation(V.shape[1])
    return V[:, perm] * q[perm][None, :]


def bounded_me(R: np.ndarray, K: int = 1, eps: float = 0.1, delta: float = 0.05,
               value_range: float = 1.0,
               schedule: Optional[Schedule] = None) -> BoundedMEResult:
    """Run Algorithm 1 on reward matrix ``R`` (n, N) given in oracle order."""
    n, N = R.shape
    if schedule is None:
        schedule = make_schedule(n, N, K=K, eps=eps, delta=delta,
                                 value_range=value_range)
    K = schedule.K
    if not schedule.rounds:  # K >= n: return everything
        means = R.mean(axis=1)
        order = np.argsort(-means)[:K]
        return BoundedMEResult(order, means[order], 0, 0, schedule)

    alive = np.arange(n)
    sums = np.zeros(n, dtype=np.float64)
    t_prev = 0
    total = 0
    for rnd in schedule.rounds:
        if rnd.t_new > 0:
            sums[alive] += R[alive, t_prev:rnd.t_cum].sum(axis=1)
            total += alive.size * rnd.t_new
        t_prev = rnd.t_cum
        means = sums[alive] / max(1, t_prev)
        # keep the n_keep arms with the highest empirical means
        keep = np.argpartition(-means, rnd.n_keep - 1)[: rnd.n_keep]
        alive = alive[keep]
    final_means = sums[alive] / max(1, t_prev)
    order = np.argsort(-final_means)[:K]
    return BoundedMEResult(alive[order], final_means[order], total,
                           len(schedule.rounds), schedule)
