"""BoundedSE — beyond-paper: instance-adaptive elimination under MAB-BP.

BoundedME (the paper) sizes every round for the *worst case*: its pull
counts depend only on (n, N, eps, delta), never on the observed gaps, so
easy instances (large gaps) pay the same as hard ones.  Classical
Successive Elimination is gap-adaptive but uses i.i.d. Hoeffding radii that
ignore the finite list.  BoundedSE combines both: SE-style anytime
elimination with the *without-replacement* deviation radius
``(b-a) sqrt(rho_m log(c m^2 / delta') / 2m)`` (Corollary 1 + a union bound
over the pull schedule), which (i) shrinks to **zero** at m = N, so the
algorithm degrades gracefully to exhaustive search, and (ii) stops as soon
as the top-K set is separated by eps — adaptively early on easy instances.

Guarantee: returned set is eps-optimal w.p. >= 1-delta (union bound over
arms x checkpoints), **provided pulls are uniformly-random without
replacement** — which the MIPS reduction guarantees by construction
(`reward_matrix` samples coordinates in a fresh random order; the adversary
controls values, never the pull order).  Under an order-controlling
adversary (the paper's Fig-1 oracle, stronger than any MIPS instance) the
anytime radius is invalid — use BoundedME there, whose worst-case round
sizing is order-robust.  Empirically 2-10x fewer pulls than BoundedME on
large-gap instances (see tests/test_bounded_se.py + table1 rows).
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.core import bounds
from repro.core.boundedme import BoundedMEResult
from repro.core.schedule import Schedule

__all__ = ["bounded_se"]


def bounded_se(R: np.ndarray, K: int = 1, eps: float = 0.1,
               delta: float = 0.05, value_range: float = 1.0,
               batch: int = 32) -> BoundedMEResult:
    """Anytime eps-top-K identification on reward matrix R (oracle order)."""
    n, N = R.shape
    if K >= n:
        means = R.mean(axis=1)
        order = np.argsort(-means)[:K]
        return BoundedMEResult(order, means[order], 0, 0,
                               Schedule(n, N, K, eps, delta, value_range, ()))
    alive = np.arange(n)
    sums = np.zeros(n, dtype=np.float64)
    t, total, checks = 0, 0, 0
    n_checks = max(1, int(math.ceil(N / batch)))
    # per-arm, per-checkpoint confidence budget (union bound)
    delta_pt = delta / (n * n_checks)

    while alive.size > K and t < N:
        t_new = min(batch, N - t)
        sums[alive] += R[alive, t:t + t_new].sum(axis=1)
        t += t_new
        total += alive.size * t_new
        checks += 1
        rad = bounds.deviation_bound(t, N, delta_pt, value_range)
        means = sums[alive] / t
        # K-th best lower bound vs each arm's upper bound
        kth = -np.partition(-means, K - 1)[K - 1]
        keep = means + rad >= kth - rad
        keep_idx = np.nonzero(keep)[0]
        if keep_idx.size >= K:
            alive = alive[keep_idx]
        if 2.0 * rad <= eps:     # everyone surviving is eps-good vs kth
            break
    means = sums[alive] / max(1, t)
    order = np.argsort(-means)[:K]
    sched = Schedule(n, N, K, eps, delta, value_range, ())
    return BoundedMEResult(alive[order], means[order], total, checks, sched)
