"""Concentration bounds for sampling *without replacement* from a finite list.

This module is the statistical heart of the paper: Corollary 2.5 of
Bardenet & Maillard (2015) and the closed-form sample size ``m(u)`` derived
from it (Lemma 1 / Lemma 3 in the paper).  It also ships the classical
(i.i.d.) Hoeffding and LIL sample sizes used by the bandit baselines so the
sample-complexity win of the without-replacement bound is measurable.

Everything here is plain python/numpy on scalars: these quantities are
*static* (they depend only on n, N, K, eps, delta), are computed at trace
time, and parameterize the shapes of the jitted TPU program.
"""

from __future__ import annotations

import math
from typing import Optional

__all__ = [
    "rho_m",
    "u_term",
    "m_required",
    "deviation_bound",
    "bernstein_radius",
    "m_required_eb",
    "coord_radius",
    "coord_m_required",
    "hoeffding_required",
    "lil_required",
    "quantization_error",
    "KAPPA_EB",
]

# additive-term constant of the empirical Bernstein–Serfling inequality
# (Bardenet & Maillard 2015, Theorem 3): kappa = 7/3 + 3/sqrt(2)
KAPPA_EB = 7.0 / 3.0 + 3.0 / math.sqrt(2.0)


def rho_m(m: int, N: int) -> float:
    """The variance-reduction factor for sampling without replacement.

    ``rho_m = min{1 - (m-1)/N, (1 - m/N)(1 + 1/m)}``  (Eq. 3 of the paper).
    As ``m → N`` this goes to 0: once the whole list is seen, the empirical
    mean is exact.  The i.i.d. Hoeffding bound corresponds to ``rho_m = 1``.

    Clamped at the boundary: any ``m >= N`` returns exactly 0.0 (the
    without-replacement variance of a fully observed list is zero), so
    callers never have to cap ``m`` themselves.
    """
    if m < 1:
        raise ValueError(f"m must be >= 1, got {m}")
    if N <= 1:
        raise ValueError(f"N must be > 1, got {N}")
    if m >= N:
        return 0.0
    return min(1.0 - (m - 1.0) / N, (1.0 - m / N) * (1.0 + 1.0 / m))


def u_term(eps: float, delta: float, value_range: float = 1.0) -> float:
    """``u = log(1/delta)/2 * (b-a)^2 / eps^2``  (Lemma 1).

    Returns ``inf`` (instead of raising ``OverflowError``) when the ratio
    overflows the float range — `m_required` clamps that to full coverage.
    """
    if not 0.0 < eps:
        raise ValueError(f"eps must be > 0, got {eps}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0,1), got {delta}")
    try:
        return 0.5 * math.log(1.0 / delta) * (value_range / eps) ** 2
    except OverflowError:
        return math.inf


def m_required(eps: float, delta: float, N: int, value_range: float = 1.0) -> int:
    """Minimal without-replacement sample size for an ``(eps, delta)`` estimate.

    ``m(u) = min{ (u+1)/(1+u/N), (u + u/N)/(1+u/N) }`` (Eq. 4/6), with
    ``u = u_term(eps, delta, value_range)``.  Always ``<= N`` — the defining
    property that makes BoundedME never slower than exhaustive search.

    Edge behavior at full coverage: as ``eps -> 0`` the required ``u``
    overflows to ``inf`` and the Eq. 4 ratio degenerates to ``inf/inf``
    (pre-PR-5 this raised from ``ceil(nan)``); any non-finite ``u`` now
    clamps straight to ``N`` — at ``m = N`` the without-replacement
    variance is exactly zero (`rho_m` returns 0), so full coverage
    satisfies every ``eps > 0``.
    """
    if N <= 1:
        return 1
    u = u_term(eps, delta, value_range)
    if not math.isfinite(u):
        return N          # eps so small the sample size saturates the list
    if u <= 0.0:
        return 1
    m1 = (u + 1.0) / (1.0 + u / N)
    m2 = (u + u / N) / (1.0 + u / N)
    m = min(m1, m2)
    return max(1, min(N, int(math.ceil(m))))


def deviation_bound(m: int, N: int, delta: float, value_range: float = 1.0) -> float:
    """One-sided deviation eps(m, delta) from Corollary 1 (Eq. 2).

    ``P[ mean_hat - mean <= (b-a) sqrt(rho_m log(1/delta) / (2m)) ] >= 1-delta``.
    Useful for anytime confidence intervals on partially computed inner
    products (the "knob" of Motivation II, inverted).
    """
    if m >= N:
        return 0.0
    return value_range * math.sqrt(rho_m(m, N) * math.log(1.0 / delta) / (2.0 * m))


def bernstein_radius(m: int, N: int, delta: float, value_range: float = 1.0,
                     std: float = 0.0) -> float:
    """Two-sided empirical Bernstein–Serfling deviation radius.

    Bardenet & Maillard (2015), Theorem 3: when sampling ``m`` of ``N``
    values without replacement, with probability at least ``1 - delta``

        |mean_hat - mean| <= std_hat sqrt(2 rho_m log(5/delta) / m)
                             + kappa (b-a) log(5/delta) / m,

    with ``kappa = 7/3 + 3/sqrt(2)`` (`KAPPA_EB`) and ``std_hat`` the
    *empirical* (population-normalized, i.e. divide-by-m) standard
    deviation of the observed values.  This is the variance-aware radius
    family behind ``make_schedule(bound='bernstein')``: on low-variance
    reward lists the ``sqrt(Vhat)`` term collapses and the radius is
    dominated by the O(1/m) additive term, far below the Hoeffding radius
    at the same ``m`` — which is what lets the adaptive cascade certify
    easy queries rounds earlier (DESIGN.md §12).

    Returns exactly 0.0 for ``m >= N`` (full coverage: the empirical mean
    is the mean).  ``std`` is the empirical standard deviation observed so
    far; pass ``value_range / 2`` for the a-priori worst case.
    """
    if m < 1:
        raise ValueError(f"m must be >= 1, got {m}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0,1), got {delta}")
    if std < 0.0:
        raise ValueError(f"std must be >= 0, got {std}")
    if m >= N:
        return 0.0
    lg = math.log(5.0 / delta)
    return (std * math.sqrt(2.0 * rho_m(m, N) * lg / m)
            + KAPPA_EB * value_range * lg / m)


def m_required_eb(eps: float, delta: float, N: int, value_range: float = 1.0,
                  std: Optional[float] = None) -> int:
    """Minimal sample size under the empirical-Bernstein–Serfling radius.

    The smallest ``m`` with ``bernstein_radius(m, N, delta, value_range,
    std) <= eps``, found by binary search (the radius is nonincreasing in
    ``m``: both ``rho_m / m`` and ``1/m`` shrink).  ``std`` defaults to the
    worst case ``value_range / 2``.  Like `m_required` this is clamped to
    ``[1, N]`` — full coverage (``m = N``, radius exactly 0) satisfies any
    ``eps > 0``, so the search always terminates and never relies on the
    caller to cap.
    """
    if not 0.0 < eps:
        raise ValueError(f"eps must be > 0, got {eps}")
    if N <= 1:
        return 1
    if std is None:
        std = value_range / 2.0
    lo, hi = 1, N
    while lo < hi:
        mid = (lo + hi) // 2
        if bernstein_radius(mid, N, delta, value_range, std) <= eps:
            hi = mid
        else:
            lo = mid + 1
    return lo


def coord_radius(m: int, d_blocks: int, delta: float, value_range: float = 1.0,
                 quant_err: float = 0.0) -> float:
    """Deviation radius of the coordinate-sampling estimator (BanditMIPS).

    The coordinate pull mode estimates each inner product ``<q, v_i>`` by
    sampling ``m`` of the ``d_blocks`` feature blocks *without replacement*
    under a shared per-query permutation; each observed block-mean is an
    unbiased reward whose per-observation range is ``value_range`` (the
    a-priori bound on a per-coordinate product, or the block-mean range
    under CLT calibration).  The radius is therefore the same
    Hoeffding–Serfling family as the row estimator — `deviation_bound` —
    but over the *feature-block* population ``N = d_blocks`` instead of
    the row-tile population, which is what makes the certified pull cost
    independent of the number of arms and sublinear in d.

    With quantized (int8) rewards each observation's range is widened by
    ``2 * quant_err`` (the rounding perturbation enters on both ends of
    the per-observation interval); the *deterministic* bias itself is
    budgeted by `coord_m_required` (``dev = eps - quant_err``), matching
    the ``Schedule.eps_effective`` accounting of the row estimator, so
    ``coord_radius(m, N, d, v, qe) == coord_radius(m, N, d, v + 2*qe, 0)``
    identically.

    Returns exactly 0.0 for ``m >= d_blocks`` (full coverage: the
    empirical block-mean is the inner product).  Monotone nonincreasing
    in ``m``.
    """
    if quant_err < 0.0:
        raise ValueError(f"quant_err must be >= 0, got {quant_err}")
    if m >= d_blocks:
        return 0.0
    return deviation_bound(m, d_blocks, delta, value_range + 2.0 * quant_err)


def coord_m_required(eps: float, delta: float, d_blocks: int,
                     value_range: float = 1.0, quant_err: float = 0.0) -> int:
    """Minimal coordinate-block sample count for an ``(eps, delta)`` estimate.

    Inverts `coord_radius`: the smallest ``m`` with
    ``coord_radius(m, d_blocks, delta, value_range, quant_err) <= eps``.
    The deterministic quantization bias is subtracted from the budget
    first (``dev = eps - quant_err``); if the bias alone exhausts the
    budget the only valid answer is full coverage ``m = d_blocks``
    (sampling cannot reduce a deterministic bias).  Like `m_required`,
    non-finite intermediate terms as ``eps → 0`` clamp to full coverage
    rather than raising — ``m = d_blocks`` has zero sampling error, so
    full coverage satisfies every ``eps >= quant_err``.  Always in
    ``[1, d_blocks]``.
    """
    if not 0.0 < eps:
        raise ValueError(f"eps must be > 0, got {eps}")
    if quant_err < 0.0:
        raise ValueError(f"quant_err must be >= 0, got {quant_err}")
    if d_blocks <= 1:
        return 1
    dev = eps - quant_err
    if dev <= 0.0:
        return d_blocks
    return m_required(dev, delta, d_blocks, value_range + 2.0 * quant_err)


def quantization_error(value_range: float, bits: int = 8) -> float:
    """Worst-case per-coordinate product error of symmetric quantization.

    With ``Q = 2^(bits-1) - 1`` levels per sign (127 for int8), symmetric
    round-to-nearest quantization ``v_hat = round(v / s_v)`` with
    ``s_v = v_max / Q`` (and likewise for the query) perturbs each
    per-coordinate product ``x = q_j * v_ij`` by at most

        |x - s_q s_v q_hat v_hat|
            <= q_max * s_v/2 + (v_max + s_v/2) * s_q/2
            <= q_max v_max * (1/Q + 1/(4 Q^2)).

    The a-priori product range bound feeding the schedule is
    ``value_range >= 2 q_max v_max`` (see `default_value_range`), so the
    returned bound is ``(value_range / 2) * (1/Q + 1/(4 Q^2))`` — the
    deterministic bias budget the quantized cascade's confidence radii
    must absorb (DESIGN.md §10).  Per-tile scales are never larger than
    the global ones, so this bound holds for tile-wise quantization too.
    """
    if value_range <= 0.0:
        raise ValueError(f"value_range must be > 0, got {value_range}")
    if bits < 2:
        raise ValueError(f"bits must be >= 2, got {bits}")
    q = float(2 ** (bits - 1) - 1)
    return (value_range / 2.0) * (1.0 / q + 1.0 / (4.0 * q * q))


def hoeffding_required(eps: float, delta: float, value_range: float = 1.0) -> int:
    """Classical i.i.d. Hoeffding sample size (no finite-population help).

    ``m >= (b-a)^2 log(1/delta) / (2 eps^2)`` — unbounded as eps → 0.
    """
    u = u_term(eps, delta, value_range)
    return max(1, int(math.ceil(u)))


def lil_required(eps: float, delta: float, value_range: float = 1.0) -> int:
    """Law-of-iterated-logarithm style sample size (Jamieson et al. 2014).

    Conservative closed form: ``m ~ (2/eps^2) (1+sqrt(e)) log(log(..)/delta)``.
    Included only as a baseline comparator for benchmarks.
    """
    c = (1.0 + math.sqrt(math.e)) * 2.0
    u = c * (value_range / eps) ** 2
    inner = max(math.e, math.log(max(math.e, u)) / delta)
    return max(1, int(math.ceil(u * math.log(inner))))
