"""Static elimination schedules for BoundedME.

The key systems observation (DESIGN.md §3): lines 7-11 of Algorithm 1 reference
only (|S_l|, K, eps_l, delta_l, N) — never the data.  Given (n, N, K, eps,
delta) the entire round structure (survivor counts, cumulative pull counts) is
therefore *data independent* and can be computed at trace time.  The jitted
TPU program becomes a fixed cascade of static-shape matmuls + top-k masks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np

from repro.core import bounds

__all__ = ["Round", "Schedule", "FlatSchedule", "make_schedule",
           "flatten_schedule", "cert_coeffs", "pulls_through_round",
           "SLOT_MASK", "END_BIT", "PULL_BIT"]

# bit-packing of the per-step word handed to the fused kernel (SMEM is the
# scarcest resource on-chip: one int32 per step instead of a wide row)
SLOT_MASK = (1 << 29) - 1   # survivor-slot index (n_tiles << 2^29 always)
END_BIT = 1 << 29           # eliminate after this step
PULL_BIT = 1 << 30          # step performs a pull (0 on saturated rounds)


@dataclasses.dataclass(frozen=True)
class Round:
    """One elimination round of Algorithm 1 (static view)."""

    index: int          # l (1-based)
    n_arms: int         # |S_l| at the start of the round
    n_keep: int         # |S_{l+1}| = K + floor((|S_l|-K)/2)
    t_cum: int          # t_l: cumulative pulls per surviving arm
    t_new: int          # t_l - t_{l-1}: pulls issued this round
    eps_l: float
    delta_l: float


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The full static pull/elimination plan."""

    n: int              # number of arms (may be a tile count on the TPU path)
    N: int              # reward-list length (may be a block count)
    K: int
    eps: float
    delta: float
    value_range: float
    rounds: Tuple[Round, ...]  # tuple => hashable => usable as a jit static
    quant_err: float = 0.0     # per-reward bias absorbed by the bounds (§10)
    bound: str = "hoeffding"   # radius family: 'hoeffding' | 'bernstein'
    pull_mode: str = "row"     # reward stream: 'row' | 'coord' (DESIGN.md §14)
    pull_width: int = 1        # coordinates touched per pull (honest cost)

    @property
    def total_pulls(self) -> int:
        """Exact sample complexity (sum over rounds of survivors x new pulls)."""
        return sum(r.n_arms * r.t_new for r in self.rounds)

    @property
    def total_coords(self) -> int:
        """Honest cross-mode cost: coordinates touched, not pulls issued.

        A 'row'-mode pull and a 'coord'-mode pull are different units of
        work — a row pull reads ``pull_width`` = block coordinates of one
        arm tile, a coord pull reads ``pull_width`` = coord_block of them.
        ``total_pulls`` alone would make narrow pulls look free;
        ``total_coords = total_pulls * pull_width`` is the width-weighted
        count that `BlockedPlan.total_multiplies` and the hybrid
        dispatcher compare across pull modes (DESIGN.md §14).
        """
        return self.total_pulls * self.pull_width

    @property
    def naive_pulls(self) -> int:
        """Pulls of the exhaustive baseline: every arm's full reward list."""
        return self.n * self.N

    @property
    def speedup(self) -> float:
        """Pull-count speedup over exhaustive search (>= 1 by Corollary 2)."""
        return self.naive_pulls / max(1, self.total_pulls)

    @property
    def final_pulls(self) -> int:
        """Cumulative pulls per arm surviving to the last round (t_L)."""
        return self.rounds[-1].t_cum if self.rounds else 0

    @property
    def eps_effective(self) -> float:
        """The honest end-to-end suboptimality bound under ``quant_err``.

        Rounds whose per-round budget absorbs the quantization bias
        (``eps_l / 2 > quant_err``) stay eps_l-correct; rounds where it
        cannot are driven to full coverage, where the only remaining error
        is the bias of the two compared estimates, ``<= 2 * quant_err``.
        Summing the per-round errors gives

            eps_eff = eps + sum_{l : eps_l <= 2 quant_err}
                                  (2 quant_err - eps_l)

        which collapses to ``eps`` as ``quant_err -> 0`` (DESIGN.md §10).
        """
        if self.quant_err <= 0.0:
            return self.eps
        penalty = sum(max(0.0, 2.0 * self.quant_err - r.eps_l)
                      for r in self.rounds)
        return self.eps + penalty


@dataclasses.dataclass(frozen=True)
class FlatSchedule:
    """The schedule unrolled to one row per kernel grid step (DESIGN.md §3).

    The fused cascade kernel runs the entire multi-round pull program as a
    single Pallas grid; these arrays are scalar-prefetched into SMEM so the
    kernel can tell, at every step, which survivor slot to pull, which
    position of the block permutation to read, and whether an elimination
    happens after the step.  Everything here is host-side numpy computed at
    trace time — no traced values.

    Step layout per round: blocks outermost, survivor slots innermost, so a
    given arm tile accumulates its coordinate blocks in permutation order
    (the same order the `lax.scan` fallback uses, which is what makes
    interpret-mode kernel results bitwise-comparable to the fallback).
    Rounds whose pull budget is already saturated (``t_new == 0``) still
    eliminate, so they emit one no-pull step carrying the round-end flag.

    With ``final_coverage=True`` extra pull steps are appended that complete
    every final survivor to full coverage (``t -> N``): the final scores are
    then *exact* inner products, the single-dispatch analogue of the
    ``final_exact`` rescore on the unfused path.
    """

    slot: np.ndarray      # (S,) int32  survivor-slot pulled this step
    bpos: np.ndarray      # (S,) int32  index into the block permutation
    is_pull: np.ndarray   # (S,) int32  0 on no-op steps (saturated rounds)
    is_end: np.ndarray    # (S,) int32  1 => eliminate after this step
    t_cum: np.ndarray     # (S,) int32  cumulative pulls of the current round
    n_surv: np.ndarray    # (S,) int32  survivors during this step's round
    n_keep: np.ndarray    # (S,) int32  survivors kept at the elimination
    t_final: int          # pulls per survivor entering the final top-K
    n_final: int          # survivor count entering the final top-K

    @property
    def n_steps(self) -> int:
        """Total kernel grid steps (pull + no-op elimination steps)."""
        return int(self.slot.shape[0])

    def stacked(self) -> np.ndarray:
        """(S, 7) int32 view — handy for oracles and debugging."""
        return np.stack([self.slot, self.bpos, self.is_pull, self.is_end,
                         self.t_cum, self.n_surv, self.n_keep],
                        axis=1).astype(np.int32)

    def packed(self) -> Tuple[np.ndarray, np.ndarray]:
        """SMEM-frugal kernel operands.

        Returns ``(slotcode (S,), rounds_meta (n_rounds+1, 3))``: the
        per-step word packs slot | PULL_BIT | END_BIT; the per-round rows
        are ``(t_cum, n_surv, n_keep)`` consumed in order at end-flagged
        steps (the kernel keeps a round cursor in SMEM).  The pad row keeps
        the array non-empty for schedules with no elimination rounds.
        """
        code = (self.slot.astype(np.int64)
                | self.is_pull.astype(np.int64) * PULL_BIT
                | self.is_end.astype(np.int64) * END_BIT)
        ends = np.nonzero(self.is_end)[0]
        meta = np.stack([self.t_cum[ends], self.n_surv[ends],
                         self.n_keep[ends]], axis=1).reshape(-1, 3)
        meta = np.concatenate([meta, np.zeros((1, 3), np.int64)], axis=0)
        return code.astype(np.int32), meta.astype(np.int32)


def flatten_schedule(sched: Schedule, *,
                     final_coverage: bool = False) -> FlatSchedule:
    """Unroll ``sched`` into the per-step arrays of :class:`FlatSchedule`."""
    slot: List[int] = []
    bpos: List[int] = []
    is_pull: List[int] = []
    is_end: List[int] = []
    t_cum: List[int] = []
    n_surv: List[int] = []
    n_keep: List[int] = []

    def emit(s, p, pull, end, t, T, k):
        slot.append(s); bpos.append(p); is_pull.append(pull)
        is_end.append(end); t_cum.append(t); n_surv.append(T); n_keep.append(k)

    t_prev = 0
    for r in sched.rounds:
        if r.t_new == 0:
            emit(0, 0, 0, 1, r.t_cum, r.n_arms, r.n_keep)
        else:
            for p in range(t_prev, r.t_cum):
                for s in range(r.n_arms):
                    last = (p == r.t_cum - 1) and (s == r.n_arms - 1)
                    emit(s, p, 1, 1 if last else 0, r.t_cum, r.n_arms,
                         r.n_keep)
        t_prev = r.t_cum

    n_final = sched.rounds[-1].n_keep if sched.rounds else sched.n
    t_final = t_prev
    if final_coverage and t_prev < sched.N:
        for p in range(t_prev, sched.N):
            for s in range(n_final):
                emit(s, p, 1, 0, sched.N, n_final, n_final)
        t_final = sched.N
    if not slot:  # degenerate: no rounds, no coverage — one no-op step so
        emit(0, 0, 0, 0, 0, n_final, n_final)  # the kernel still finalizes

    return FlatSchedule(
        slot=np.asarray(slot, np.int32), bpos=np.asarray(bpos, np.int32),
        is_pull=np.asarray(is_pull, np.int32),
        is_end=np.asarray(is_end, np.int32),
        t_cum=np.asarray(t_cum, np.int32),
        n_surv=np.asarray(n_surv, np.int32),
        n_keep=np.asarray(n_keep, np.int32),
        t_final=t_final, n_final=n_final)


def cert_coeffs(sched: Schedule) -> np.ndarray:
    """Per-round certification-radius coefficients for adaptive early exit.

    Returns ``(n_rounds + 1, 2) float32`` rows ``(a_l, b_l)`` (one pad row
    so the array is never empty, mirroring `FlatSchedule.packed`): at the
    end of round ``l`` every surviving arm's confidence radius on the
    block-mean reward scale is

        r_i = a_l * sqrt(max(Vhat_i, 0)) + b_l

    with ``Vhat_i`` the arm's empirical (divide-by-m) reward variance.
    The kernel and both jnp fallbacks evaluate exactly this expression at
    round boundaries and certify a query — freezing its remaining pulls —
    when the top-K arms' lower bounds clear every other survivor's upper
    bound (DESIGN.md §12).

    Budget accounting (why early exit preserves the union bound):

      * ``bound='hoeffding'`` — ``a_l = 0`` and ``b_l`` is the
        Hoeffding–Serfling `deviation_bound` at the round's cumulative
        pulls and the *same* per-arm-per-side budget `_round_pulls` sized
        the round with: certification reads the very events the schedule
        already paid for, so it adds zero failure probability.
      * ``bound='bernstein'`` — ``(a_l, b_l)`` come from the two-sided
        empirical Bernstein–Serfling radius (`bounds.bernstein_radius`) at
        the per-arm budget `_round_pulls` reserved for it (the sizing half
        ran at ``delta_eff / 2``).

    Both families add the schedule's ``quant_err`` to ``b_l`` — on the
    int8 path the certification radii absorb the deterministic
    quantization bias exactly as the sizing radii do (the *eps_effective*
    calibration of DESIGN.md §10), and the width is computed on the
    quantized reward range ``value_range + 2 quant_err``.
    """
    rng_w = sched.value_range + 2.0 * sched.quant_err
    rows = []
    for r in sched.rounds:
        gap = r.n_arms - sched.K
        # delta_eff is the PER-SIDE sizing budget of `_round_pulls`: the
        # per-arm round budget is beta = 2 * delta_eff.  Accounting:
        #   hoeffding  — sizing spends beta (two sides at delta_eff each)
        #                and certification re-reads those same events;
        #   bernstein  — sizing ran at delta_eff/2 per side (beta/2 total),
        #                so the two-sided EB event below may spend the
        #                remaining beta/2 = delta_eff.  Totals stay <= beta.
        delta_eff = r.delta_l * (gap // 2 + 1) / (2.0 * gap)
        m = r.t_cum
        if sched.bound == "bernstein":
            if m >= sched.N:
                a = b = 0.0
            else:
                lg = math.log(5.0 / delta_eff)
                a = math.sqrt(2.0 * bounds.rho_m(m, sched.N) * lg / m)
                b = bounds.KAPPA_EB * rng_w * lg / m
        else:
            a = 0.0
            b = bounds.deviation_bound(m, sched.N, delta_eff, rng_w)
        rows.append((a, b + sched.quant_err))
    rows.append((0.0, 0.0))                      # pad row, never indexed
    return np.asarray(rows, np.float32)


def pulls_through_round(sched: Schedule) -> np.ndarray:
    """Cumulative *executed* pull count after each possible exit round.

    ``out[r]`` for ``r in [0, n_rounds]`` is the total number of
    (arm, block) pulls the cascade has issued once ``rounds_used == r``
    rounds have run: ``out[0] = 0`` and ``out[n_rounds] ==
    Schedule.total_pulls``.  This is the lookup `benchmarks/bench_adaptive`
    and the serve engine use to convert a per-query ``rounds_used`` into
    the paper's sample-complexity metric.
    """
    out = [0]
    for r in sched.rounds:
        out.append(out[-1] + r.n_arms * r.t_new)
    return np.asarray(out, np.int64)


def _round_pulls(n_l: int, K: int, eps_l: float, delta_l: float, N: int,
                 value_range: float, quant_err: float = 0.0,
                 bound: str = "hoeffding") -> int:
    """t_l of Algorithm 1, line 7 (expanded per the Lemma 4 proof).

    Each arm needs an (eps_l/2, delta'_l/2)-accurate estimate where
    ``delta'_l = delta_l (floor((n_l-K)/2)+1) / (n_l-K)`` is the per-arm
    budget and the factor 2 covers the two one-sided deviation events.

    With ``quant_err > 0`` (the int8 sampling path, DESIGN.md §10) each
    estimate additionally carries a deterministic bias of at most
    ``quant_err``, so the *sampling* deviation target shrinks to
    ``eps_l/2 - quant_err`` and the reward range widens by ``2 quant_err``
    (the quantized reward list's range).  Rounds whose budget cannot absorb
    the bias (``eps_l/2 <= quant_err``) are driven to full coverage
    (``t_l = N``), leaving only the bias; `Schedule.eps_effective` accounts
    for those.

    With ``bound='bernstein'`` (DESIGN.md §12) half of each arm's round
    budget is reserved for the per-round empirical-Bernstein certification
    events of the adaptive early-exit path, so the sizing confidence drops
    to ``delta_eff / 2`` (slightly more pulls per round); the Hoeffding
    default reuses the sizing events for certification and reserves
    nothing.
    """
    gap = n_l - K
    if gap <= 0:
        return 0
    delta_eff = delta_l * (gap // 2 + 1) / (2.0 * gap)
    if bound == "bernstein":
        delta_eff /= 2.0   # the other half funds the EB certification
    dev = eps_l / 2.0 - quant_err
    if dev <= 0.0:
        return N          # sampling cannot absorb the bias: full coverage
    # deviation eps_l/2 (minus the bias budget), confidence delta_eff
    return bounds.m_required(dev, delta_eff, N,
                             value_range + 2.0 * quant_err)


def make_schedule(n: int, N: int, K: int = 1, eps: float = 0.1,
                  delta: float = 0.05, value_range: float = 1.0,
                  quant_err: float = 0.0,
                  bound: str = "hoeffding",
                  pull_mode: str = "row",
                  pull_width: int = 1) -> Schedule:
    """Build the static round plan of Algorithm 1.

    eps_1 = eps/4, delta_1 = delta/2; eps_{l+1} = 3/4 eps_l,
    delta_{l+1} = delta_l/2; each round keeps K + floor((|S_l|-K)/2) arms.
    Cumulative pull counts are clamped to be nondecreasing and <= N.
    ``quant_err`` widens every round's pull count so a per-reward bias of
    that size (low-precision sampling arithmetic) is absorbed into the
    confidence radii (see `_round_pulls` and DESIGN.md §10).

    ``pull_mode`` records which reward stream the schedule prices
    (DESIGN.md §14) and ``pull_width`` how many coordinates one pull
    touches, feeding `Schedule.total_coords`:

      * 'row' (default) — rewards are block-means of whole feature blocks
        per arm tile; ``N`` is the feature-block count at the row block
        width (typically ``min(512, d)``).
      * 'coord' — the BanditMIPS coordinate estimator: rewards are means
        of *narrow* feature blocks sampled without replacement under a
        shared per-query permutation, so ``N = d_blocks = ceil(d /
        coord_block)`` is larger and each pull is cheaper.  The round
        structure is identical — the Hoeffding–Serfling / Bernstein
        machinery only sees the population size ``N`` — which is why the
        whole kernel path is reused unchanged.

    The composite 'hybrid' mode is *not* a schedule-level concept: it is
    resolved to 'row' or 'coord' by ``make_plan`` (which prices both
    candidate plans and keeps the cheaper; see
    `repro.core.boundedme_jax.choose_pull_mode`), so passing it here
    raises.

    ``bound`` selects the radius family the adaptive early-exit path uses
    to certify queries at round boundaries (`cert_coeffs`, DESIGN.md §12):

      * 'hoeffding' (default) — certification reuses the schedule's own
        Hoeffding–Serfling sizing events at no extra delta cost; the round
        plan is *identical* to the non-adaptive one.
      * 'bernstein' — certification uses the variance-aware empirical
        Bernstein–Serfling radius (`bounds.bernstein_radius`, with running
        mean/M2 accumulators carried per surviving tile at run time);
        those are new events, so each round's sizing confidence is halved
        to reserve budget for them (slightly more pulls per round, much
        earlier certification on low-variance data).
    """
    if n < 1 or N < 1:
        raise ValueError(f"need n,N >= 1, got n={n} N={N}")
    if quant_err < 0.0:
        raise ValueError(f"quant_err must be >= 0, got {quant_err}")
    if bound not in ("hoeffding", "bernstein"):
        raise ValueError(f"unknown bound {bound!r} "
                         f"(expected 'hoeffding' or 'bernstein')")
    if pull_mode == "hybrid":
        raise ValueError(
            "pull_mode='hybrid' is resolved by make_plan (it prices both "
            "candidate plans via choose_pull_mode); make_schedule only "
            "accepts the concrete modes 'row' and 'coord'")
    if pull_mode not in ("row", "coord"):
        raise ValueError(f"unknown pull_mode {pull_mode!r} "
                         f"(expected 'row' or 'coord')")
    if pull_width < 1:
        raise ValueError(f"pull_width must be >= 1, got {pull_width}")
    if K >= n:
        return Schedule(n, N, K, eps, delta, value_range, (), quant_err,
                        bound, pull_mode, pull_width)
    rounds: List[Round] = []
    n_l, eps_l, delta_l, t_prev, l = n, eps / 4.0, delta / 2.0, 0, 1
    while n_l > K:
        t_l = _round_pulls(n_l, K, eps_l, delta_l, N, value_range, quant_err,
                           bound)
        t_l = min(N, max(t_l, t_prev))  # nondecreasing, saturates at N
        n_keep = K + (n_l - K) // 2
        rounds.append(Round(index=l, n_arms=n_l, n_keep=n_keep, t_cum=t_l,
                            t_new=t_l - t_prev, eps_l=eps_l, delta_l=delta_l))
        n_l, t_prev, l = n_keep, t_l, l + 1
        eps_l, delta_l = 0.75 * eps_l, 0.5 * delta_l
    return Schedule(n, N, K, eps, delta, value_range, tuple(rounds),
                    quant_err, bound, pull_mode, pull_width)
