"""Static elimination schedules for BoundedME.

The key systems observation (DESIGN.md §3): lines 7-11 of Algorithm 1 reference
only (|S_l|, K, eps_l, delta_l, N) — never the data.  Given (n, N, K, eps,
delta) the entire round structure (survivor counts, cumulative pull counts) is
therefore *data independent* and can be computed at trace time.  The jitted
TPU program becomes a fixed cascade of static-shape matmuls + top-k masks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

from repro.core import bounds

__all__ = ["Round", "Schedule", "make_schedule"]


@dataclasses.dataclass(frozen=True)
class Round:
    """One elimination round of Algorithm 1 (static view)."""

    index: int          # l (1-based)
    n_arms: int         # |S_l| at the start of the round
    n_keep: int         # |S_{l+1}| = K + floor((|S_l|-K)/2)
    t_cum: int          # t_l: cumulative pulls per surviving arm
    t_new: int          # t_l - t_{l-1}: pulls issued this round
    eps_l: float
    delta_l: float


@dataclasses.dataclass(frozen=True)
class Schedule:
    """The full static pull/elimination plan."""

    n: int              # number of arms (may be a tile count on the TPU path)
    N: int              # reward-list length (may be a block count)
    K: int
    eps: float
    delta: float
    value_range: float
    rounds: Tuple[Round, ...]  # tuple => hashable => usable as a jit static

    @property
    def total_pulls(self) -> int:
        """Exact sample complexity (sum over rounds of survivors x new pulls)."""
        return sum(r.n_arms * r.t_new for r in self.rounds)

    @property
    def naive_pulls(self) -> int:
        return self.n * self.N

    @property
    def speedup(self) -> float:
        """Pull-count speedup over exhaustive search (>= 1 by Corollary 2)."""
        return self.naive_pulls / max(1, self.total_pulls)

    @property
    def final_pulls(self) -> int:
        return self.rounds[-1].t_cum if self.rounds else 0


def _round_pulls(n_l: int, K: int, eps_l: float, delta_l: float, N: int,
                 value_range: float) -> int:
    """t_l of Algorithm 1, line 7 (expanded per the Lemma 4 proof).

    Each arm needs an (eps_l/2, delta'_l/2)-accurate estimate where
    ``delta'_l = delta_l (floor((n_l-K)/2)+1) / (n_l-K)`` is the per-arm
    budget and the factor 2 covers the two one-sided deviation events.
    """
    gap = n_l - K
    if gap <= 0:
        return 0
    delta_eff = delta_l * (gap // 2 + 1) / (2.0 * gap)
    # deviation eps_l/2, confidence delta_eff
    return bounds.m_required(eps_l / 2.0, delta_eff, N, value_range)


def make_schedule(n: int, N: int, K: int = 1, eps: float = 0.1,
                  delta: float = 0.05, value_range: float = 1.0) -> Schedule:
    """Build the static round plan of Algorithm 1.

    eps_1 = eps/4, delta_1 = delta/2; eps_{l+1} = 3/4 eps_l,
    delta_{l+1} = delta_l/2; each round keeps K + floor((|S_l|-K)/2) arms.
    Cumulative pull counts are clamped to be nondecreasing and <= N.
    """
    if n < 1 or N < 1:
        raise ValueError(f"need n,N >= 1, got n={n} N={N}")
    if K >= n:
        return Schedule(n, N, K, eps, delta, value_range, ())
    rounds: List[Round] = []
    n_l, eps_l, delta_l, t_prev, l = n, eps / 4.0, delta / 2.0, 0, 1
    while n_l > K:
        t_l = _round_pulls(n_l, K, eps_l, delta_l, N, value_range)
        t_l = min(N, max(t_l, t_prev))  # nondecreasing, saturates at N
        n_keep = K + (n_l - K) // 2
        rounds.append(Round(index=l, n_arms=n_l, n_keep=n_keep, t_cum=t_l,
                            t_new=t_l - t_prev, eps_l=eps_l, delta_l=delta_l))
        n_l, t_prev, l = n_keep, t_l, l + 1
        eps_l, delta_l = 0.75 * eps_l, 0.5 * delta_l
    return Schedule(n, N, K, eps, delta, value_range, tuple(rounds))
