"""Classical fixed-confidence bandit baselines (i.i.d. bounds).

These are the "existing MAB methods" of the paper's comparison: they assume
rewards are i.i.d. draws from an infinite population and size their pulls
with Hoeffding, so their per-round pull counts are NOT capped by N.  We cap
*consumption* at N (reading past the list would be meaningless) but keep the
Hoeffding-sized accounting so the sample-complexity gap versus BoundedME is
visible — exactly the point of the MAB-BP setting.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import bounds
from repro.core.boundedme import BoundedMEResult
from repro.core.schedule import Schedule, Round

__all__ = ["median_elimination", "successive_elimination"]


def median_elimination(R: np.ndarray, K: int = 1, eps: float = 0.1,
                       delta: float = 0.05,
                       value_range: float = 1.0) -> BoundedMEResult:
    """Even-Dar et al. (2002) Median Elimination with Hoeffding pull counts."""
    n, N = R.shape
    alive = np.arange(n)
    sums = np.zeros(n, dtype=np.float64)
    t_prev, total, l = 0, 0, 1
    eps_l, delta_l = eps / 4.0, delta / 2.0
    rounds = []
    while alive.size > K:
        gap = alive.size - K
        delta_eff = delta_l * (gap // 2 + 1) / (2.0 * gap)
        t_l = bounds.hoeffding_required(eps_l / 2.0, delta_eff, value_range)
        t_read = min(t_l, N)  # cannot read past the finite list
        if t_read > t_prev:
            sums[alive] += R[alive, t_prev:t_read].sum(axis=1)
        total += alive.size * max(0, t_l - t_prev)  # Hoeffding accounting
        n_keep = K + gap // 2
        means = sums[alive] / max(1, t_read)
        keep = np.argpartition(-means, n_keep - 1)[:n_keep]
        alive = alive[keep]
        rounds.append(Round(l, alive.size, n_keep, t_l, t_l - t_prev,
                            eps_l, delta_l))
        t_prev = max(t_prev, t_read)
        eps_l, delta_l, l = 0.75 * eps_l, 0.5 * delta_l, l + 1
    means = sums[alive] / max(1, t_prev)
    order = np.argsort(-means)[:K]
    sched = Schedule(n, N, K, eps, delta, value_range, tuple(rounds))
    return BoundedMEResult(alive[order], means[order], total, len(rounds), sched)


def successive_elimination(R: np.ndarray, K: int = 1, eps: float = 0.1,
                           delta: float = 0.05, value_range: float = 1.0,
                           batch: int = 32) -> BoundedMEResult:
    """Even-Dar et al. (2006) successive elimination, Hoeffding radii.

    Pull all surviving arms ``batch`` times per sweep; drop any arm whose UCB
    falls below the K-th best LCB; stop when the radius is below eps/2 or K
    arms remain.  Consumption capped at the list length N.
    """
    n, N = R.shape
    alive = np.arange(n)
    sums = np.zeros(n, dtype=np.float64)
    t_acc = 0   # iid-accounted pulls per arm (can exceed N!)
    t_read = 0  # entries actually consumed from the finite list (<= N)
    total, sweeps = 0, 0
    delta_arm = delta / max(2, n)  # union bound over arms (crude)
    while alive.size > K:
        t_new = min(batch, max(0, N - t_read))
        if t_new:
            sums[alive] += R[alive, t_read:t_read + t_new].sum(axis=1)
            t_read += t_new
        t_acc += batch
        # accounting is iid-Hoeffding: an algorithm unaware of the finite
        # list must keep pulling (with replacement) to shrink its radius
        total += alive.size * batch
        sweeps += 1
        rad_iid = value_range * np.sqrt(np.log(1.0 / delta_arm)
                                        / (2.0 * t_acc))
        means = sums[alive] / t_read
        kth = np.partition(-means, K - 1)
        lcb_k = -kth[K - 1] - rad_iid
        keep = means + rad_iid >= lcb_k
        keep_idx = np.nonzero(keep)[0]
        if keep_idx.size >= K:
            alive = alive[keep_idx]
        if rad_iid <= eps / 2.0:
            break
    means = sums[alive] / max(1, t_read)
    order = np.argsort(-means)[:K]
    sched = Schedule(n, N, K, eps, delta, value_range, ())
    return BoundedMEResult(alive[order], means[order], total, sweeps, sched)
