"""Deterministic synthetic data: LM token streams + MIPS datasets.

The LM stream is a seeded Zipf-unigram / Markov-bigram mixture — learnable
structure so a few hundred training steps visibly reduce loss.  The MIPS
generators reproduce the paper's experimental settings: gaussian, uniform,
the adversarial Bernoulli construction of Fig. 1, and a low-rank
matrix-factorization proxy for the Netflix/Yahoo embeddings of Fig. 4.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["LMStream", "gaussian_dataset", "uniform_dataset",
           "adversarial_dataset", "mf_dataset"]


@dataclasses.dataclass
class LMStream:
    """Sharded deterministic LM batch stream."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    # fault-tolerance: the stream is indexable by step, so a restart resumes
    # at exactly the right batch (no data replay / skip)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # zipf unigram over a head of the vocab + bigram chain
        head = min(self.vocab, 4096)
        base = rng.zipf(1.3, size=(self.batch, self.seq + 1)) % head
        drift = np.cumsum(rng.integers(0, 3, size=(self.batch, self.seq + 1)),
                          axis=1)
        toks = ((base + drift) % self.vocab).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def gaussian_dataset(n: int, N: int, seed: int = 0
                     ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, N)).astype(np.float32),
            rng.normal(size=N).astype(np.float32))


def uniform_dataset(n: int, N: int, seed: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    return (rng.uniform(0, 1, size=(n, N)).astype(np.float32),
            rng.uniform(0, 1, size=N).astype(np.float32))


def adversarial_dataset(n: int, N: int, seed: int = 0) -> np.ndarray:
    """The paper's Fig-1 construction, directly as a reward matrix.

    Each arm's true mean is uniform in [0, 1]; rewards are Bernoulli and the
    oracle returns all 1-rewards before any 0-reward (rows sorted
    descending) to make arms maximally indistinguishable.
    """
    rng = np.random.default_rng(seed)
    means = rng.uniform(0, 1, size=n)
    ones = np.rint(means * N).astype(np.int64)
    R = np.zeros((n, N), dtype=np.float32)
    for i, k in enumerate(ones):  # sorted: 1s first = adversarial order
        R[i, :k] = 1.0
    return R


def mf_dataset(n: int, N: int, rank: int = 32, seed: int = 0,
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Matrix-factorization embedding proxy (Fig. 4 real-world stand-in).

    Low-rank structure with a heavy-tailed spectrum + noise, mimicking
    ALS/SGD item embeddings from recommender training.
    """
    rng = np.random.default_rng(seed)
    spectrum = 1.0 / np.sqrt(1 + np.arange(rank))
    U = rng.normal(size=(n, rank)) * spectrum
    Wd = rng.normal(size=(rank, N))
    V = (U @ Wd + 0.05 * rng.normal(size=(n, N))).astype(np.float32)
    u_q = rng.normal(size=rank) * spectrum
    q = (u_q @ Wd + 0.05 * rng.normal(size=N)).astype(np.float32)
    return V, q
