"""Exhaustive MIPS baseline with explicit cost accounting."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = ["SearchResult", "exact_mips"]


@dataclasses.dataclass
class SearchResult:
    topk: np.ndarray        # (K,) indices, best first
    scores: np.ndarray      # (K,) inner products (NOT divided by N)
    query_multiplies: int   # multiply count attributable to this query
    preprocess_multiplies: int = 0
    candidates: int = 0     # size of the exactly-rescored candidate set


def exact_mips(V: np.ndarray, q: np.ndarray, K: int = 1) -> SearchResult:
    scores = V @ q
    order = np.argsort(-scores)[:K]
    return SearchResult(order, scores[order], V.shape[0] * V.shape[1],
                        candidates=V.shape[0])
