"""MIPS baselines from the paper's comparison (Table 1 / Figs 2-4)."""

from repro.baselines.exact import SearchResult, exact_mips
from repro.baselines.lsh_mips import LSHIndex, build_lsh, lsh_mips
from repro.baselines.greedy_mips import GreedyIndex, build_greedy, greedy_mips
from repro.baselines.pca_mips import PCATree, build_pca_tree, pca_mips

__all__ = [
    "SearchResult", "exact_mips", "LSHIndex", "build_lsh", "lsh_mips",
    "GreedyIndex", "build_greedy", "greedy_mips", "PCATree",
    "build_pca_tree", "pca_mips",
]
