"""LSH-MIPS baseline (Shrivastava & Li 2014 / Neyshabur & Srebro 2015).

MIPS -> angular NNS via the Bachrach et al. (2014) Euclidean transform:
scale every v by 1/U (U = max norm) and append sqrt(1 - |v|^2) so all data
lie on the unit sphere; the query appends 0 and is normalized.  Then
sign-random-projection LSH with the standard amplification: ``b`` hyper hash
functions (OR), each an AND of ``a`` random projections.  Candidates from
matching buckets are exactly rescored.

Preprocessing cost: O(N n a b) projections — the Table 1 entry.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.baselines.exact import SearchResult

__all__ = ["LSHIndex", "build_lsh", "lsh_mips"]


def _transform_data(V: np.ndarray) -> Tuple[np.ndarray, float]:
    norms = np.linalg.norm(V, axis=1)
    U = float(norms.max()) or 1.0
    Vs = V / U
    aug = np.sqrt(np.maximum(0.0, 1.0 - (norms / U) ** 2))
    return np.concatenate([Vs, aug[:, None]], axis=1), U


def _transform_query(q: np.ndarray) -> np.ndarray:
    qn = np.linalg.norm(q) or 1.0
    return np.concatenate([q / qn, [0.0]])


@dataclasses.dataclass
class LSHIndex:
    planes: np.ndarray          # (b, a, N+1) random hyperplanes
    tables: List[Dict[int, np.ndarray]]
    V: np.ndarray               # original data (for exact rescoring)
    preprocess_multiplies: int


def _codes(planes: np.ndarray, X: np.ndarray) -> np.ndarray:
    """Pack a sign-projection AND-construction into integer bucket ids."""
    b, a, d = planes.shape
    proj = np.einsum("bad,nd->nba", planes, X) > 0
    weights = (1 << np.arange(a)).astype(np.int64)
    return proj.astype(np.int64) @ weights  # (n, b)


def build_lsh(V: np.ndarray, a: int = 8, b: int = 16,
              seed: int = 0) -> LSHIndex:
    rng = np.random.default_rng(seed)
    Vt, _ = _transform_data(V)
    planes = rng.normal(size=(b, a, Vt.shape[1]))
    codes = _codes(planes, Vt)
    tables: List[Dict[int, np.ndarray]] = []
    for t in range(b):
        buckets: Dict[int, List[int]] = {}
        for i, c in enumerate(codes[:, t]):
            buckets.setdefault(int(c), []).append(i)
        tables.append({k: np.asarray(v) for k, v in buckets.items()})
    pre = V.shape[0] * Vt.shape[1] * a * b
    return LSHIndex(planes, tables, V, pre)


def lsh_mips(index: LSHIndex, q: np.ndarray, K: int = 1) -> SearchResult:
    qt = _transform_query(q)
    qcodes = _codes(index.planes, qt[None, :])[0]  # (b,)
    cand: List[np.ndarray] = []
    for t, code in enumerate(qcodes):
        hit = index.tables[t].get(int(code))
        if hit is not None:
            cand.append(hit)
    query_cost = index.planes.shape[0] * index.planes.shape[1] * qt.size
    if not cand:
        return SearchResult(np.empty(0, np.int64), np.empty(0), query_cost,
                            index.preprocess_multiplies, 0)
    ids = np.unique(np.concatenate(cand))
    scores = index.V[ids] @ q
    query_cost += ids.size * q.size
    order = np.argsort(-scores)[:K]
    return SearchResult(ids[order], scores[order], query_cost,
                        index.preprocess_multiplies, ids.size)
