"""GREEDY-MIPS baseline (Yu et al., NIPS 2017).

Preprocessing: for every dimension j, the data indices sorted by v_i^(j)
(O(N n log n)).  Query phase: visit candidate (i, j) entries in decreasing
q^(j) v_i^(j) order with an N-way max-heap over dimensions (Greedy screening)
until ``budget`` distinct candidates are collected, then rescore exactly.
The budget B is the (implicit) efficiency/accuracy knob — no suboptimality
guarantee for non-uniform data, which is the paper's Motivation II contrast.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Tuple

import numpy as np

from repro.baselines.exact import SearchResult

__all__ = ["GreedyIndex", "build_greedy", "greedy_mips"]


@dataclasses.dataclass
class GreedyIndex:
    order_desc: np.ndarray      # (N, n) argsort of each column, descending
    V: np.ndarray
    preprocess_multiplies: int  # comparison count proxy for O(N n log n)


def build_greedy(V: np.ndarray) -> GreedyIndex:
    n, N = V.shape
    order_desc = np.argsort(-V, axis=0).T.copy()  # (N, n)
    pre = int(N * n * max(1, np.log2(max(2, n))))
    return GreedyIndex(order_desc, V, pre)


def greedy_mips(index: GreedyIndex, q: np.ndarray, K: int = 1,
                budget: int = 128) -> SearchResult:
    V, order = index.V, index.order_desc
    n, N = V.shape
    budget = min(budget, n)
    # heap entries: (-q_j * v_{i_r, j}, j, rank r); ranks advance per dim
    heap = []
    cost = 0
    for j in range(N):
        if q[j] == 0.0:
            continue
        col = order[j] if q[j] > 0 else order[j][::-1]
        val = q[j] * V[col[0], j]
        cost += 1
        heap.append((-val, j, 0, col))
    heapq.heapify(heap)
    seen = set()
    cand = []
    while heap and len(cand) < budget:
        negval, j, r, col = heapq.heappop(heap)
        i = int(col[r])
        if i not in seen:
            seen.add(i)
            cand.append(i)
        if r + 1 < n:
            val = q[j] * V[col[r + 1], j]
            cost += 1
            heapq.heappush(heap, (-val, j, r + 1, col))
    ids = np.asarray(cand, dtype=np.int64)
    scores = V[ids] @ q
    cost += ids.size * N
    order_k = np.argsort(-scores)[:K]
    return SearchResult(ids[order_k], scores[order_k], cost,
                        index.preprocess_multiplies, ids.size)
