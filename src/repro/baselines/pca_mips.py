"""PCA-MIPS baseline (Bachrach et al., RecSys 2014).

MIPS -> Euclidean NNS via the same augmentation as LSH-MIPS, then a PCA tree:
at depth t the data are split at the median of their projection onto the t-th
principal component.  A query descends to one leaf (optionally spilling to
sibling leaves within ``spill`` of the split) and exactly rescores the leaf.
Preprocessing: O(N^2 n) for the PCA + O(n log n) tree build (Table 1).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.baselines.exact import SearchResult
from repro.baselines.lsh_mips import _transform_data, _transform_query

__all__ = ["PCATree", "build_pca_tree", "pca_mips"]


@dataclasses.dataclass
class _Node:
    depth: int
    ids: Optional[np.ndarray] = None      # leaf only
    threshold: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None


@dataclasses.dataclass
class PCATree:
    components: np.ndarray   # (depth, N+1) principal directions
    root: _Node
    V: np.ndarray
    depth: int
    preprocess_multiplies: int


def _build(ids: np.ndarray, proj: np.ndarray, depth: int, max_depth: int) -> _Node:
    if depth >= max_depth or ids.size <= 1:
        return _Node(depth, ids=ids)
    vals = proj[ids, depth]
    thr = float(np.median(vals))
    left_mask = vals <= thr
    # guard degenerate splits (all-equal projections)
    if left_mask.all() or not left_mask.any():
        return _Node(depth, ids=ids)
    node = _Node(depth, threshold=thr)
    node.left = _build(ids[left_mask], proj, depth + 1, max_depth)
    node.right = _build(ids[~left_mask], proj, depth + 1, max_depth)
    return node


def build_pca_tree(V: np.ndarray, depth: int = 6) -> PCATree:
    Vt, _ = _transform_data(V)
    mu = Vt.mean(axis=0)
    X = Vt - mu
    # top-`depth` principal components via SVD
    _, _, vt = np.linalg.svd(X, full_matrices=False)
    comps = vt[:depth]
    proj = X @ comps.T  # (n, depth)
    root = _build(np.arange(V.shape[0]), proj, 0, depth)
    d = Vt.shape[1]
    pre = d * d * V.shape[0] + depth * V.shape[0] * d
    return PCATree(comps, root, V, depth, pre)


def pca_mips(tree: PCATree, q: np.ndarray, K: int = 1,
             spill: float = 0.0) -> SearchResult:
    qt = _transform_query(q)
    # queries are projected against the same centered components
    qproj = tree.components @ qt
    cost = tree.components.size
    leaves: List[np.ndarray] = []

    def descend(node: _Node):
        if node.ids is not None:
            leaves.append(node.ids)
            return
        v = qproj[node.depth]
        if v <= node.threshold + spill:
            descend(node.left)
        if v > node.threshold - spill:
            descend(node.right)

    descend(tree.root)
    ids = np.unique(np.concatenate(leaves)) if leaves else np.empty(0, np.int64)
    scores = tree.V[ids] @ q
    cost += ids.size * q.size
    order = np.argsort(-scores)[:K]
    return SearchResult(ids[order], scores[order], cost,
                        tree.preprocess_multiplies, ids.size)
