"""Dynamic table store: zero-rebuild streaming upserts/deletes (DESIGN.md §11).

The paper's headline claim is *no preprocessing* — so the serving stack
must absorb corpus churn at O(rows touched), never by rebuilding an
engine.  This package holds the versioned, capacity-slack mutable table
stores the serving engine mutates between micro-batch flushes:

  * :class:`~repro.store.dynamic_table.DynamicTableStore` — single-device
    store: preallocated capacity rounded to a tile multiple, live rows
    kept a dense prefix (swap-delete) so the fused kernel's existing
    traced-scalar ``n_valid`` masks exactly the dead suffix, jit-donated
    `dynamic_update_slice` writes, dirty-tile incremental int8
    re-quantization, and monotonic ``version`` / value-range counters;
  * :class:`~repro.store.sharded_table.ShardedTableStore` — the same
    contract over the PR-2 serving mesh: per-shard slot pools, a
    per-shard ``n_valid`` vector through `sharded_bounded_me_decode`,
    and the exact cross-shard merge untouched.

Both are consumed by `repro.launch.serve.MIPSServeEngine` — pass a store
where a static table was expected and call ``engine.apply_updates()``
(drained automatically at every `poll`).

Both stores expose a ``fault_hook`` attribute (DESIGN.md §13): a
callable run at the top of `flush_updates` that may raise
:class:`StoreFlushError` *before* any staged mutation is taken, so a
failed flush leaves the staged queue intact for retry — the flush
failure surface the serving runtime's fault-injection harness drives.
"""

from repro.store.dynamic_table import DynamicTableStore, StoreFlushError
from repro.store.sharded_table import ShardedTableStore

__all__ = ["DynamicTableStore", "ShardedTableStore", "StoreFlushError"]
