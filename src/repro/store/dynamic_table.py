"""Single-device dynamic table store (DESIGN.md §11).

The serving engines of PR 2/3 froze the item matrix at construction, so a
corpus change meant rebuilding the whole engine — exactly the amortization
burden the paper argues index-based MIPS must pay and BoundedME does not.
:class:`DynamicTableStore` removes it: row churn lands in O(rows touched)
device work, and the engine's compiled flush functions are reused across
arbitrary upsert/delete/append streams with **zero recompilation**.

The contract that makes this work (normative in DESIGN.md §11):

  * **Capacity slack.**  The device buffer is preallocated at
    ``capacity_rows`` = the requested capacity rounded *up* to a multiple
    of the arm-tile size.  Every compiled shape is a function of
    ``capacity_rows``, never of the live count, so growth within capacity
    is invisible to jit.
  * **Dense-prefix liveness.**  Live rows always occupy slots
    ``[0, n_live)``.  The fused cascade masks rows with a *prefix* bound
    (the traced-scalar ``n_valid`` added in PR 2), so a hole left by a
    delete could be neither masked nor safely zeroed (a zero row wins any
    all-negative ranking).  ``delete`` therefore swap-fills the hole with
    the last live row and zeroes the vacated tail slot: the free pool is
    always exactly the suffix ``[n_live, capacity_rows)``, and ``n_valid
    = n_live`` stays a correct mask after every mutation.  External ids
    stay stable through the moves via the slot <-> id indirection.
  * **Donated writes.**  Every device mutation is a
    `jax.lax.dynamic_update_slice` at a *traced* slot index inside a
    jitted function whose buffer argument is donated: one executable per
    store geometry, reused for every slot, no per-write allocation growth.
  * **Monotonic version.**  Every applied mutation bumps ``version``;
    consumers (the engine's LRU, its recall mirror) key their caches on
    it.  ``value_abs_max`` is likewise monotonic — it only grows, so a
    schedule calibrated on it stays a valid bound until growth is
    observed (DESIGN.md §11 value-range monotonicity).
  * **Dirty-tile shadow maintenance** (``precision='int8'``/``'int4'``/
    ``'pq'``).  The store maintains the tile-major quantized shadow
    (`repro.core.quantize`) the fused kernel consumes; a mutation marks
    only its arm-tile dirty and `flush_updates` re-encodes just those
    (1, n_blocks, R, C) slabs.  Per-(tile, block) cells are quantized
    independently — and pq code assignments are per-cell argmins against
    a *frozen* table-level codebook — so incremental maintenance is
    bit-identical to rebuilding the whole updated table's shadow from
    scratch.  `refresh_codebook` is the one recalibrating pq mutation
    (retrain + full re-encode, like `grow`).

Mutations are *staged* host-side (`upsert` / `delete` / `append`) and
applied in submission order by `flush_updates` — the engine drains them
between micro-batch flushes so in-flight queries never see a torn table.

Failure modes: rows must be (N,) float and finite (NaN/inf propagate into
every later score they touch); exceeding capacity raises at flush time
(`grow` reallocates, the one operation that *does* recompile); deleting
an unknown id raises.  The store is not thread-safe; drive it from the
engine's loop.
"""

from __future__ import annotations

import functools
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import (pq_encode, pq_train, quantize_tiles,
                                 quantize_tiles_int4)
from repro.obs.metrics import MetricsRegistry

__all__ = ["DynamicTableStore", "StoreFlushError"]


class StoreFlushError(RuntimeError):
    """A store's `flush_updates` was failed before applying anything.

    Raised by the store's ``fault_hook`` (installed e.g. by
    `repro.launch.faults.FaultInjector.attach`) at the *top* of
    `flush_updates`, before any staged mutation is taken: the staged
    queue is left intact, so the caller can keep serving the current
    table and retry the flush at its next poll (DESIGN.md §13 failure
    model).  Real I/O-backed stores would raise it for a failed
    persistence barrier; in this repo it is the typed flush-failure
    surface the serving runtime's fault tests drive.
    """


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_row(buf, row, slot):
    """Donated single-row write: ``buf[slot] = row`` at a traced index."""
    return jax.lax.dynamic_update_slice(buf, row[None, :], (slot, 0))


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _requant_tile(V8, vscale, slab, t):
    """Donated re-quantization of one dirty arm-tile of the int8 shadow.

    ``slab`` is the tile's updated fp32 rows in tile-major layout
    (1, n_blocks, R, C); ``t`` is the traced arm-tile index.  Quantizes
    the slab with the same `quantize_tiles` the full-table path uses and
    splices the (codes, scale) cells in place — bit-identical to a full
    re-quantization because cells are independent.
    """
    q8, scl = quantize_tiles(slab)
    V8 = jax.lax.dynamic_update_slice(V8, q8, (t, 0, 0, 0))
    vscale = jax.lax.dynamic_update_slice(vscale, scl, (t, 0))
    return V8, vscale


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _requant_tile_int4(P4, vscale, slab, t):
    """Donated re-quantization of one dirty arm-tile of the int4 shadow.

    Same contract as `_requant_tile`, on the nibble-packed tier: the
    spliced codes slab has stored width C/2 (`quantize_tiles_int4`).
    Per-(tile, block) cells are independent, so the splice is
    bit-identical to re-packing the whole updated table.
    """
    q4, scl = quantize_tiles_int4(slab)
    P4 = jax.lax.dynamic_update_slice(P4, q4, (t, 0, 0, 0))
    vscale = jax.lax.dynamic_update_slice(vscale, scl, (t, 0))
    return P4, vscale


@functools.partial(jax.jit, donate_argnums=(0,))
def _reencode_tile_pq(codes, slab, t, codebook):
    """Donated re-encode of one dirty arm-tile against the FROZEN codebook.

    pq assignments are per-cell independent argmins against table-level
    codebook state (`pq_encode`), so splicing one tile's codes is
    bit-identical to re-encoding the whole updated table against the same
    codebook — the store-tier analogue of the int8 dirty-tile rule.  The
    codebook itself never changes here; `refresh_codebook` is the one
    recalibrating mutation (DESIGN.md §11).
    """
    c = pq_encode(slab, codebook)
    return jax.lax.dynamic_update_slice(codes, c, (t, 0, 0, 0))


@jax.jit
def _quantize_full(V4):
    """Full-table tile quantization (store construction / `grow` only)."""
    return quantize_tiles(V4)


@jax.jit
def _quantize_full_int4(V4):
    """Full-table int4 pack (store construction / `grow` only)."""
    return quantize_tiles_int4(V4)


@functools.partial(jax.jit, static_argnames=("n_codes", "subdims"))
def _pq_train_full(V4, *, n_codes, subdims):
    """Codebook training (construction / `refresh_codebook` only)."""
    return pq_train(V4, n_codes=n_codes, subdims=subdims)


@jax.jit
def _pq_encode_full(V4, codebook):
    """Full-table pq assignment (construction / `grow` / refresh only)."""
    return pq_encode(V4, codebook)


def _call_donated(fn, *args):
    """Invoke a donating jitted op, silencing the CPU 'donation
    unimplemented' warning (harmless: CPU copies instead of aliasing)."""
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
        return fn(*args)


class DynamicTableStore:
    """Versioned, capacity-slack mutable item table for the serving stack.

    Wraps an (n, N) item matrix in a preallocated ``capacity_rows``-row
    device buffer (capacity rounded up to a ``tile`` multiple) whose live
    rows are a dense prefix ``[0, n_live)`` — `n_valid` for the fused
    cascade is always exactly ``n_live``, a traced scalar, so mutations
    never change a compiled shape.  Deletes swap-fill from the tail
    (stable external ids via slot <-> id maps); writes are jit-donated
    `dynamic_update_slice` ops; every applied mutation bumps the
    monotonic ``version``.  On the quantized tiers the store also
    maintains the tile-major shadow the fused kernel consumes, with
    dirty-tile incremental maintenance (DESIGN.md §11): per-tile
    (codes, scale) cells for 'int8', nibble-packed cells for 'int4', and
    per-cell code assignments against a frozen table-level codebook for
    'pq' — dirty tiles re-encode against that frozen codebook, so
    incremental maintenance stays bit-identical to a fresh build;
    `refresh_codebook` is the one recalibrating pq mutation (analogous
    to `grow`).

    Args:
      table: optional (n0, N) initial rows (any float dtype); row i gets
        external id ``ids[i]`` (default ``i``).
      dim: N when ``table`` is None (an empty store).
      capacity: minimum row capacity; default ``ceil(n0 * capacity_slack)``.
        Rounded up to a ``tile`` multiple either way.
      capacity_slack: headroom factor used when ``capacity`` is omitted.
      tile / block: cascade geometry this store serves (must match the
        engine's plan; the engine adopts the store's values).
      precision: 'fp32', 'int8', 'int4' or 'pq' — which quantized shadow
        (if any) to maintain for the serving path.  'int4' needs an even
        ``block``; 'pq' needs ``block`` divisible by ``pq_subdims``.
      pq_subdims / pq_codes: pq codebook geometry (precision='pq' only).
      codebook: optional pre-trained pq codebook
        ((n_blocks, block/pq_subdims, pq_codes, pq_subdims) f32) to adopt
        instead of training on the initial rows — how a fresh store
        reproduces an existing store's shadow byte-for-byte (see
        `snapshot`); ignored unless precision='pq'.
      ids: optional explicit external ids for the initial rows.

    Mutations stage host-side and apply on `flush_updates` in submission
    order.  ``value_abs_max`` tracks max|v| over every row ever applied
    (monotonic; deletes do not shrink it).
    """

    def __init__(self, table=None, *, dim: Optional[int] = None,
                 capacity: Optional[int] = None, capacity_slack: float = 1.5,
                 tile: int = 8, block: int = 512, precision: str = "fp32",
                 pq_subdims: int = 8, pq_codes: int = 16, codebook=None,
                 ids=None):
        if precision not in ("fp32", "int8", "int4", "pq"):
            raise ValueError(f"unknown precision {precision!r} "
                             f"(expected 'fp32', 'int8', 'int4' or 'pq')")
        if table is None:
            if dim is None:
                raise ValueError("need `table` or `dim`")
            init = np.zeros((0, int(dim)), np.float32)
        else:
            init = np.asarray(table, np.float32)
            if init.ndim != 2:
                raise ValueError(f"table must be 2D, got {init.shape}")
        n0, N = init.shape
        if capacity is None:
            capacity = max(n0, int(np.ceil(n0 * float(capacity_slack))))
        capacity = max(int(capacity), n0, 1)
        self.tile = int(tile)
        self.block = min(int(block), N)
        self.N = N
        self.capacity_rows = -(-capacity // self.tile) * self.tile
        self.n_tiles = self.capacity_rows // self.tile
        self.n_blocks = -(-N // self.block)
        self._col_pad = self.n_blocks * self.block - N
        self.precision = precision
        self.pq_subdims = int(pq_subdims)
        self.pq_codes = int(pq_codes)
        if precision == "int4" and self.block % 2 != 0:
            raise ValueError(f"precision='int4' needs an even block, "
                             f"got block={self.block}")
        if precision == "pq":
            if self.block % self.pq_subdims != 0:
                raise ValueError(
                    f"precision='pq' needs block divisible by pq_subdims, "
                    f"got block={self.block}, pq_subdims={self.pq_subdims}")
            if not 1 <= self.pq_codes <= 256:
                raise ValueError(f"pq_codes must be in [1, 256], "
                                 f"got {self.pq_codes}")

        self._host = np.zeros((self.capacity_rows, N), np.float32)
        self._host[:n0] = init
        self._dev = jnp.asarray(self._host)
        self._zero_row = jnp.zeros((N,), jnp.float32)

        if ids is None:
            ids = np.arange(n0, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if ids.shape != (n0,) or len(set(ids.tolist())) != n0:
                raise ValueError("ids must be unique and match table rows")
        self._slot_ids = np.full(self.capacity_rows, -1, np.int64)
        self._slot_ids[:n0] = ids
        self._id2slot: Dict[int, int] = {int(i): s
                                         for s, i in enumerate(ids)}
        self._next_id = int(ids.max()) + 1 if n0 else 0

        self.n_live = n0
        self.version = 0
        self._vmax = float(np.abs(init).max()) if init.size else 0.0
        self._staged: List[Tuple[str, int, Optional[np.ndarray]]] = []
        #: optional zero-arg callable invoked at the top of
        #: `flush_updates`; may raise `StoreFlushError` to fail the
        #: flush before anything is applied (fault injection surface)
        self.fault_hook = None
        #: private `repro.obs.metrics` registry; the serving runtime
        #: adopts it so `store_*` metrics appear in its exports.  The
        #: legacy counter attributes below are registry-backed
        #: properties — same names, same values, read-only.
        self.metrics = MetricsRegistry()
        self._c_upserts = self.metrics.counter(
            "store_upserts_total", "Applied row upserts.")
        self._c_deletes = self.metrics.counter(
            "store_deletes_total", "Applied row deletes.")
        self._c_rows_written = self.metrics.counter(
            "store_rows_written_total", "Donated device row writes.")
        self._c_flush_failures = self.metrics.counter(
            "store_flush_failures_total",
            "flush_updates calls failed by the fault hook.")
        self._c_tiles_requant = self.metrics.counter(
            "store_tiles_requantized_total",
            "Dirty arm-tiles re-encoded into the quantized shadow.")
        self._c_refreshes = self.metrics.counter(
            "store_codebook_refreshes_total",
            "Full pq codebook retrain + re-encode passes.")
        self.metrics.gauge(
            "store_live_rows", "Live rows (dense prefix length).",
        ).set_fn(lambda: self.n_live)
        self.metrics.gauge(
            "store_capacity_rows", "Preallocated row capacity.",
        ).set_fn(lambda: self.capacity_rows)
        self.metrics.gauge(
            "store_version", "Monotonic mutation version.",
        ).set_fn(lambda: self.version)
        self.metrics.gauge(
            "store_pending_updates", "Staged, not yet flushed mutations.",
        ).set_fn(lambda: len(self._staged))
        self.metrics.gauge(
            "store_value_abs_max",
            "Monotone max |v| over all applied rows.",
        ).set_fn(lambda: self._vmax)

        self._V8 = self._vscale = self._codebook = None
        if precision == "int8":
            self._V8, self._vscale = _quantize_full(self._tile_major_dev())
            jax.block_until_ready(self._vscale)
        elif precision == "int4":
            self._V8, self._vscale = _quantize_full_int4(
                self._tile_major_dev())
            jax.block_until_ready(self._vscale)
        elif precision == "pq":
            V4 = self._tile_major_dev()
            S = self.block // self.pq_subdims
            if codebook is not None:
                cb = jnp.asarray(codebook, jnp.float32)
                want = (self.n_blocks, S, self.pq_codes, self.pq_subdims)
                if cb.shape != want:
                    raise ValueError(f"codebook shape {cb.shape} != {want}")
                self._codebook = cb
            else:
                self._codebook = _pq_train_full(V4, n_codes=self.pq_codes,
                                                subdims=self.pq_subdims)
            self._V8 = _pq_encode_full(V4, self._codebook)
            jax.block_until_ready(self._V8)

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def n_upserts(self) -> int:
        """Applied row upserts (registry-backed)."""
        return int(self._c_upserts.total())

    @property
    def n_deletes(self) -> int:
        """Applied row deletes (registry-backed)."""
        return int(self._c_deletes.total())

    @property
    def rows_written(self) -> int:
        """Donated device row writes (registry-backed)."""
        return int(self._c_rows_written.total())

    @property
    def n_flush_failures(self) -> int:
        """Flushes failed by the fault hook (registry-backed)."""
        return int(self._c_flush_failures.total())

    @property
    def tiles_requantized(self) -> int:
        """Dirty tiles re-encoded into the shadow (registry-backed)."""
        return int(self._c_tiles_requant.total())

    @property
    def codebook_refreshes(self) -> int:
        """Full pq codebook retrain passes (registry-backed)."""
        return int(self._c_refreshes.total())

    # ---- geometry helpers -----------------------------------------------

    def _tile_major_dev(self):
        """Current buffer as the (n_tiles, n_blocks, R, C) kernel layout."""
        V = self._dev
        if self._col_pad:
            V = jnp.pad(V, ((0, 0), (0, self._col_pad)))
        return V.reshape(self.n_tiles, self.tile, self.n_blocks,
                         self.block).transpose(0, 2, 1, 3)

    def _tile_slab(self, t: int):
        """One arm-tile's fp32 rows in tile-major layout (1, n_blocks, R, C)."""
        rows = self._host[t * self.tile:(t + 1) * self.tile]
        if self._col_pad:
            rows = np.pad(rows, ((0, 0), (0, self._col_pad)))
        slab = rows.reshape(self.tile, self.n_blocks,
                            self.block).transpose(1, 0, 2)
        return jnp.asarray(slab[None])

    # ---- read side -------------------------------------------------------

    @property
    def n_valid(self) -> int:
        """The cascade's validity bound: live rows are exactly [0, n_live)."""
        return self.n_live

    @property
    def free_rows(self) -> int:
        """Capacity slack remaining (the suffix free pool)."""
        return self.capacity_rows - self.n_live

    @property
    def pending_updates(self) -> int:
        """Mutations staged but not yet applied by `flush_updates`."""
        return len(self._staged)

    @property
    def value_abs_max(self) -> float:
        """Monotonic max|v| over every row ever applied (never shrinks)."""
        return self._vmax

    def device_table(self):
        """The (capacity_rows, N) device buffer (live prefix + zero slack)."""
        return self._dev

    def quantized(self):
        """The tier's shadow artifacts, or None on the fp32 path.

        The 2-tuple `bounded_me_decode` takes as ``quantized=``:
        ``(V8, vscale)`` for 'int8', ``(P4 packed, vscale)`` for 'int4',
        ``(codes, codebook)`` for 'pq' (DESIGN.md §10/§11).
        """
        if self.precision == "fp32":
            return None
        if self.precision == "pq":
            return self._V8, self._codebook
        return self._V8, self._vscale

    def codebook(self):
        """The frozen pq codebook (table-level state), or None off-pq.

        Inject it into a fresh store built from `snapshot()` rows
        (``codebook=``) to reproduce this store's code shadow
        byte-for-byte without retraining.
        """
        return self._codebook

    def refresh_codebook(self) -> dict:
        """Retrain the pq codebook on the current live table and re-encode.

        The one *recalibrating* pq mutation (DESIGN.md §11): ordinary row
        churn re-encodes dirty tiles against the frozen codebook (cheap,
        bit-identical to a fresh build), which slowly degrades code
        fidelity as the data distribution drifts; this O(n N) refresh
        re-anchors it — analogous to `grow` in cost and in bumping
        ``version`` so every consumer cache invalidates.  Engines serving
        measured-error pq plans must re-measure ``quant_err`` afterwards
        (the bound was calibrated against the old codebook).

        Raises RuntimeError unless ``precision='pq'``.
        """
        if self.precision != "pq":
            raise RuntimeError(
                f"refresh_codebook() needs precision='pq', "
                f"got {self.precision!r}")
        t0 = time.perf_counter()
        V4 = self._tile_major_dev()
        self._codebook = _pq_train_full(V4, n_codes=self.pq_codes,
                                        subdims=self.pq_subdims)
        self._V8 = _pq_encode_full(V4, self._codebook)
        jax.block_until_ready(self._V8)
        self._c_refreshes.inc()
        self.version += 1
        return {"version": self.version,
                "refreshes": self.codebook_refreshes,
                "seconds": time.perf_counter() - t0}

    def host_table(self) -> np.ndarray:
        """Host mirror of the device buffer (read-only view; always fresh)."""
        v = self._host.view()
        v.flags.writeable = False
        return v

    def external_ids(self, slots) -> np.ndarray:
        """Map cascade row indices (slots) to external ids (-1 = dead)."""
        slots = np.asarray(slots)
        return self._slot_ids[np.clip(slots, 0, self.capacity_rows - 1)]

    def live_ids(self) -> np.ndarray:
        """External ids of the live rows, in slot order."""
        return self._slot_ids[:self.n_live].copy()

    def live_mask(self) -> np.ndarray:
        """Boolean (capacity_rows,) mask of live slots (the dense prefix)."""
        return self._slot_ids >= 0

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, ids) copies of the live prefix, in slot order.

        A fresh store built as ``DynamicTableStore(rows, ids=ids,
        capacity=capacity_rows)`` reproduces this store's buffers
        byte-for-byte — the equivalence the bit-identity tests assert.
        On the pq tier also pass ``codebook=self.codebook()``: codes are
        assignments against table-level codebook state, so the fresh
        store must adopt the same frozen codebook rather than retrain on
        its (possibly churned) initial rows.
        """
        return self._host[:self.n_live].copy(), self.live_ids()

    def page_state(self) -> dict:
        """Complete host-side page-out image of this store.

        Everything `from_page` needs to rebuild a store whose device
        buffers, quantized shadow, external-id maps, ``version``,
        ``value_abs_max`` and id allocator are bit-identical to this
        one: the `snapshot` rows/ids plus geometry, precision, the
        frozen pq codebook, and the monotonic scalars that a plain
        snapshot-rebuild would reset.  The tenancy layer's table
        registry (`repro.launch.tenancy.TableRegistry`) uses this to
        evict cold tables from device memory and page them back in on
        the next serve without violating bit-identity.  Staged (not yet
        flushed) mutations are carried along verbatim.  Churn *counters*
        (upserts/deletes/...) are observability, not table state, and
        restart at zero after a page round-trip.
        """
        rows, ids = self.snapshot()
        cb = (None if self._codebook is None
              else np.asarray(self._codebook).copy())
        return {"rows": rows, "ids": ids,
                "capacity_rows": self.capacity_rows,
                "tile": self.tile, "block": self.block,
                "precision": self.precision,
                "pq_subdims": self.pq_subdims, "pq_codes": self.pq_codes,
                "codebook": cb, "dim": self.N,
                "version": self.version, "value_abs_max": self._vmax,
                "next_id": self._next_id,
                "staged": list(self._staged)}

    @classmethod
    def from_page(cls, state: dict) -> "DynamicTableStore":
        """Rebuild a store from a `page_state` image (page-in).

        The returned store's device buffer, quantized shadow, id maps,
        ``version``, ``value_abs_max``, id allocator and staged-mutation
        queue all match the paged-out store exactly — serving through it
        is indistinguishable from never having evicted the table.
        """
        st = cls(state["rows"], dim=state["dim"],
                 capacity=state["capacity_rows"], tile=state["tile"],
                 block=state["block"], precision=state["precision"],
                 pq_subdims=state["pq_subdims"],
                 pq_codes=state["pq_codes"],
                 codebook=state["codebook"], ids=state["ids"])
        if st.capacity_rows != state["capacity_rows"]:
            raise ValueError(
                f"page-in capacity mismatch: rebuilt {st.capacity_rows} "
                f"rows != paged {state['capacity_rows']}")
        st.version = int(state["version"])
        st._vmax = max(st._vmax, float(state["value_abs_max"]))
        st._next_id = max(st._next_id, int(state["next_id"]))
        st._staged = list(state["staged"])
        return st

    def resident_bytes(self) -> int:
        """Device bytes this table pins while resident.

        The fp32 capacity buffer plus (on quantized tiers) the shadow:
        codes, scales, and the pq codebook.  This is the unit the
        tenancy registry's byte budget accounts in.
        """
        total = int(self._dev.nbytes)
        for arr in (self._V8, self._vscale, self._codebook):
            if arr is not None:
                total += int(arr.nbytes)
        return total

    # ---- write side (staged) --------------------------------------------

    def upsert(self, ext_id: int, row) -> None:
        """Stage an insert-or-overwrite of external id ``ext_id``.

        New ids append at slot ``n_live`` (capacity permitting); known ids
        overwrite in place.  Applied by `flush_updates`.
        """
        row = np.asarray(row, np.float32)
        if row.shape != (self.N,):
            raise ValueError(f"row shape {row.shape} != ({self.N},)")
        ext_id = int(ext_id)
        if ext_id < 0:
            raise ValueError(f"ids must be >= 0, got {ext_id}")
        self._next_id = max(self._next_id, ext_id + 1)
        self._staged.append(("upsert", ext_id, row.copy()))

    def append(self, row) -> int:
        """Stage an insert under a fresh auto-assigned id; returns the id."""
        ext_id = self._next_id
        self.upsert(ext_id, row)
        return ext_id

    def delete(self, ext_id: int) -> None:
        """Stage removal of external id ``ext_id`` (raises at flush if
        unknown).  The vacated slot is swap-filled from the tail so live
        rows remain the dense prefix the cascade's ``n_valid`` masks."""
        self._staged.append(("delete", int(ext_id), None))

    # ---- apply -----------------------------------------------------------

    def _dev_write(self, row_dev, slot: int) -> None:
        self._dev = _call_donated(_write_row, self._dev, row_dev,
                                  np.int32(slot))
        self._c_rows_written.inc()

    def _apply_upsert(self, ext_id: int, row: np.ndarray, dirty: set) -> None:
        slot = self._id2slot.get(ext_id)
        if slot is None:
            if self.n_live >= self.capacity_rows:
                raise RuntimeError(
                    f"store full: {self.n_live}/{self.capacity_rows} rows "
                    f"live; call grow() (recompiles) or provision more "
                    f"capacity_slack")
            slot = self.n_live
            self._id2slot[ext_id] = slot
            self._slot_ids[slot] = ext_id
            self.n_live += 1
        self._host[slot] = row
        self._dev_write(jnp.asarray(row), slot)
        dirty.add(slot // self.tile)
        self._vmax = max(self._vmax, float(np.abs(row).max(initial=0.0)))
        self._c_upserts.inc()
        self.version += 1

    def _apply_delete(self, ext_id: int, dirty: set) -> None:
        slot = self._id2slot.pop(ext_id, None)
        if slot is None:
            raise KeyError(f"delete of unknown id {ext_id}")
        last = self.n_live - 1
        if slot != last:
            # swap-fill the hole from the tail: one row moved, ids stable
            moved = self._slot_ids[last]
            self._host[slot] = self._host[last]
            self._dev_write(jnp.asarray(self._host[slot]), slot)
            self._slot_ids[slot] = moved
            self._id2slot[int(moved)] = slot
            dirty.add(slot // self.tile)
        self._host[last] = 0.0
        self._dev_write(self._zero_row, last)
        self._slot_ids[last] = -1
        dirty.add(last // self.tile)
        self.n_live -= 1
        self._c_deletes.inc()
        self.version += 1

    def flush_updates(self) -> dict:
        """Apply every staged mutation in submission order; returns stats.

        O(rows touched) device work: one donated row write per upsert,
        two per interior delete, plus — on the quantized tiers — one
        dirty-tile shadow update per touched arm-tile (int8/int4
        re-quantization, or pq re-encode against the frozen codebook;
        each bit-identical to a full rebuild of the updated table).
        Bumps ``version`` once per applied mutation.  Returns
        ``{"applied", "version", "requantized_tiles", "seconds"}``.

        On a failing mutation (unknown delete, capacity exhausted) the
        failing op is dropped, the ops staged after it stay staged, and
        the quantized shadow is still re-synchronized to everything
        already applied before the error re-raises — the store is never
        torn.

        If a ``fault_hook`` is installed it runs first and may raise
        `StoreFlushError` *before* anything is applied: the staged queue
        is untouched (nothing applied, nothing dropped) and the caller
        retries at its next flush opportunity.
        """
        t0 = time.perf_counter()
        if self.fault_hook is not None:
            try:
                self.fault_hook()
            except Exception:
                # nothing taken yet: every staged op survives for retry
                self._c_flush_failures.inc()
                raise
        dirty: set = set()
        applied = 0
        staged, self._staged = self._staged, []
        try:
            for i, (op, ext_id, row) in enumerate(staged):
                if op == "upsert":
                    self._apply_upsert(ext_id, row, dirty)
                else:
                    self._apply_delete(ext_id, dirty)
                applied += 1
        except Exception:
            # drop the failing op, keep its successors staged (in front
            # of anything staged while we ran), then fall through to the
            # shadow re-sync below before re-raising
            self._staged = staged[applied + 1:] + self._staged
            raise
        finally:
            if self.precision != "fp32" and dirty:
                for t in sorted(dirty):
                    if self.precision == "int8":
                        self._V8, self._vscale = _call_donated(
                            _requant_tile, self._V8, self._vscale,
                            self._tile_slab(t), np.int32(t))
                    elif self.precision == "int4":
                        self._V8, self._vscale = _call_donated(
                            _requant_tile_int4, self._V8, self._vscale,
                            self._tile_slab(t), np.int32(t))
                    else:   # pq: re-encode against the frozen codebook
                        self._V8 = _call_donated(
                            _reencode_tile_pq, self._V8,
                            self._tile_slab(t), np.int32(t),
                            self._codebook)
                self._c_tiles_requant.inc(len(dirty))
            if applied:
                jax.block_until_ready(self._dev)
        return {"applied": applied, "version": self.version,
                "requantized_tiles": len(dirty)
                if self.precision != "fp32" else 0,
                "seconds": time.perf_counter() - t0}

    def grow(self, capacity: int) -> None:
        """Reallocate to a larger capacity (rounded to a tile multiple).

        The one mutation that changes compiled shapes and therefore
        recompiles — consumers must rebuild their plans/flush functions
        (the engine does this when it observes ``capacity_rows``
        changed).  O(n N): copies the buffer and re-quantizes the shadow
        from scratch.
        """
        capacity = max(int(capacity), self.n_live)
        new_rows = -(-capacity // self.tile) * self.tile
        if new_rows <= self.capacity_rows:
            return
        host = np.zeros((new_rows, self.N), np.float32)
        host[:self.capacity_rows] = self._host
        slot_ids = np.full(new_rows, -1, np.int64)
        slot_ids[:self.capacity_rows] = self._slot_ids
        self._host, self._slot_ids = host, slot_ids
        self.capacity_rows = new_rows
        self.n_tiles = new_rows // self.tile
        self._dev = jnp.asarray(self._host)
        if self.precision == "int8":
            self._V8, self._vscale = _quantize_full(self._tile_major_dev())
        elif self.precision == "int4":
            self._V8, self._vscale = _quantize_full_int4(
                self._tile_major_dev())
        elif self.precision == "pq":
            # the codebook is frozen table-level state: growth re-encodes
            # against it (only `refresh_codebook` ever retrains)
            self._V8 = _pq_encode_full(self._tile_major_dev(),
                                       self._codebook)
        self.version += 1

    # ---- observability ---------------------------------------------------

    def jit_cache_size(self) -> int:
        """Total compiled-executable count of the store's jitted write ops.

        The zero-recompilation tests snapshot this (plus the engine's
        flush-fn cache) after warmup and assert it never grows across a
        mutation stream.
        """
        return int(_write_row._cache_size() + _requant_tile._cache_size()
                   + _quantize_full._cache_size()
                   + _requant_tile_int4._cache_size()
                   + _quantize_full_int4._cache_size()
                   + _reencode_tile_pq._cache_size()
                   + _pq_train_full._cache_size()
                   + _pq_encode_full._cache_size())

    def stats(self) -> dict:
        """Counters: live/capacity rows, version, churn totals."""
        return {"n_live": self.n_live, "capacity_rows": self.capacity_rows,
                "utilization": self.n_live / max(1, self.capacity_rows),
                "version": self.version, "upserts": self.n_upserts,
                "deletes": self.n_deletes, "rows_written": self.rows_written,
                "tiles_requantized": self.tiles_requantized,
                "codebook_refreshes": self.codebook_refreshes,
                "value_abs_max": self._vmax,
                "flush_failures": self.n_flush_failures,
                "pending": len(self._staged)}
