"""Sharded dynamic table store over the serving mesh (DESIGN.md §11).

:class:`ShardedTableStore` extends the `DynamicTableStore` contract to the
PR-2 multi-device serving engine: the capacity buffer is row-sharded over
the mesh's model axis (`distributed.specs.serving_table_sharding`), every
shard owns an independent slot pool with its own dense live prefix, and
the store exports the **per-shard** ``n_valid`` vector that
`sharded_bounded_me_decode` masks with inside each shard's cascade.  The
exact cross-shard merge is untouched: shards still emit fp32-exact
candidate scores and the global top-K is an argmax over them — a shard
whose live count just changed contributes exactly its live rows, nothing
else.

Updates route by id: a known id overwrites in place on its owning shard;
a new id appends to the shard with the most free slots (lowest index on
ties), so shards stay balanced under sustained growth without any row
ever migrating between shards.  Deletes swap-fill *within* the owning
shard's region, preserving each shard's dense prefix independently.

Device writes go through one jitted, buffer-donating
`dynamic_update_slice` whose output sharding is pinned to the serving
layout, so a row write touches only the owning shard's device memory and
never re-shards the table.  Zero-recompilation holds exactly as in the
single-device store: compiled shapes depend only on the (static) capacity
geometry, live counts ride in as a traced (shards,) vector.

No quantized shadow (int8/int4/pq) is maintained here — the sharded
quantized paths pack, train and encode shard-locally in-jit per flush
(DESIGN.md §10), which keeps quantization consistent with each shard's
own rows at any live count; the store therefore always reports
``precision='fp32'`` and engines pass their own precision knob through
`sharded_bounded_me_decode` instead (pq additionally needs a measured
``quant_err`` calibrated via `measured_plan_quant_err`).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.specs import serving_table_sharding
from repro.obs.metrics import MetricsRegistry
from repro.store.dynamic_table import _call_donated

__all__ = ["ShardedTableStore"]


class ShardedTableStore:
    """Mutable, versioned item table row-sharded over the serving mesh.

    Per-shard slot pools of ``cap_local`` rows (the global capacity split
    evenly and rounded up to a ``tile`` multiple per shard); live rows are
    a dense prefix of every shard region, exported as the per-shard
    ``n_valid`` vector (`n_valid_vector`) the sharded cascade masks with.
    New ids append to the shard with the most free capacity; deletes
    swap-fill within their shard.  Monotonic ``version`` and
    ``value_abs_max`` follow the `DynamicTableStore` contract; the exact
    cross-shard merge of `sharded_bounded_me_decode` is preserved because
    masking happens inside each shard's cascade, before any candidate is
    emitted.

    Args:
      table: optional (n0, N) initial rows, distributed contiguously and
        evenly across shards.
      mesh: the serving mesh; ``model_axis`` names the row-sharding axis.
      dim: N when ``table`` is None.
      capacity / capacity_slack / tile / block / ids: as in
        `DynamicTableStore` (capacity is global; each shard gets
        ``cap_local = round_up(ceil(capacity / shards), tile)`` rows).
    """

    def __init__(self, table=None, *, mesh, model_axis: str = "model",
                 dim: Optional[int] = None, capacity: Optional[int] = None,
                 capacity_slack: float = 1.5, tile: int = 8,
                 block: int = 512, ids=None):
        if table is None:
            if dim is None:
                raise ValueError("need `table` or `dim`")
            init = np.zeros((0, int(dim)), np.float32)
        else:
            init = np.asarray(table, np.float32)
            if init.ndim != 2:
                raise ValueError(f"table must be 2D, got {init.shape}")
        n0, N = init.shape
        self.mesh = mesh
        self.model_axis = model_axis
        self.n_shards = int(mesh.shape[model_axis])
        S = self.n_shards
        if capacity is None:
            capacity = max(n0, int(np.ceil(n0 * float(capacity_slack))))
        capacity = max(int(capacity), n0, S)
        self.tile = int(tile)
        self.block = min(int(block), N)
        self.N = N
        per_shard = -(-capacity // S)
        self.cap_local = -(-per_shard // self.tile) * self.tile
        self.capacity_rows = S * self.cap_local
        self.precision = "fp32"

        self._host = np.zeros((self.capacity_rows, N), np.float32)
        self._slot_ids = np.full(self.capacity_rows, -1, np.int64)
        self._id2slot: Dict[int, int] = {}
        self._n_live = np.zeros(S, np.int64)
        if ids is None:
            ids = np.arange(n0, dtype=np.int64)
        else:
            ids = np.asarray(ids, np.int64)
            if ids.shape != (n0,) or len(set(ids.tolist())) != n0:
                raise ValueError("ids must be unique and match table rows")
        # contiguous, even initial distribution: shard s takes the next
        # n0//S (+1 for the first n0%S shards) rows
        counts = [n0 // S + (1 if s < n0 % S else 0) for s in range(S)]
        if max(counts, default=0) > self.cap_local:
            raise ValueError("initial table exceeds per-shard capacity")
        row = 0
        for s, c in enumerate(counts):
            base = s * self.cap_local
            self._host[base:base + c] = init[row:row + c]
            self._slot_ids[base:base + c] = ids[row:row + c]
            for j in range(c):
                self._id2slot[int(ids[row + j])] = base + j
            self._n_live[s] = c
            row += c
        self._next_id = int(ids.max()) + 1 if n0 else 0

        self._sharding = serving_table_sharding(mesh, model_axis)
        self._dev = jax.device_put(jnp.asarray(self._host), self._sharding)
        self._zero_row = jnp.zeros((N,), jnp.float32)
        self._write = jax.jit(
            lambda buf, r, slot: jax.lax.dynamic_update_slice(
                buf, r[None, :], (slot, 0)),
            donate_argnums=(0,), out_shardings=self._sharding)

        self.version = 0
        self._vmax = float(np.abs(init).max()) if init.size else 0.0
        self._staged: List[Tuple[str, int, Optional[np.ndarray]]] = []
        #: optional zero-arg callable run at the top of `flush_updates`;
        #: may raise `StoreFlushError` to fail the flush with every
        #: staged op intact (fault injection surface, DESIGN.md §13)
        self.fault_hook = None
        #: private `repro.obs.metrics` registry (same `store_*` families
        #: as `DynamicTableStore`); the legacy counters below are
        #: registry-backed read-only properties.
        self.metrics = MetricsRegistry()
        self._c_upserts = self.metrics.counter(
            "store_upserts_total", "Applied row upserts.")
        self._c_deletes = self.metrics.counter(
            "store_deletes_total", "Applied row deletes.")
        self._c_rows_written = self.metrics.counter(
            "store_rows_written_total", "Donated device row writes.")
        self._c_flush_failures = self.metrics.counter(
            "store_flush_failures_total",
            "flush_updates calls failed by the fault hook.")
        self.metrics.gauge(
            "store_live_rows", "Live rows summed over shards.",
        ).set_fn(lambda: self.n_live)
        self.metrics.gauge(
            "store_capacity_rows", "Preallocated row capacity (global).",
        ).set_fn(lambda: self.capacity_rows)
        self.metrics.gauge(
            "store_version", "Monotonic mutation version.",
        ).set_fn(lambda: self.version)
        self.metrics.gauge(
            "store_pending_updates", "Staged, not yet flushed mutations.",
        ).set_fn(lambda: len(self._staged))
        self.metrics.gauge(
            "store_value_abs_max",
            "Monotone max |v| over all applied rows.",
        ).set_fn(lambda: self._vmax)

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def n_upserts(self) -> int:
        """Applied row upserts (registry-backed)."""
        return int(self._c_upserts.total())

    @property
    def n_deletes(self) -> int:
        """Applied row deletes (registry-backed)."""
        return int(self._c_deletes.total())

    @property
    def rows_written(self) -> int:
        """Donated device row writes (registry-backed)."""
        return int(self._c_rows_written.total())

    @property
    def n_flush_failures(self) -> int:
        """Flushes failed by the fault hook (registry-backed)."""
        return int(self._c_flush_failures.total())

    # ---- read side -------------------------------------------------------

    @property
    def n_live(self) -> int:
        """Total live rows across all shards."""
        return int(self._n_live.sum())

    @property
    def free_rows(self) -> int:
        """Free slots summed over every shard's suffix pool."""
        return self.capacity_rows - self.n_live

    @property
    def pending_updates(self) -> int:
        """Mutations staged but not yet applied by `flush_updates`."""
        return len(self._staged)

    @property
    def value_abs_max(self) -> float:
        """Monotonic max|v| over every row ever applied."""
        return self._vmax

    def n_valid_vector(self) -> np.ndarray:
        """Per-shard live counts (shards,) — the cascade's validity bounds."""
        return self._n_live.astype(np.int32).copy()

    def device_table(self):
        """The (capacity_rows, N) row-sharded device buffer."""
        return self._dev

    def host_table(self) -> np.ndarray:
        """Host mirror (read-only view; always in sync with the device)."""
        v = self._host.view()
        v.flags.writeable = False
        return v

    def external_ids(self, slots) -> np.ndarray:
        """Map global row indices (slots) to external ids (-1 = dead)."""
        slots = np.asarray(slots)
        return self._slot_ids[np.clip(slots, 0, self.capacity_rows - 1)]

    def live_ids(self) -> np.ndarray:
        """External ids of all live rows, in global slot order."""
        return self._slot_ids[self._slot_ids >= 0].copy()

    def live_mask(self) -> np.ndarray:
        """Boolean (capacity_rows,) mask of live slots (dense per shard)."""
        return self._slot_ids >= 0

    # ---- write side ------------------------------------------------------

    def upsert(self, ext_id: int, row) -> None:
        """Stage insert-or-overwrite; new ids route to the emptiest shard."""
        row = np.asarray(row, np.float32)
        if row.shape != (self.N,):
            raise ValueError(f"row shape {row.shape} != ({self.N},)")
        ext_id = int(ext_id)
        if ext_id < 0:
            raise ValueError(f"ids must be >= 0, got {ext_id}")
        self._next_id = max(self._next_id, ext_id + 1)
        self._staged.append(("upsert", ext_id, row.copy()))

    def append(self, row) -> int:
        """Stage an insert under a fresh auto-assigned id; returns the id."""
        ext_id = self._next_id
        self.upsert(ext_id, row)
        return ext_id

    def delete(self, ext_id: int) -> None:
        """Stage removal; swap-fills within the owning shard's region."""
        self._staged.append(("delete", int(ext_id), None))

    # ---- apply -----------------------------------------------------------

    def _dev_write(self, row_dev, slot: int) -> None:
        self._dev = _call_donated(self._write, self._dev, row_dev,
                                  np.int32(slot))
        self._c_rows_written.inc()

    def _route(self) -> int:
        free = self.cap_local - self._n_live
        s = int(np.argmax(free))
        if free[s] <= 0:
            raise RuntimeError(
                f"store full: {self.n_live}/{self.capacity_rows} rows live "
                f"across {self.n_shards} shards; provision more capacity")
        return s

    def _apply_upsert(self, ext_id: int, row: np.ndarray) -> None:
        slot = self._id2slot.get(ext_id)
        if slot is None:
            s = self._route()
            slot = s * self.cap_local + int(self._n_live[s])
            self._id2slot[ext_id] = slot
            self._slot_ids[slot] = ext_id
            self._n_live[s] += 1
        self._host[slot] = row
        self._dev_write(jnp.asarray(row), slot)
        self._vmax = max(self._vmax, float(np.abs(row).max(initial=0.0)))
        self._c_upserts.inc()
        self.version += 1

    def _apply_delete(self, ext_id: int) -> None:
        slot = self._id2slot.pop(ext_id, None)
        if slot is None:
            raise KeyError(f"delete of unknown id {ext_id}")
        s = slot // self.cap_local
        last = s * self.cap_local + int(self._n_live[s]) - 1
        if slot != last:
            moved = self._slot_ids[last]
            self._host[slot] = self._host[last]
            self._dev_write(jnp.asarray(self._host[slot]), slot)
            self._slot_ids[slot] = moved
            self._id2slot[int(moved)] = slot
        self._host[last] = 0.0
        self._dev_write(self._zero_row, last)
        self._slot_ids[last] = -1
        self._n_live[s] -= 1
        self._c_deletes.inc()
        self.version += 1

    def flush_updates(self) -> dict:
        """Apply staged mutations in order; returns ``{"applied",
        "version", "requantized_tiles", "seconds"}`` (the tile counter is
        always 0 here — the sharded int8 path quantizes in-jit).  A
        failing op is dropped and its successors stay staged, as in
        `DynamicTableStore.flush_updates`.  An installed ``fault_hook``
        runs first and may raise `StoreFlushError` with the staged queue
        untouched."""
        t0 = time.perf_counter()
        if self.fault_hook is not None:
            try:
                self.fault_hook()
            except Exception:
                self._c_flush_failures.inc()
                raise
        applied = 0
        staged, self._staged = self._staged, []
        try:
            for op, ext_id, row in staged:
                if op == "upsert":
                    self._apply_upsert(ext_id, row)
                else:
                    self._apply_delete(ext_id)
                applied += 1
        except Exception:
            self._staged = staged[applied + 1:] + self._staged
            raise
        if applied:
            jax.block_until_ready(self._dev)
        return {"applied": applied, "version": self.version,
                "requantized_tiles": 0,
                "seconds": time.perf_counter() - t0}

    # ---- observability ---------------------------------------------------

    def jit_cache_size(self) -> int:
        """Compiled-executable count of this store's write op (for the
        zero-recompilation assertions)."""
        return int(self._write._cache_size())

    def resident_bytes(self) -> int:
        """Device bytes this table pins across the pool while resident.

        The sharded store is fp32-only (quantization happens in-jit per
        flush), so this is just the preallocated capacity buffer summed
        over shards.  The tenancy registry counts these bytes against
        its budget but never pages a sharded table (no `page_state`:
        per-shard slot pools are device-pool state, so sharded tenants
        are auto-pinned).
        """
        return int(self._dev.nbytes)

    def stats(self) -> dict:
        """Counters: per-shard occupancy, version, churn totals."""
        return {"n_live": self.n_live, "capacity_rows": self.capacity_rows,
                "cap_local": self.cap_local, "n_shards": self.n_shards,
                "per_shard_live": self._n_live.tolist(),
                "utilization": self.n_live / max(1, self.capacity_rows),
                "version": self.version, "upserts": self.n_upserts,
                "deletes": self.n_deletes, "rows_written": self.rows_written,
                "value_abs_max": self._vmax,
                "pending": len(self._staged)}
