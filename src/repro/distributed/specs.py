"""PartitionSpec trees for params / optimizer / caches / batches.

Name+shape-based rules over the flattened param paths.  2D "FSDP-style"
sharding for very large models (weights sharded over *both* data and model)
is applied when the per-chip bf16 param bytes would otherwise exceed
``fsdp_threshold`` — this is what lets grok-1-314B's optimizer state fit a
v5e (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "tree_pspecs",
           "batch_axes", "serving_table_sharding"]


def serving_table_sharding(mesh: Mesh, model_axis: str = "model"):
    """NamedSharding placing an (n, N) item matrix row-sharded for serving.

    The serving engine (`launch/serve.py`) device_puts its table with this
    before the first request so `sharded_bounded_me_decode`'s shard_map
    finds each row shard already resident on its device — no resharding
    collective on the first flush (DESIGN.md §7).
    """
    from jax.sharding import NamedSharding
    return NamedSharding(mesh, P(model_axis, None))


def batch_axes(mesh: Mesh, global_batch: int):
    """('pod','data') filtered to the mesh, dropped if batch not divisible."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and global_batch % size == 0:
        return axes
    # try data-only (e.g. batch 16 on a (2,16,16) mesh)
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def _key_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspecs(cfg: ArchConfig, abstract_params, mesh: Mesh,
                 fsdp: bool = False):
    """P-spec tree matching init_params(cfg) structure."""
    msize = mesh.shape["model"]
    ep_ok = cfg.n_experts > 0 and cfg.n_experts % msize == 0
    di_ok = cfg.ssm_heads > 0 and cfg.d_inner % msize == 0 \
        and cfg.ssm_heads % msize == 0
    fsdp_axis = "data" if (fsdp and "data" in mesh.axis_names) else None

    def spec(path, leaf):
        key = _key_str(path)
        name = key.split("/")[-1]
        r = leaf.ndim
        lead = lambda k: (None,) * (r - k)  # leading stack dims
        is_expert = "moe" in key or (cfg.family == "moe"
                                     and name in ("w_gate", "w_up", "w_down",
                                                  "router"))
        if name in ("embed", "unembed"):
            return P("model", fsdp_axis)
        if name == "enc_pos":
            return P(None, None)
        if name.endswith("wq") or name == "bq":
            return P(*lead(2), fsdp_axis, "model") if r >= 2 \
                else P(*lead(1), "model")
        if name.endswith(("wk", "wv")) or name in ("bk", "bv"):
            # kv head counts rarely divide the model axis: replicate heads
            return P(*lead(2), fsdp_axis, None) if r >= 2 else P(*lead(1), None)
        if name.endswith("wo"):
            return P(*lead(2), "model", fsdp_axis)
        if is_expert:
            if name == "router":
                return P(*lead(2), None, None)
            if name in ("w_gate", "w_up"):        # (..., E, d, ff)
                return (P(*lead(3), "model", fsdp_axis, None) if ep_ok
                        else P(*lead(3), None, fsdp_axis, "model"))
            if name == "w_down":                  # (..., E, ff, d)
                return (P(*lead(3), "model", None, fsdp_axis) if ep_ok
                        else P(*lead(3), None, "model", fsdp_axis))
        if name in ("w_gate", "w_up"):            # dense mlp (..., d, ff)
            return P(*lead(2), fsdp_axis, "model")
        if name == "w_down":                      # (..., ff, d)
            return P(*lead(2), "model", fsdp_axis)
        if name == "b_up":
            return P(*lead(1), "model")
        if name in ("wz", "wx"):                  # mamba (..., d, di)
            return P(*lead(2), fsdp_axis, "model" if di_ok else None)
        if name == "out_proj":                    # (..., di, d)
            return P(*lead(2), "model" if di_ok else None, fsdp_axis)
        if name == "norm_w" and "layers" not in key.split("/")[-2:]:
            pass
        return P(*(None,) * r)

    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def batch_pspecs(mesh: Mesh, global_batch: int, batch: dict):
    """Batch tree specs: leading dim on `batch_axes`, rest replicated."""
    axes = batch_axes(mesh, global_batch)

    def spec(path, leaf):
        return P(axes, *(None,) * (leaf.ndim - 1))
    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_pspecs(mesh: Mesh, global_batch: int, abstract_caches,
                 seq_axes=None):
    """Caches: batch-shard dim B; KV sequence on `seq_axes` (default model)."""
    baxes = batch_axes(mesh, global_batch)
    kvseq = seq_axes if seq_axes is not None else (
        "model" if "model" in mesh.axis_names else None)

    def spec(path, leaf):
        key = _key_str(path)
        name = key.split("/")[-1]
        r = leaf.ndim
        if name in ("k", "v"):      # (L, B, S, KV, D) or (periods, B, S, KV, D)
            return P(*(None,) * (r - 4), baxes, kvseq, None, None)
        if name in ("ck", "cv"):    # (L, B, S_enc, H, D)
            return P(*(None,) * (r - 4), baxes, None, "model", None)
        if name == "h":             # (L, B, H, Sd, P) / (periods, nm, B, ...)
            b_at = 1 if r == 5 else 2
            return P(*(None,) * b_at, baxes, *(None,) * (r - b_at - 1))
        return P(*(None,) * r)

    return jax.tree_util.tree_map_with_path(spec, abstract_caches)


def tree_pspecs(tree, like_specs=None, default=P()):
    """Replicated specs for everything (scalars, schedules, rng)."""
    return jax.tree.map(lambda leaf: P(*(None,) * getattr(leaf, "ndim", 0)),
                        tree)
