"""Logical-axis sharding, shard_map compat, and the sharded decode engine.

Model code annotates activations/params with *logical* axes ('batch',
'vocab', 'ff', 'heads', 'experts', 'kvseq', ...).  The launcher binds a mesh
and a logical->mesh translation; smoke tests bind nothing and every
annotation becomes a no-op.  This keeps the model definition identical from
1 CPU device to the 512-chip multi-pod mesh.

This module also hosts :func:`sharded_bounded_me_decode` — the multi-device
serving primitive (DESIGN.md §7): each shard of an arm-sharded item matrix
runs the PR-1 fused cascade locally under `shard_map` (its own flat
schedule, survivor set and accumulator stay on-chip), emits its top-K
candidates with exact scores and bound gaps, and a cheap all-gather merge
takes the global top-K over exact scores so the (eps, delta) guarantee
holds globally, not per shard.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES", "logical_mesh", "current_mesh", "shard", "spec_of",
    "named_sharding", "shard_map_compat", "sharded_bounded_me_decode",
    "make_shard_plan", "dispatch_lane_stats",
]


def dispatch_lane_stats(rounds_used, *, schedule, lanes: int,
                        filled: int) -> dict:
    """Per-dispatch lane accounting for one fused-cascade launch.

    A dispatch always runs ``lanes`` kernel lanes; ``filled`` of them
    carry real queries (the rest are padding the scheduler could not
    backfill in time).  ``rounds_used`` is the adaptive early-exit
    round per lane — ``(B,)`` single-device or ``(B, shards)`` sharded
    (each shard certifies independently; a lane's executed pulls are its
    per-shard mean) — or None on non-adaptive dispatches (every lane
    runs the full schedule).

    Returns a plain dict: ``occupancy`` (filled lanes), ``lane_util``
    (filled / lanes), ``executed_pull_frac`` (pulls actually executed by
    the *filled* lanes, as a fraction of the schedule's full pull
    budget — 1.0 when non-adaptive), and ``wasted_lane_frac`` (the pull
    budget burned on padding lanes).  Schedulers aggregate these per
    dispatch; they are the kernel-side half of the runtime's
    ``stats()["lanes"]`` block.
    """
    import numpy as np

    from repro.core.schedule import pulls_through_round

    lanes = max(1, int(lanes))
    filled = max(0, min(int(filled), lanes))
    if rounds_used is None or filled == 0:
        frac = 1.0
    else:
        r = np.asarray(rounds_used)[:filled]
        if r.ndim == 1:
            r = r[:, None]          # unify: (filled, shards)
        pulls = np.asarray(pulls_through_round(schedule), np.float64)
        total = max(1.0, float(pulls[-1]))
        idx = np.clip(r.astype(np.int64), 0, len(pulls) - 1)
        frac = float(pulls[idx].mean() / total)
    return {
        "occupancy": filled,
        "lane_util": filled / lanes,
        "executed_pull_frac": frac,
        "wasted_lane_frac": (lanes - filled) / lanes,
    }


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` across jax versions.

    Newer jax exposes it at top level with a ``check_vma`` kwarg; 0.4.x only
    has `jax.experimental.shard_map.shard_map` with ``check_rep``.  Every
    shard_map in this repo goes through here so version skew is handled in
    one place.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

AxisBinding = Union[str, Tuple[str, ...], None]

# default logical axis -> mesh axis binding for the production meshes
LOGICAL_RULES: Dict[str, AxisBinding] = {
    "batch": ("pod", "data"),   # 'pod' silently dropped on single-pod meshes
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,           # GQA kv counts rarely divide the model axis
    "ff": "model",
    "experts": "model",
    "expert_cap": None,
    "kvseq": "model",           # sequence-sharded KV cache at decode
    "seq": None,
    "embed": None,
    "state": None,
    "dinner": "model",          # mamba inner dim (bound per-config)
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Dict[str, AxisBinding] = {}


_CTX = _Ctx()


@contextlib.contextmanager
def logical_mesh(mesh: Mesh, rules: Optional[Dict[str, AxisBinding]] = None):
    """Bind a mesh + logical rules for `shard` annotations (and pjit specs)."""
    prev = (_CTX.mesh, _CTX.rules)
    merged = dict(LOGICAL_RULES)
    if rules:
        merged.update(rules)
    # drop bindings to axes the mesh doesn't have (e.g. 'pod' on single pod)
    def _filter(b: AxisBinding) -> AxisBinding:
        names = mesh.axis_names
        if b is None:
            return None
        if isinstance(b, str):
            return b if b in names else None
        kept = tuple(a for a in b if a in names)
        return kept or None
    _CTX.mesh = mesh
    _CTX.rules = {k: _filter(v) for k, v in merged.items()}
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    """The mesh bound by the innermost `logical_mesh`, or None."""
    return _CTX.mesh


def spec_of(*logical_axes: Optional[str]) -> P:
    """Translate logical axes to a PartitionSpec under the bound rules.

    A mesh axis may appear only once in a spec; if two logical axes bind to
    the same mesh axis (e.g. 'experts' and 'ff' both on 'model'), the first
    keeps it and later ones are replicated.
    """
    used: set = set()
    out = []
    for a in logical_axes:
        b = _CTX.rules.get(a) if a else None
        if b is None:
            out.append(None)
            continue
        bt = (b,) if isinstance(b, str) else tuple(b)
        bt = tuple(x for x in bt if x not in used)
        used.update(bt)
        out.append(bt if len(bt) > 1 else (bt[0] if bt else None))
    return P(*out)


def shard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint if a mesh is bound; identity otherwise."""
    if _CTX.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec_of(*logical_axes)))


def named_sharding(*logical_axes: Optional[str]) -> NamedSharding:
    """NamedSharding for the bound mesh; requires `logical_mesh` active."""
    assert _CTX.mesh is not None, "no mesh bound"
    return NamedSharding(_CTX.mesh, spec_of(*logical_axes))


# ---------------------------------------------------------------------------
# Sharded serving engine: shard-local fused cascades + exact cross-shard merge
# ---------------------------------------------------------------------------


def make_shard_plan(n: int, N: int, n_shards: int, *, K: int = 1,
                    eps: float = 0.05, delta: float = 0.05,
                    value_range: float = 4.0, tile: int = 8,
                    block: int = 512, precision: str = "fp32",
                    bound: str = "hoeffding", pull_mode: str = "row",
                    coord_block: int = 128,
                    quant_err: Optional[float] = None,
                    pq_subdims: int = 8, pq_codes: int = 16):
    """Shard-local BlockedPlan + padding geometry for an arm-sharded table.

    Splits an (n, N) item matrix into ``n_shards`` row shards of
    ``n_local = ceil(n / n_shards)`` arms (the last shard is padded with
    ``n_pad = n_shards * n_local - n`` zero rows when n is ragged) and
    calibrates the per-shard cascade so the *global* (eps, delta) guarantee
    survives sharding (DESIGN.md §7):

    * ``delta`` is split across shards by union bound (each shard runs at
      ``delta / n_shards``);
    * padding rows (ragged zero rows, and any caller padding past
      ``n_valid`` such as a padded vocab) are masked *inside* each shard's
      cascade via the dynamic ``n_valid`` bound of `bounded_me_decode`, so
      they can never occupy survivor or candidate slots — no shard-local K
      inflation is needed;
    * ``k_out`` asks each shard for one candidate beyond its top-K so the
      merge can report per-candidate bound gaps (margin over the best
      non-returned survivor);
    * ``precision='int8'``/``'int4'``/``'pq'`` calibrates each shard's
      plan with quantization-widened bounds (DESIGN.md §10); quantization
      itself is shard-local (per-tile scales — or the pq codebook — over
      the shard's own rows).  ``quant_err`` forwards a *measured* per-pull
      error bound (`measured_plan_quant_err`); it is required for 'pq',
      which has no a-priori worst-case model;
    * ``bound`` selects the certification radius family of the adaptive
      early-exit path (DESIGN.md §12) — certification is *shard-local*
      (each shard certifies its own top-K at its own ``delta / n_shards``
      budget), so the exact cross-shard merge argument is untouched;
    * ``pull_mode`` / ``coord_block`` select the reward stream
      (DESIGN.md §14) — the coord/hybrid schedule is likewise
      *shard-local* (each shard prices its own (n_local, N) geometry;
      'hybrid' resolves per shard plan, identically on every shard since
      all shards share one geometry), and merge scores stay exact, so the
      pull mode never touches the cross-shard merge argument.

    Returns ``(plan, n_local, n_pad, k_out)``.
    """
    from repro.core.boundedme_jax import make_plan

    if not 1 <= n_shards:
        raise ValueError(f"need n_shards >= 1, got {n_shards}")
    if not 1 <= K <= n:
        raise ValueError(f"need 1 <= K <= n, got K={K} n={n}")
    n_local = -(-n // n_shards)
    n_pad = n_shards * n_local - n
    K_local = min(K, n_local)
    plan = make_plan(n_local, N, K=K_local, eps=eps, delta=delta / n_shards,
                     value_range=value_range, tile=tile, block=block,
                     precision=precision, bound=bound, pull_mode=pull_mode,
                     coord_block=coord_block, quant_err=quant_err,
                     pq_subdims=pq_subdims, pq_codes=pq_codes)
    k_out = max(K_local, min(K_local + 1, plan.k_out_cap, n_local))
    return plan, n_local, n_pad, k_out


def sharded_bounded_me_decode(table, Q, key, *, mesh: Mesh, K: int = 1,
                              model_axis: str = "model", batch_axes=None,
                              n_valid: Optional[int] = None,
                              eps: float = 0.05, delta: float = 0.05,
                              value_range: float = 4.0, tile: int = 8,
                              block: int = 512, final_exact: bool = True,
                              use_pallas: Optional[bool] = None,
                              precision: str = "fp32",
                              adaptive: bool = False,
                              bound: str = "hoeffding",
                              pull_mode: str = "row",
                              coord_block: int = 128,
                              quant_err: Optional[float] = None,
                              pq_subdims: int = 8, pq_codes: int = 16,
                              return_candidates: bool = False):
    """Multi-device batched-decode MIPS: per-shard fused cascade + exact merge.

    The serving engine's distributed hot path (DESIGN.md §7).  The item
    matrix ``table`` (n, N) is sharded on rows over ``model_axis``; under
    `shard_map` each shard runs the single-dispatch fused BoundedME cascade
    (`bounded_me_decode`) on its own ``n_local`` arms — per-shard flat
    schedule, survivor set and accumulator never leave the device — and
    emits its local top-K candidate ids, *exact* scores and bound gaps.
    The merge all-gathers only those O(shards * K) floats per query and
    takes the global top-K over exact scores.

    Why the global (eps, delta) guarantee holds: the shard owning the
    global optimum returns a candidate within eps of it with probability
    >= 1 - delta/shards (union bound over shards); candidate scores
    entering the merge are exact inner products (the flat schedule's
    coverage completion when ``final_exact=True``, or an explicit dense
    rescore of the k_out candidates otherwise), so the cross-shard argmax
    introduces no additional estimation error.

    Args:
      table: (n, N) float item matrix, rows = arms.  n need not divide the
        shard count — ragged tables are zero-padded to
        ``shards * ceil(n/shards)`` rows and padding can never win (see
        :func:`make_shard_plan`).
      Q: (B, N) query batch; B must be divisible by the ``batch_axes``
        mesh extent when batch-sharded.
      key: PRNG key; one block permutation is shared by the whole batch
        and all shards (identical columns per round => dense MXU rounds).
      mesh: the device mesh; ``model_axis`` names the arm-sharding axis.
      K: global top-K to return.
      batch_axes: mesh axis (or tuple) to shard the query batch over, or
        None for a replicated batch.
      n_valid: number of *real* rows if the caller already padded ``table``
        (e.g. a padded vocab); defaults to n.  Either a global int (rows
        past it are masked, prefix semantics as before) or a per-shard
        (shards,) int vector of live-row counts — the layout a
        `ShardedTableStore` (DESIGN.md §11) exports, where every shard
        region has its own dense live prefix; the vector may be traced,
        so live-count changes never recompile.  Rows past the bound are
        masked *inside* each shard's cascade, before the merge.
      eps / delta / value_range / tile / block: cascade calibration knobs,
        as in `make_plan`; delta is split across shards internally.
      final_exact: complete survivors to full coverage on-shard so merge
        scores are exact (default).  With False, an explicit (B, k_out, N)
        gather-rescore supplies the exact merge scores instead — cheaper
        per shard when N is huge and the schedule saturates early.
      use_pallas: force/deny the fused kernel (default auto: TPU only).
      precision: 'fp32' (default), 'int8', 'int4' or 'pq' — each shard
        samples on its own quantized tiles (scalar int grids or pq codes
        trained on the shard's rows) under quantization-widened bounds
        (DESIGN.md §10); candidates entering the merge are still fp32
        exact (coverage completion at fp32, or the quantized path's fp32
        candidate rescore), so the exact-merge argument is untouched.
        'pq' requires an explicit ``quant_err`` (see
        `measured_plan_quant_err`); ``pq_subdims``/``pq_codes`` size the
        per-subspace codebooks.
      adaptive / bound: per-query adaptive early exit (DESIGN.md §12),
        certified *shard-locally*: each shard freezes its own cascade as
        soon as its local top-K is certified under its ``delta / shards``
        budget and the ``bound`` radius family; merge scores stay exact
        (the adaptive path always rescores its candidates in fp32), so
        the exact cross-shard merge — and with it the global
        (eps, delta) argument — is untouched.  ``adaptive=False`` is
        bit-identical to the pre-adaptive path.
      pull_mode / coord_block: reward stream per shard (DESIGN.md §14) —
        'row' (default), 'coord' (narrow feature tiles; shard-local
        coordinate schedules over the shard's own (n_local, N) geometry)
        or 'hybrid' (each shard resolves to the cheaper concrete mode —
        deterministically identical across shards, which all share one
        geometry).  Merge scores remain exact inner products under every
        mode, so the exact cross-shard merge is untouched.
      return_candidates: also return the pre-merge per-shard candidate
        sets — a dict of ``ids/scores/gaps`` arrays shaped
        (B, shards, k_out) — for diagnostics and tests.

    Returns:
      ``(ids (B, K) int32, scores (B, K) f32, gaps (B, K) f32)`` — scores
      are exact mean products (q . v)/N; ``gaps[b, j]`` is candidate j's
      margin over its *source shard's* best non-returned survivor (+inf
      when the shard had no spare survivor), a per-candidate certificate of
      how decisively it won shard-locally.  With ``adaptive=True`` a
      ``rounds_used (B, shards) int32`` element is appended (each shard's
      per-query exit round); with ``return_candidates=True`` the
      candidates dict is appended last.
    """
    from repro.core.boundedme_jax import bounded_me_decode

    if use_pallas is None:
        from repro.kernels import ops as _kops
        use_pallas = _kops.on_tpu()
    table = jnp.asarray(table)
    Q = jnp.asarray(Q)
    n, N = table.shape
    if n_valid is None:
        n_valid = n
    n_shards = mesh.shape[model_axis]
    plan, n_local, n_pad, k_out = make_shard_plan(
        n, N, n_shards, K=K, eps=eps, delta=delta, value_range=value_range,
        tile=tile, block=block, precision=precision, bound=bound,
        pull_mode=pull_mode, coord_block=coord_block, quant_err=quant_err,
        pq_subdims=pq_subdims, pq_codes=pq_codes)
    if n_pad:
        table = jnp.pad(table, ((0, n_pad), (0, 0)))
    key = jnp.asarray(key)
    neg = jnp.float32(-jnp.inf)
    if getattr(n_valid, "ndim", 0) == 1:
        # per-shard live counts (a ShardedTableStore's n_valid_vector):
        # every shard region carries its own dense live prefix
        nv_vec = jnp.asarray(n_valid, jnp.int32)
    else:
        # global prefix bound -> the per-shard prefix it induces; jnp so
        # a traced scalar (e.g. under an outer jit) keeps working
        nv_vec = jnp.clip(jnp.asarray(n_valid)
                          - jnp.arange(n_shards) * n_local,
                          0, n_local).astype(jnp.int32)

    def local(table_l, Q_l, key_l, nv_l):
        # rows of this shard at or past its live bound (ragged zero
        # padding, caller padding such as a padded vocab, or a dynamic
        # store's dead suffix) are masked *inside* the cascade: they can
        # never evict a true winner from the survivor set, so no
        # shard-local K inflation is needed
        n_valid_l = nv_l[0]
        out = bounded_me_decode(
            table_l, Q_l, key_l, plan=plan, final_exact=final_exact,
            use_pallas=use_pallas, k_out=k_out,
            n_valid=n_valid_l, adaptive=adaptive)         # (B_loc, k_out)
        if adaptive:
            ids, scores, rounds_l = out
        else:
            ids, scores = out
            rounds_l = jnp.zeros((ids.shape[0],), jnp.int32)
        if not final_exact:
            # exact cross-shard rescore: merge decisions must compare exact
            # inner products, never block-mean estimates
            safe = jnp.clip(ids, 0, table_l.shape[0] - 1)
            scores = jnp.einsum("bkc,bc->bk", table_l[safe], Q_l,
                                preferred_element_type=jnp.float32)
            scores = scores / jnp.float32(N)
        gids = ids + jax.lax.axis_index(model_axis) * n_local
        # bound gap: margin over the shard's best non-returned survivor
        if k_out > plan.K:
            thr = scores[:, k_out - 1:k_out]               # (B_loc, 1)
            gaps = scores - thr
        else:
            gaps = jnp.full_like(scores, jnp.inf)
        # belt-and-braces for the merge: in-cascade masking already keeps
        # padding out of the candidates, but a shard with fewer than k_out
        # valid arms still emits filler entries — keep them at -inf
        scores = jnp.where(ids < n_valid_l, scores, neg)
        B_loc = ids.shape[0]
        all_ids = jax.lax.all_gather(gids, model_axis, axis=1)
        all_sc = jax.lax.all_gather(scores, model_axis, axis=1)
        all_gap = jax.lax.all_gather(gaps, model_axis, axis=1)
        all_rnd = jax.lax.all_gather(rounds_l, model_axis, axis=1)
        cands = (all_ids, all_sc, all_gap)                 # (B_loc, S, k_out)
        flat_ids = all_ids.reshape(B_loc, -1)
        flat_sc = all_sc.reshape(B_loc, -1)
        flat_gap = all_gap.reshape(B_loc, -1)
        vals, pos = jax.lax.top_k(flat_sc, K)
        top_ids = jnp.take_along_axis(flat_ids, pos, axis=1)
        top_gaps = jnp.take_along_axis(flat_gap, pos, axis=1)
        return top_ids, vals, top_gaps, all_rnd, cands

    kspec = P(*([None] * key.ndim))
    out2 = P(batch_axes, None)
    out3 = P(batch_axes, None, None)
    fn = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(model_axis, None), P(batch_axes, None), kspec,
                  P(model_axis)),
        out_specs=(out2, out2, out2, out2, (out3, out3, out3)))
    ids, scores, gaps, rounds, cands = fn(table, Q, key, nv_vec)
    out = [ids, scores, gaps]
    if adaptive:
        out.append(rounds)     # (B, shards): each shard's per-query exit
    if return_candidates:
        out.append({"ids": cands[0], "scores": cands[1], "gaps": cands[2]})
    return tuple(out)
