"""Logical-axis sharding: rules + a no-op-safe constraint helper.

Model code annotates activations/params with *logical* axes ('batch',
'vocab', 'ff', 'heads', 'experts', 'kvseq', ...).  The launcher binds a mesh
and a logical->mesh translation; smoke tests bind nothing and every
annotation becomes a no-op.  This keeps the model definition identical from
1 CPU device to the 512-chip multi-pod mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES", "logical_mesh", "current_mesh", "shard", "spec_of",
    "named_sharding", "shard_map_compat",
]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` across jax versions.

    Newer jax exposes it at top level with a ``check_vma`` kwarg; 0.4.x only
    has `jax.experimental.shard_map.shard_map` with ``check_rep``.  Every
    shard_map in this repo goes through here so version skew is handled in
    one place.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)

AxisBinding = Union[str, Tuple[str, ...], None]

# default logical axis -> mesh axis binding for the production meshes
LOGICAL_RULES: Dict[str, AxisBinding] = {
    "batch": ("pod", "data"),   # 'pod' silently dropped on single-pod meshes
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,           # GQA kv counts rarely divide the model axis
    "ff": "model",
    "experts": "model",
    "expert_cap": None,
    "kvseq": "model",           # sequence-sharded KV cache at decode
    "seq": None,
    "embed": None,
    "state": None,
    "dinner": "model",          # mamba inner dim (bound per-config)
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Dict[str, AxisBinding] = {}


_CTX = _Ctx()


@contextlib.contextmanager
def logical_mesh(mesh: Mesh, rules: Optional[Dict[str, AxisBinding]] = None):
    """Bind a mesh + logical rules for `shard` annotations (and pjit specs)."""
    prev = (_CTX.mesh, _CTX.rules)
    merged = dict(LOGICAL_RULES)
    if rules:
        merged.update(rules)
    # drop bindings to axes the mesh doesn't have (e.g. 'pod' on single pod)
    def _filter(b: AxisBinding) -> AxisBinding:
        names = mesh.axis_names
        if b is None:
            return None
        if isinstance(b, str):
            return b if b in names else None
        kept = tuple(a for a in b if a in names)
        return kept or None
    _CTX.mesh = mesh
    _CTX.rules = {k: _filter(v) for k, v in merged.items()}
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def spec_of(*logical_axes: Optional[str]) -> P:
    """Translate logical axes to a PartitionSpec under the bound rules.

    A mesh axis may appear only once in a spec; if two logical axes bind to
    the same mesh axis (e.g. 'experts' and 'ff' both on 'model'), the first
    keeps it and later ones are replicated.
    """
    used: set = set()
    out = []
    for a in logical_axes:
        b = _CTX.rules.get(a) if a else None
        if b is None:
            out.append(None)
            continue
        bt = (b,) if isinstance(b, str) else tuple(b)
        bt = tuple(x for x in bt if x not in used)
        used.update(bt)
        out.append(bt if len(bt) > 1 else (bt[0] if bt else None))
    return P(*out)


def shard(x, *logical_axes: Optional[str]):
    """with_sharding_constraint if a mesh is bound; identity otherwise."""
    if _CTX.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec_of(*logical_axes)))


def named_sharding(*logical_axes: Optional[str]) -> NamedSharding:
    assert _CTX.mesh is not None, "no mesh bound"
    return NamedSharding(_CTX.mesh, spec_of(*logical_axes))
