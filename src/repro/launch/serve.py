"""Serving launcher: batched prefill + greedy decode with MIPS logits.

The paper's feature in production position: `--mips boundedme` replaces the
full unembedding matvec at every decode step with the BoundedME bandit
(per-query (eps, delta) knob, zero preprocessing — the vocab table can be
hot-swapped between requests with no index rebuild).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --mips boundedme --eps 0.1 --tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mips", default="exact",
                    choices=["exact", "boundedme"])
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, mips_mode=args.mips, mips_eps=args.eps,
                              mips_delta=args.delta)

    if cfg.mips_mode == "boundedme":
        # the decode hot path runs the whole bandit as ONE fused kernel
        # dispatch per batch (DESIGN.md §3); surface the static plan so the
        # (eps, delta) <-> pull-count trade is visible at launch
        from repro.core.schedule import flatten_schedule
        from repro.kernels.ops import on_tpu
        from repro.models.steps import make_mips_plan
        plan = make_mips_plan(cfg, K=1)
        flat = flatten_schedule(plan.schedule, final_coverage=True)
        path = ("fused pallas_call, dispatches_per_decode_batch=1"
                if on_tpu() else "jnp scan fallback (non-TPU backend)")
        print(f"[serve] fused cascade: rounds={len(plan.schedule.rounds)} "
              f"grid_steps={flat.n_steps} "
              f"pull_speedup={plan.schedule.speedup:.2f}x path={path}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    t0 = time.time()
    _, caches = prefill_step(params, cfg, prompt, cache_len=cache_len, **kw)
    jax.block_until_ready(caches)
    t_prefill = time.time() - t0

    dfn = jax.jit(lambda p, c, t, pos, k: decode_step(p, cfg, c, t, pos,
                                                      key=k))
    tok = prompt[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.tokens):
        pos = jnp.int32(P + i)
        nxt, caches = dfn(params, caches, tok, pos,
                          jax.random.PRNGKey(i))
        out.append(np.asarray(nxt))
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} mips={cfg.mips_mode} "
          f"eps={cfg.mips_eps} batch={B}")
    print(f"[serve] prefill {P} toks: {t_prefill*1e3:.1f} ms; "
          f"decode {args.tokens} toks: {t_decode*1e3:.1f} ms "
          f"({t_decode/args.tokens*1e3:.2f} ms/tok)")
    print(f"[serve] first sequences: {gen[0][:16].tolist()}")


if __name__ == "__main__":
    main()
