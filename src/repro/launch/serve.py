"""Serving CLI: request-loop / runtime driver + LM decode demo.

The paper's feature in production position: `--mips boundedme` replaces the
full unembedding matvec at every decode step with the BoundedME bandit
(per-query (eps, delta) knob, zero preprocessing — the vocab table can be
hot-swapped between requests with no index rebuild).

The serving classes themselves live in `repro.launch.engine`
(`MIPSServeEngine`, `ServeRuntime`, `CascadeExecutor`, `QuantizedLRU`) and
`repro.launch.admission` (priority classes, typed results, degradation
ladder); they are re-exported here for backward compatibility.  This
module owns the *driving*: seeded reproducible arrival traces
(`arrival_trace`), the virtual-clock stream driver (`simulate_stream`),
CLI argument validation, and three entry points:

* ``--loop`` — the PR-2 micro-batching request loop (`MIPSServeEngine`):

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
          --smoke --loop --requests 256 --batch 8 --deadline-ms 2

* ``--loop --runtime`` — the continuous-batching async runtime
  (DESIGN.md §13: admission control, priority classes, overload
  shedding via the eps degradation ladder, fault injection):

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
          --smoke --loop --runtime --requests 512 --pattern bursty \
          --queue-capacity 32 --eps-floor 0.4 \
          --inject-error-rate 0.05 --inject-latency-rate 0.05

* the original batched prefill + greedy decode demo:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
          --smoke --mips boundedme --eps 0.1 --tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.admission import (STATUSES,  # noqa: F401
                                    AdmissionController,
                                    DegradationLadder, PriorityClass,
                                    ServeResult)
from repro.launch.engine import (CascadeExecutor,  # noqa: F401
                                 MIPSServeEngine, QuantizedLRU,
                                 ServeRuntime)
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step

__all__ = ["QuantizedLRU", "MIPSServeEngine", "ServeRuntime",
           "CascadeExecutor", "PriorityClass", "ServeResult",
           "arrival_trace", "simulate_stream", "main"]

#: namespace tag so trace streams never alias other default_rng users
_TRACE_ROOT = 0x7AC3


def arrival_trace(n: int, *, interarrival_ms: float = 0.1,
                  pattern: str = "uniform", seed: int = 0,
                  burst_factor: float = 8.0, burst_len: int = 16,
                  tail: float = 1.5) -> np.ndarray:
    """Reproducible (n,) arrival times in seconds for a query stream.

    Patterns (all with mean spacing ``interarrival_ms`` except bursty's
    heavy tail):

      * ``uniform`` — exactly ``i * interarrival_ms`` (deterministic,
        seed-independent; the PR-2 default, byte-identical to the old
        driver);
      * ``poisson`` — i.i.d. exponential gaps (memoryless open-loop
        traffic);
      * ``bursty`` — geometric-length bursts of arrivals spaced
        ``interarrival_ms / burst_factor`` apart, separated by
        Pareto(``tail``) heavy-tailed quiet gaps — the overload pattern
        the admission/degradation stack is tested under.

    The trace is a pure function of ``(seed, pattern, parameters)`` —
    two calls with the same arguments return byte-identical arrays, so
    CI can assert exact shed/degrade counters against it.
    """
    d = float(interarrival_ms) * 1e-3
    if n <= 0:
        return np.zeros(0, np.float64)
    if pattern == "uniform":
        return np.arange(n, dtype=np.float64) * d
    rng = np.random.default_rng(
        np.random.SeedSequence([_TRACE_ROOT, int(seed)]))
    if pattern == "poisson":
        gaps = rng.exponential(d, size=n)
        gaps[0] = 0.0
        return np.cumsum(gaps)
    if pattern == "bursty":
        gaps = np.empty(n, np.float64)
        i = 0
        while i < n:
            blen = min(n - i, max(1, int(rng.geometric(1.0 / burst_len))))
            # quiet gap before the burst, heavy-tailed so occasional
            # lulls let the queue drain (and occasional back-to-back
            # bursts overload it)
            gaps[i] = (0.0 if i == 0
                       else d * burst_len * (0.5 + rng.pareto(tail)))
            gaps[i + 1:i + blen] = d / burst_factor
            i += blen
        return np.cumsum(gaps)
    raise ValueError(f"unknown arrival pattern {pattern!r}; "
                     f"use uniform | poisson | bursty")


def simulate_stream(engine, queries, *, interarrival_ms: float = 0.1,
                    churn=None, pattern: str = "uniform", seed: int = 0,
                    open_loop: bool = False, classes=None, tenants=None,
                    burst_factor: float = 8.0, burst_len: int = 16,
                    trace=None, metrics_out=None, trace_out=None) -> dict:
    """Drive a query stream through an engine/runtime on a virtual clock.

    Arrivals follow a reproducible `arrival_trace` (``pattern`` /
    ``seed``; or pass an explicit ``trace`` array) on a simulated clock
    that only advances by (a) arrival spacing and (b) *measured* compute
    time of each dispatch — batching/deadline/overload dynamics are
    exercised exactly as in wall-clock serving, without sleeps.

    ``open_loop=True`` stamps each submit at its *true* trace arrival
    time even when the engine's virtual clock has already passed it,
    and admits every arrival the clock has overtaken *before* the next
    poll (arrivals keep coming while the server is busy — the load
    model under which queues actually grow, batches fill, and shedding
    fires).  The default closed-ish loop (arrivals wait for the clock,
    one submit per poll) matches the PR-2 driver byte-for-byte on the
    uniform pattern.

    ``churn(engine, i)`` (optional) runs before each arrival — stage
    store mutations there to simulate a live corpus.  ``classes(i)``
    (optional, `ServeRuntime` only) names the priority class of arrival
    ``i``.  ``tenants(i)`` (optional, `repro.launch.tenancy.
    MultiTenantRuntime` only) names the tenant whose table serves
    arrival ``i`` — a multi-tenant trace is just a merged arrival trace
    plus this routing function.  Returns the engine stats dict plus
    ``virtual_s``,
    ``throughput_rps`` and the ``trace`` metadata block (pattern, seed,
    span, offered rate) that makes the run reproducible.

    ``metrics_out`` / ``trace_out`` (optional paths) export the engine's
    observability artifacts after the drain: the metrics registry
    snapshot (Prometheus text for ``.prom``/``.txt``, JSON otherwise)
    and the Chrome trace-event JSON of the span tracer
    (docs/OBSERVABILITY.md).  Paths actually written are echoed in an
    ``artifacts`` block of the returned dict.
    """
    n = len(queries)
    if trace is None:
        trace = arrival_trace(n, interarrival_ms=interarrival_ms,
                              pattern=pattern, seed=seed,
                              burst_factor=burst_factor,
                              burst_len=burst_len)
    trace = np.asarray(trace, np.float64)
    now = 0.0
    i = 0
    while i < n:
        now = max(now, float(trace[i]))
        # admit arrival i — and, open loop, every later arrival already
        # overdue because the clock advanced while the server was busy.
        # Without this the queue can never exceed one request and
        # continuous batching degenerates to singleton dispatches.
        while True:
            if churn is not None:
                churn(engine, i)
            kw = {} if classes is None else {"cls": classes(i)}
            if tenants is not None:
                kw["tenant"] = tenants(i)
            engine.submit(queries[i],
                          now=(float(trace[i]) if open_loop else now), **kw)
            i += 1
            if not (open_loop and i < n and float(trace[i]) <= now):
                break
        _, busy = engine.poll(now=now)
        now += busy
        # batch-wait timer: a real async loop flushes a partial batch
        # after batch_wait even with no new arrival to wake it.  Poll at
        # timer ticks across quiet gaps so a burst tail is not stuck
        # queued (and expiring) until the next burst arrives.
        t_next = float(trace[i]) if i < n else np.inf
        while engine.pending_count and now + engine.deadline_s < t_next:
            now += engine.deadline_s
            _, busy = engine.poll(now=now)
            now += busy
    while engine.pending_count:
        now += engine.deadline_s
        _, busy = engine.poll(now=now)
        now += busy
    span = float(trace[-1]) if n else 0.0
    artifacts = {}
    if metrics_out is not None and getattr(engine, "metrics", None) is not None:
        engine.metrics.write(metrics_out)
        artifacts["metrics"] = str(metrics_out)
    if trace_out is not None and getattr(engine, "tracer", None) is not None:
        engine.tracer.write(trace_out)
        artifacts["trace"] = str(trace_out)
    return {"virtual_s": now,
            "throughput_rps": max(1, n) / max(now, 1e-9),
            "trace": {"pattern": pattern, "seed": int(seed),
                      "interarrival_ms": float(interarrival_ms),
                      "open_loop": bool(open_loop),
                      "span_s": span,
                      "offered_rps": n / max(span, 1e-9) if n else 0.0},
            **({"artifacts": artifacts} if artifacts else {}),
            **engine.stats()}


def _make_churn(store, churn_rate: float, scale: float):
    """The --dynamic mutation closure: upserts/delete+append per arrival."""
    crng = np.random.default_rng(1)

    def churn(eng, i):
        if crng.random() >= churn_rate:
            return
        row = (scale * crng.normal(size=eng.N) / np.sqrt(eng.N)
               ).astype(np.float32)
        live = store.live_ids()
        if crng.random() < 0.7 or live.size == 0:
            tgt = (int(crng.choice(live)) if live.size
                   else store.append(row) or 0)
            store.upsert(tgt, row)
        elif store.free_rows > 0:
            store.delete(int(crng.choice(live)))
            store.append(row)

    return churn


def _run_loop(args) -> None:
    """--loop mode: serve a synthetic query stream against the unembedding.

    With ``--dynamic`` the vocab table is wrapped in a
    `repro.store.DynamicTableStore` (or `ShardedTableStore` under
    ``--shards``) and ``--churn-rate`` of the arrivals additionally stage
    an embedding upsert or a delete+append pair — the live-corpus
    scenario (DESIGN.md §11).  With ``--runtime`` the stream is served by
    the continuous-batching `ServeRuntime` (DESIGN.md §13) under the
    chosen arrival ``--pattern``, optionally with deterministic fault
    injection (``--inject-*``).
    """
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    mesh = None
    if args.shards > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.shards)
    block = min(512, cfg.d_model)
    churn = None
    store = None
    n_valid = cfg.vocab
    if args.dynamic:
        from repro.store import DynamicTableStore, ShardedTableStore
        table = np.asarray(table, np.float32)[:cfg.vocab]
        if mesh is not None:
            store = ShardedTableStore(
                table, mesh=mesh, block=block,
                capacity_slack=args.capacity_slack)
        else:
            store = DynamicTableStore(
                table, block=block, capacity_slack=args.capacity_slack,
                precision=args.precision, pq_subdims=args.pq_subdims)
        table, n_valid = store, None
        if args.churn_rate > 0:
            churn = _make_churn(store, args.churn_rate,
                                float(store.value_abs_max))

    common = dict(K=args.topk, eps=args.eps, delta=args.delta,
                  mesh=mesh, recall_sample_rate=args.recall_rate,
                  cache_entries=args.cache_entries,
                  precision=args.precision, adaptive=args.adaptive,
                  bound=args.bound, pull_mode=args.pull_mode,
                  pq_subdims=args.pq_subdims)
    if not args.dynamic:
        common.update(block=block, n_valid=n_valid)

    tracer = None
    flight = None
    if args.runtime:
        if args.trace_out:
            from repro.obs import SpanTracer
            tracer = SpanTracer(seed=args.stream_seed)
        if args.flight_recorder_path:
            from repro.obs import FlightRecorder
            flight = FlightRecorder(capacity=args.flight_capacity,
                                    path=args.flight_recorder_path)
        injector = None
        if (args.inject_latency_rate > 0 or args.inject_error_rate > 0
                or args.inject_flush_rate > 0):
            from repro.launch.faults import FaultInjector
            injector = FaultInjector(
                args.fault_seed,
                latency_rate=args.inject_latency_rate,
                error_rate=args.inject_error_rate,
                flush_failure_rate=args.inject_flush_rate)
        classes = {
            "interactive": PriorityClass(
                "interactive", priority=0,
                deadline_ms=args.request_deadline_ms, sheddable=False),
            "default": PriorityClass(
                "default", priority=1,
                deadline_ms=args.request_deadline_ms),
            "batch": PriorityClass(
                "batch", priority=2,
                deadline_ms=4 * args.request_deadline_ms),
        }
        engine = ServeRuntime(
            table, eps_floor=args.eps_floor,
            degrade_rungs=args.degrade_rungs, lanes=args.batch,
            batch_wait_ms=args.deadline_ms,
            queue_capacity=args.queue_capacity, classes=classes,
            max_retries=args.max_retries, fault_injector=injector,
            tracer=tracer, flight=flight, **common)
        print(f"[serve] runtime: table=({engine.n},{engine.N}) "
              f"K={args.topk} eps={args.eps} "
              f"eps_floor={engine.ladder.eps_floor} "
              f"rungs={engine.ladder.n_rungs} lanes={args.batch} "
              f"queue={args.queue_capacity} "
              f"pattern={args.pattern} "
              f"shards={mesh.shape['model'] if mesh else 1} "
              f"dynamic={bool(args.dynamic)} churn={args.churn_rate} "
              f"pull_mode={args.pull_mode} "
              f"faults={'on' if injector else 'off'}")
    else:
        engine = MIPSServeEngine(
            table, batch_size=args.batch, deadline_ms=args.deadline_ms,
            **common)
        print(f"[serve] loop: table=({engine.n},{engine.N}) "
              f"K={args.topk} eps={args.eps} batch={args.batch} "
              f"deadline={args.deadline_ms}ms "
              f"shards={mesh.shape['model'] if mesh else 1} "
              f"dynamic={bool(args.dynamic)} churn={args.churn_rate} "
              f"rounds={len(engine.plan.schedule.rounds)} "
              f"precision={engine.plan.precision} "
              f"adaptive={args.adaptive} bound={args.bound} "
              f"pull_mode={engine.plan.pull_mode} "
              f"block={engine.plan.block} "
              f"eps_eff={engine.plan.eps_effective:.4f} "
              f"pull_speedup={engine.plan.schedule.speedup:.2f}x")
    rng = np.random.default_rng(0)
    qs = rng.normal(size=(args.requests, engine.N)).astype(np.float32)
    if args.repeat_rate > 0:                  # cacheable duplicate queries
        n_dup = int(args.requests * args.repeat_rate)
        idx = rng.integers(0, max(1, args.requests - n_dup), n_dup)
        qs[args.requests - n_dup:] = qs[idx]
    cls_fn = None
    if args.runtime:
        crng = np.random.default_rng(args.stream_seed + 1)
        names = ("interactive", "default", "default", "batch")
        picks = crng.integers(0, len(names), args.requests)
        cls_fn = lambda i: names[picks[i]]   # noqa: E731
    stats = simulate_stream(
        engine, qs, interarrival_ms=args.interarrival_ms, churn=churn,
        pattern=args.pattern, seed=args.stream_seed,
        open_loop=args.runtime, classes=cls_fn,
        metrics_out=args.metrics_out, trace_out=args.trace_out)
    if flight is not None:
        # always leave a final snapshot on disk so CI can validate the
        # artifact even on a fault-free run (failure dumps, if any,
        # already happened mid-stream and this one supersedes them)
        dumped = flight.dump("end_of_run", stats["virtual_s"])
        if dumped:
            stats.setdefault("artifacts", {})["flight"] = dumped
    print(json.dumps(stats, indent=2))
    if args.runtime and args.check_outcomes:
        _check_outcomes(args, stats)


def _load_tenant_spec(path: str) -> dict:
    """Parse a ``--tenants`` spec file into {name: spec-dict}.

    The file is JSON: either a mapping of tenant name -> spec, or
    ``{"tenants": {...}}``.  Each spec holds driver keys — ``rows``
    (synthetic table rows, required) and ``rate_factor`` (arrival-rate
    multiplier vs ``--interarrival-ms``, default 1.0; the hot-tenant
    skew knob) — plus any `repro.launch.tenancy.TenantConfig` field
    (``eps``, ``precision``, ``weight``, ``pinned``, ...).  Unknown
    keys are rejected so a typo'd knob cannot silently serve defaults.
    """
    with open(path) as f:
        spec = json.load(f)
    if isinstance(spec, dict) and isinstance(spec.get("tenants"), dict):
        spec = spec["tenants"]
    if not isinstance(spec, dict) or not spec:
        raise ValueError(f"{path}: expected a non-empty JSON object of "
                         f"tenant name -> spec")
    from repro.launch.tenancy import TenantConfig
    cfg_fields = {f.name for f in dataclasses.fields(TenantConfig)}
    driver_keys = {"rows", "rate_factor"}
    for name, s in spec.items():
        if not isinstance(s, dict) or "rows" not in s:
            raise ValueError(f"{path}: tenant {name!r} needs at least "
                             f"{{\"rows\": <n>}}")
        unknown = set(s) - cfg_fields - driver_keys
        if unknown:
            raise ValueError(f"{path}: tenant {name!r} has unknown keys "
                             f"{sorted(unknown)}")
        if float(s.get("rate_factor", 1.0)) <= 0:
            raise ValueError(f"{path}: tenant {name!r} rate_factor must "
                             f"be > 0")
    return spec


def _run_tenants(args) -> None:
    """--tenants mode: one multi-tenant runtime, one merged stream.

    Builds a synthetic table per tenant from the spec (seeded per
    tenant, dim = the arch's d_model), registers all of them in a
    `repro.launch.tenancy.TableRegistry` under ``--table-budget-mb``,
    and drives one merged open-loop arrival trace — each tenant
    arrives at ``rate_factor`` times the base ``--interarrival-ms``
    rate under its own ``--pattern`` stream — through a
    `MultiTenantRuntime` (deficit-round-robin fairness, per-tenant
    admission, LRU residency).  Stats keep the single-runtime top-level
    shape, so ``--check-outcomes`` gates the run unchanged; per-tenant
    and registry breakdowns ride in ``tenants`` / ``registry``.
    """
    from repro.launch.tenancy import (MultiTenantRuntime, TableRegistry,
                                      TenantConfig)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    dim = cfg.d_model
    spec = _load_tenant_spec(args.tenants)
    tracer = None
    flight = None
    if args.trace_out:
        from repro.obs import SpanTracer
        tracer = SpanTracer(seed=args.stream_seed)
    if args.flight_recorder_path:
        from repro.obs import FlightRecorder
        flight = FlightRecorder(capacity=args.flight_capacity,
                                path=args.flight_recorder_path)
    injector = None
    if (args.inject_latency_rate > 0 or args.inject_error_rate > 0
            or args.inject_flush_rate > 0):
        from repro.launch.faults import FaultInjector
        injector = FaultInjector(
            args.fault_seed,
            latency_rate=args.inject_latency_rate,
            error_rate=args.inject_error_rate,
            flush_failure_rate=args.inject_flush_rate)
    budget = (None if args.table_budget_mb is None
              else int(args.table_budget_mb * 2**20))
    registry = TableRegistry(byte_budget=budget, lanes=args.batch,
                             flight=flight)
    rates = {}
    for idx, (name, s) in enumerate(sorted(spec.items())):
        s = dict(s)
        rows = int(s.pop("rows"))
        rates[name] = float(s.pop("rate_factor", 1.0))
        defaults = dict(K=args.topk, eps=args.eps, delta=args.delta,
                        eps_floor=args.eps_floor,
                        degrade_rungs=args.degrade_rungs,
                        precision=args.precision,
                        pull_mode=args.pull_mode,
                        pq_subdims=args.pq_subdims,
                        adaptive=args.adaptive, bound=args.bound,
                        cache_entries=args.cache_entries,
                        deadline_ms=args.request_deadline_ms,
                        queue_capacity=args.queue_capacity,
                        seed=args.stream_seed + idx)
        defaults.update(s)
        tcfg = TenantConfig(**defaults)
        trng = np.random.default_rng(
            np.random.SeedSequence([_TRACE_ROOT, args.stream_seed, idx]))
        table = (trng.normal(size=(rows, dim)) / np.sqrt(dim)
                 ).astype(np.float32)
        registry.register(name, table, tcfg)
    engine = MultiTenantRuntime(
        registry, batch_wait_ms=args.deadline_ms,
        max_retries=args.max_retries, fault_injector=injector,
        recall_sample_rate=args.recall_rate, seed=args.stream_seed,
        tracer=tracer, flight=flight)
    names = sorted(spec)
    print(f"[serve] tenants: {len(names)} tables dim={dim} "
          f"budget={'none' if budget is None else f'{budget}B'} "
          f"lanes={args.batch} pattern={args.pattern} "
          f"rates={ {n: rates[n] for n in names} } "
          f"faults={'on' if injector else 'off'}")
    engine.warmup()
    # merged arrival trace: each tenant gets its own seeded stream at
    # rate_factor x the base rate; the merge is sorted by arrival time
    total_rate = sum(rates.values())
    per_tenant_n = {n: max(1, int(round(args.requests * rates[n]
                                        / total_rate)))
                    for n in names}
    times, labels = [], []
    for idx, name in enumerate(names):
        tr = arrival_trace(per_tenant_n[name],
                           interarrival_ms=(args.interarrival_ms
                                            / rates[name]),
                           pattern=args.pattern,
                           seed=args.stream_seed + 1000 * (idx + 1),
                           burst_factor=8.0, burst_len=16)
        times.append(tr)
        labels.extend([name] * len(tr))
    times = np.concatenate(times) if times else np.zeros(0)
    order = np.argsort(times, kind="stable")
    trace = times[order]
    labels = [labels[int(j)] for j in order]
    qrng = np.random.default_rng(args.stream_seed)
    qs = qrng.normal(size=(len(trace), dim)).astype(np.float32)
    if args.repeat_rate > 0:
        n_dup = int(len(trace) * args.repeat_rate)
        if n_dup:
            idxs = qrng.integers(0, max(1, len(trace) - n_dup), n_dup)
            qs[len(trace) - n_dup:] = qs[idxs]
    stats = simulate_stream(
        engine, qs, interarrival_ms=args.interarrival_ms,
        pattern=args.pattern, seed=args.stream_seed, open_loop=True,
        tenants=lambda i: labels[i], trace=trace,
        metrics_out=args.metrics_out, trace_out=args.trace_out)
    if flight is not None:
        dumped = flight.dump("end_of_run", stats["virtual_s"])
        if dumped:
            stats.setdefault("artifacts", {})["flight"] = dumped
    print(json.dumps(stats, indent=2))
    if args.check_outcomes:
        _check_outcomes(args, stats)


def _check_outcomes(args, stats: dict) -> None:
    """--check-outcomes: fail the process unless the runtime held its
    serving contract over the stream — reaching this line at all proves
    no exception escaped `simulate_stream`; on top of that every request
    must have finished with exactly one typed status from the closed
    set, and the answered tail latency must stay inside 8x the request
    deadline (expiry bounds queueing; dispatch + retries ride on top).
    Used by the CI overload + fault-injection smoke job."""
    o = stats["outcomes"]
    unknown = set(o) - set(STATUSES)
    if unknown:
        sys.exit(f"[check] unknown outcome statuses: {sorted(unknown)}")
    total = sum(o.values())
    if total != stats["requests"]:
        sys.exit(f"[check] {stats['requests']} requests but {total} "
                 f"typed outcomes — a request finished without a "
                 f"status, or with two")
    bound = 8.0 * args.request_deadline_ms
    p99 = stats["latency_ms"]["p99"]
    if stats["completed"] and p99 > bound:
        sys.exit(f"[check] p99 {p99:.1f}ms exceeds {bound:.0f}ms "
                 f"(8x --request-deadline-ms)")
    print(f"[check] OK: outcomes closed, {stats['requests']} requests "
          f"all typed, p99 {p99:.1f}ms <= {bound:.0f}ms")


def _run_decode_demo(args) -> None:
    """Default mode: batched prefill + greedy decode with MIPS logits."""
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, mips_mode=args.mips, mips_eps=args.eps,
                              mips_delta=args.delta,
                              mips_precision=args.precision)

    if cfg.mips_mode == "boundedme":
        # the decode hot path runs the whole bandit as ONE fused kernel
        # dispatch per batch (DESIGN.md §3); surface the static plan so the
        # (eps, delta) <-> pull-count trade is visible at launch
        from repro.core.schedule import flatten_schedule
        from repro.kernels.ops import on_tpu
        from repro.models.steps import make_mips_plan
        plan = make_mips_plan(cfg, K=1)
        flat = flatten_schedule(plan.schedule, final_coverage=True)
        path = ("fused pallas_call, dispatches_per_decode_batch=1"
                if on_tpu() else "jnp scan fallback (non-TPU backend)")
        print(f"[serve] fused cascade: rounds={len(plan.schedule.rounds)} "
              f"grid_steps={flat.n_steps} "
              f"precision={plan.precision} "
              f"pull_speedup={plan.schedule.speedup:.2f}x path={path}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    t0 = time.time()
    _, caches = prefill_step(params, cfg, prompt, cache_len=cache_len, **kw)
    jax.block_until_ready(caches)
    t_prefill = time.time() - t0

    dfn = jax.jit(lambda p, c, t, pos, k: decode_step(p, cfg, c, t, pos,
                                                      key=k))
    tok = prompt[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.tokens):
        pos = jnp.int32(P + i)
        nxt, caches = dfn(params, caches, tok, pos,
                          jax.random.PRNGKey(i))
        out.append(np.asarray(nxt))
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} mips={cfg.mips_mode} "
          f"eps={cfg.mips_eps} batch={B}")
    print(f"[serve] prefill {P} toks: {t_prefill*1e3:.1f} ms; "
          f"decode {args.tokens} toks: {t_decode*1e3:.1f} ms "
          f"({t_decode/args.tokens*1e3:.2f} ms/tok)")
    print(f"[serve] first sequences: {gen[0][:16].tolist()}")


def _validate_args(ap: argparse.ArgumentParser, args) -> None:
    """Fail fast on inconsistent CLI combinations, with actionable errors.

    Every check here would otherwise surface minutes later as a confusing
    deep failure (a churn closure that never fires, a ladder that refuses
    to build, a zero batch deadline that flushes every poll) — so the CLI
    refuses up front and says what to change.
    """
    if args.churn_rate > 0 and not args.dynamic:
        ap.error(f"--churn-rate {args.churn_rate} requires --dynamic: "
                 f"churn mutates a DynamicTableStore, but without "
                 f"--dynamic the table is a static array (add --dynamic, "
                 f"or drop --churn-rate)")
    if not 0.0 <= args.churn_rate <= 1.0:
        ap.error(f"--churn-rate must be in [0, 1], got {args.churn_rate}")
    if args.deadline_ms <= 0:
        ap.error(f"--deadline-ms must be > 0, got {args.deadline_ms}: it "
                 f"is the batch-assembly wait; 0 would flush a "
                 f"single-request batch at every poll (for per-request "
                 f"completion deadlines use --request-deadline-ms)")
    if args.eps_floor is not None:
        if not (args.runtime or args.tenants):
            ap.error("--eps-floor requires --runtime or --tenants: the "
                     "degradation ladder lives in the continuous-"
                     "batching runtimes (add --runtime, or drop "
                     "--eps-floor)")
        if args.eps_floor < args.eps:
            ap.error(f"--eps-floor {args.eps_floor} must be >= --eps "
                     f"{args.eps}: overload *relaxes* eps toward the "
                     f"floor (a floor tighter than the contract would "
                     f"mean degrading improves accuracy)")
    for name, val in (("--inject-latency-rate", args.inject_latency_rate),
                      ("--inject-error-rate", args.inject_error_rate),
                      ("--inject-flush-rate", args.inject_flush_rate)):
        if not 0.0 <= val <= 1.0:
            ap.error(f"{name} must be in [0, 1], got {val}")
        if val > 0 and not (args.runtime or args.tenants):
            ap.error(f"{name} requires --runtime or --tenants: fault "
                     f"injection is wired through the runtimes' "
                     f"retry/quarantine machinery (add --runtime)")
    if args.inject_flush_rate > 0 and not (args.dynamic or args.tenants):
        ap.error("--inject-flush-rate requires --dynamic or --tenants: "
                 "flush faults fire inside a store's flush_updates, and "
                 "without either there is no store")
    if args.tenants is not None:
        if not args.loop:
            ap.error("--tenants requires --loop: the multi-tenant "
                     "registry serves the request stream, not the "
                     "decode demo")
        if args.runtime:
            ap.error("--tenants is its own runtime mode; drop --runtime "
                     "(the MultiTenantRuntime is always continuous-"
                     "batching)")
        if args.dynamic or args.shards > 1:
            ap.error("--tenants builds its own stores per tenant; drop "
                     "--dynamic/--shards (per-tenant precision and "
                     "placement live in the spec file)")
    if args.table_budget_mb is not None:
        if args.tenants is None:
            ap.error("--table-budget-mb requires --tenants: the byte "
                     "budget governs the multi-tenant table registry")
        if args.table_budget_mb <= 0:
            ap.error(f"--table-budget-mb must be > 0, got "
                     f"{args.table_budget_mb}")
    if args.queue_capacity < 1:
        ap.error(f"--queue-capacity must be >= 1, "
                 f"got {args.queue_capacity}")
    if args.request_deadline_ms <= 0:
        ap.error(f"--request-deadline-ms must be > 0, got "
                 f"{args.request_deadline_ms} (per-request completion "
                 f"budget; requests older than it are shed)")
    if args.batch < 1:
        ap.error(f"--batch must be >= 1, got {args.batch}")
    if not 0.0 <= args.repeat_rate <= 1.0:
        ap.error(f"--repeat-rate must be in [0, 1], got {args.repeat_rate}")
    if (args.pull_mode != "row" and args.dynamic
            and args.precision != "fp32" and args.shards <= 1):
        ap.error(f"--pull-mode {args.pull_mode} is incompatible with a "
                 f"single-device quantized store (--dynamic --precision "
                 f"{args.precision}): the store's incrementally maintained "
                 f"{args.precision} shadow fixes the quantization-block "
                 f"geometry, which only the 'row' plan matches (use "
                 f"--pull-mode row, fp32, or --shards 2+)")
    if args.trace_out and not (args.runtime or args.tenants):
        ap.error("--trace-out requires --runtime or --tenants: span "
                 "tracing hooks live in the continuous-batching "
                 "runtimes")
    if args.flight_recorder_path and not (args.runtime or args.tenants):
        ap.error("--flight-recorder-path requires --runtime or "
                 "--tenants: the flight recorder records runtime "
                 "lifecycle events")
    if args.flight_capacity < 1:
        ap.error(f"--flight-capacity must be >= 1, "
                 f"got {args.flight_capacity}")
    if args.metrics_out and not (args.loop or args.runtime):
        ap.error("--metrics-out requires --loop or --runtime: the "
                 "decode demo does not run a metrics-instrumented "
                 "serving engine")
    if args.pq_subdims < 1:
        ap.error(f"--pq-subdims must be >= 1, got {args.pq_subdims}")
    if args.precision == "pq" and not (args.loop or args.runtime):
        ap.error("--precision pq requires --loop or --runtime: pq plans "
                 "need a measured quantization-error bound calibrated on "
                 "the served table (DESIGN.md §10), which the serving "
                 "engines perform at build time; the decode demo's "
                 "trace-time plan has no table to calibrate on")


def _build_parser() -> argparse.ArgumentParser:
    """The serve CLI parser (separate from `main` so tests can drive
    `_validate_args` against real parsed argv)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mips", default="exact",
                    choices=["exact", "boundedme"])
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "int8", "int4", "pq"],
                    help="sampling arithmetic of the cascade: int8/int4 "
                         "quantized pulls under widened bounds (int4 "
                         "nibble-packed, half the bytes), pq per-subspace "
                         "codebook pulls under a measured error bound "
                         "(DESIGN.md §10)")
    ap.add_argument("--pq-subdims", type=int, default=8,
                    help="product-quantization subspace width "
                         "(--precision pq; must divide the block width)")
    ap.add_argument("--adaptive", action="store_true",
                    help="certify per-query early exit at round "
                         "boundaries (DESIGN.md §12); easy queries stop "
                         "pulling inside the same (eps, delta) contract")
    ap.add_argument("--bound", default="hoeffding",
                    choices=["hoeffding", "bernstein"],
                    help="certification radius family for --adaptive "
                         "(bernstein = variance-aware, more pulls/round)")
    ap.add_argument("--pull-mode", default="row",
                    choices=["row", "coord", "hybrid"],
                    help="reward stream of the cascade (DESIGN.md §14): "
                         "'row' samples wide feature blocks, 'coord' the "
                         "BanditMIPS coordinate estimator (pull cost "
                         "sublinear in d), 'hybrid' prices both plans and "
                         "serves the cheaper")
    ap.add_argument("--batch", type=int, default=4,
                    help="micro-batch size (--loop) / kernel lanes "
                         "(--runtime) / decode batch (demo)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    # request-loop mode
    ap.add_argument("--loop", action="store_true",
                    help="run the micro-batching MIPS request loop")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--deadline-ms", type=float, default=2.0,
                    help="batch-assembly wait (micro-batch deadline)")
    ap.add_argument("--interarrival-ms", type=float, default=0.1)
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--cache-entries", type=int, default=512)
    ap.add_argument("--repeat-rate", type=float, default=0.1,
                    help="fraction of requests repeating an earlier query")
    ap.add_argument("--recall-rate", type=float, default=0.05)
    ap.add_argument("--dynamic", action="store_true",
                    help="serve from a mutable DynamicTableStore "
                         "(zero-rebuild upserts/deletes, DESIGN.md §11)")
    ap.add_argument("--churn-rate", type=float, default=0.0,
                    help="fraction of arrivals that also mutate the "
                         "table (needs --dynamic)")
    ap.add_argument("--capacity-slack", type=float, default=1.5,
                    help="store capacity headroom factor (--dynamic)")
    # continuous-batching runtime mode (DESIGN.md §13)
    ap.add_argument("--runtime", action="store_true",
                    help="serve with the continuous-batching async "
                         "runtime (admission control, priority classes, "
                         "eps degradation ladder, typed refusals)")
    ap.add_argument("--queue-capacity", type=int, default=64,
                    help="bounded admission queue depth (--runtime)")
    ap.add_argument("--eps-floor", type=float, default=None,
                    help="worst eps the degradation ladder may serve "
                         "under overload (>= --eps; default: no "
                         "degradation)")
    ap.add_argument("--degrade-rungs", type=int, default=3,
                    help="precompiled eps rungs between --eps and "
                         "--eps-floor")
    ap.add_argument("--request-deadline-ms", type=float, default=50.0,
                    help="per-request completion budget (--runtime); "
                         "requests queued past it are shed, not served "
                         "late")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="dispatch retry budget before a micro-batch is "
                         "failed (--runtime)")
    ap.add_argument("--pattern", default="uniform",
                    choices=["uniform", "poisson", "bursty"],
                    help="arrival pattern of the simulated stream")
    ap.add_argument("--stream-seed", type=int, default=0,
                    help="seed of the reproducible arrival trace")
    ap.add_argument("--inject-latency-rate", type=float, default=0.0,
                    help="fault injection: per-dispatch latency-spike "
                         "probability (--runtime)")
    ap.add_argument("--inject-error-rate", type=float, default=0.0,
                    help="fault injection: per-dispatch exception "
                         "probability (--runtime)")
    ap.add_argument("--inject-flush-rate", type=float, default=0.0,
                    help="fault injection: store flush failure "
                         "probability (--runtime --dynamic)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the deterministic fault schedule")
    ap.add_argument("--check-outcomes", action="store_true",
                    help="after the stream, fail unless every request "
                         "got a typed status from the closed set and "
                         "p99 stayed inside 8x the request deadline "
                         "(CI smoke contract; --runtime)")
    # observability artifacts (docs/OBSERVABILITY.md)
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics-registry snapshot here after "
                         "the stream (.prom/.txt = Prometheus text "
                         "exposition, anything else = JSON)")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request span traces here as Chrome "
                         "trace-event JSON — load in Perfetto / "
                         "chrome://tracing (--runtime)")
    ap.add_argument("--flight-recorder-path", default=None,
                    help="arm the crash flight recorder: a bounded ring "
                         "of structured serving events dumped here on "
                         "request failure / store-flush error, plus a "
                         "final end-of-run snapshot (--runtime)")
    ap.add_argument("--flight-capacity", type=int, default=256,
                    help="flight-recorder ring size in events")
    # multi-tenant mode (DESIGN.md §16)
    ap.add_argument("--tenants", default=None, metavar="SPEC.json",
                    help="serve a multi-tenant registry instead of one "
                         "table: JSON mapping tenant name -> spec "
                         "({'rows': n, 'rate_factor': r, plus any "
                         "TenantConfig field}); drives one merged "
                         "arrival trace through the deficit-round-robin "
                         "MultiTenantRuntime (--loop)")
    ap.add_argument("--table-budget-mb", type=float, default=None,
                    help="device-memory budget for resident tenant "
                         "tables (MB); cold tables are paged out LRU "
                         "(--tenants; default: unbounded)")
    return ap


def main():
    """CLI: `--loop` for the request loop, default for the decode demo."""
    ap = _build_parser()
    args = ap.parse_args()
    _validate_args(ap, args)
    if args.tenants is not None:
        _run_tenants(args)
    elif args.loop:
        _run_loop(args)
    else:
        _run_decode_demo(args)


if __name__ == "__main__":
    main()
