"""Serving: the MIPS request loop (micro-batching engine) + LM decode demo.

The paper's feature in production position: `--mips boundedme` replaces the
full unembedding matvec at every decode step with the BoundedME bandit
(per-query (eps, delta) knob, zero preprocessing — the vocab table can be
hot-swapped between requests with no index rebuild).

Two entry points:

* :class:`MIPSServeEngine` — a real request loop (DESIGN.md §7): incoming
  queries are micro-batched up to a batch deadline, each flush is one
  fused-cascade dispatch (single-device `bounded_me_decode`, or
  `sharded_bounded_me_decode` across a device mesh) with the query buffer
  donated to jit, results are memoized in a small LRU keyed on quantized
  queries, and per-request latency/recall counters are exported as a stats
  dict.  Pass a `repro.store.DynamicTableStore` / `ShardedTableStore`
  instead of a static table to serve a *live* corpus: upserts/deletes are
  drained between flushes with zero recompilation and zero index rebuild
  (DESIGN.md §11; `--dynamic --churn-rate 0.1` below).

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
          --smoke --loop --requests 256 --batch 8 --deadline-ms 2

* the original batched prefill + greedy decode demo:

      PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
          --smoke --mips boundedme --eps 0.1 --tokens 32
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import struct
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step

__all__ = ["QuantizedLRU", "MIPSServeEngine", "simulate_stream", "main"]


class QuantizedLRU:
    """LRU result cache keyed on quantized queries.

    Keys are the bytes of ``round(q / resolution)`` (int32): any two
    queries within ``resolution`` per coordinate share a cache line, which
    is exactly the granularity at which an (eps, delta)-approximate answer
    is reusable.  ``resolution=0`` disables quantization sharing (exact
    byte equality only).  Capacity 0 disables the cache entirely.
    """

    def __init__(self, capacity: int, resolution: float = 1e-3):
        self.capacity = int(capacity)
        self.resolution = float(resolution)
        self._od: "collections.OrderedDict[bytes, object]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def key(self, q: np.ndarray) -> bytes:
        """Quantize a (N,) query to its cache key."""
        if self.resolution > 0:
            return np.round(np.asarray(q, np.float32)
                            / self.resolution).astype(np.int64).tobytes()
        return np.asarray(q, np.float32).tobytes()   # exact bytes only

    def get(self, key: bytes):
        """Return the cached value or None; counts the hit/miss."""
        hit = self._od.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._od.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: bytes, value) -> None:
        """Insert/update; evicts the least-recently-used past capacity."""
        if self.capacity <= 0:
            return
        self._od[key] = value
        self._od.move_to_end(key)
        while len(self._od) > self.capacity:
            self._od.popitem(last=False)

    def invalidate(self) -> None:
        """Drop every entry (table version bump: cached answers are stale).

        Hit/miss counters survive; ``invalidations`` counts the calls.
        The engine additionally salts its keys with the table version, so
        even an entry that somehow survived an invalidation could never
        answer a post-update query.
        """
        self._od.clear()
        self.invalidations += 1

    def __len__(self) -> int:
        return len(self._od)


@dataclasses.dataclass
class _Pending:
    req_id: int
    q: np.ndarray
    t_submit: float
    cache_key: Optional[bytes]


class MIPSServeEngine:
    """Micro-batching MIPS request loop over a fixed item table.

    Requests (`submit`) are answered from the LRU when a quantized-equal
    query was served recently; otherwise they queue until either
    ``batch_size`` requests are waiting or the oldest has aged past
    ``deadline_ms`` (`poll` applies both triggers), then the whole
    micro-batch is served by ONE fused-cascade dispatch.  The padded
    (batch_size, N) query buffer is donated to jit so steady-state serving
    re-uses its device allocation instead of growing one per flush.

    With ``mesh`` the flush runs `sharded_bounded_me_decode` (shard-local
    cascades + exact cross-shard merge, DESIGN.md §7); otherwise the
    single-device `bounded_me_decode`.  Results arrive via `result` as
    ``(ids (K,), scores (K,))`` numpy arrays.

    ``recall_sample_rate`` > 0 additionally rescoring a random fraction of
    requests exhaustively on the host and folds top-K recall into
    `stats` — the live accuracy counter for the (eps, delta) knob.

    ``precision='int8'`` serves every flush on int8-quantized tiles under
    quantization-widened confidence bounds (DESIGN.md §10, docs/TUNING.md):
    roughly half the sampling-phase memory traffic per flush, with returned
    scores still fp32-exact (candidate rescore).  The live ``recall``
    stat is the operator's check that the widened (eps, delta) calibration
    holds on real traffic.

    ``adaptive=True`` (DESIGN.md §12) lets every query in a flush certify
    early exit at round boundaries under the ``bound`` radius family
    ('hoeffding' reuses the schedule's events; 'bernstein' is
    variance-aware): easy queries stop pulling rounds early inside the
    same (eps, delta) contract, and `stats()["adaptive"]` exports the
    per-query ``rounds_used`` histogram plus the mean executed-pull
    fraction.  Works on every path — single-device, sharded
    (shard-local certification), and store-backed including the int8
    shadow (certification radii carry the quantization bias).

    **Live corpora** (DESIGN.md §11): ``table`` may be a
    `repro.store.DynamicTableStore` (or `ShardedTableStore` for
    multi-device serving) instead of a static array.  The engine then
    serves the store's preallocated capacity buffer with the live-row
    count riding in as a traced ``n_valid`` every flush, so
    upsert/delete/append streams recompile nothing; staged mutations are
    drained by `apply_updates` — called automatically at every `poll` /
    `drain`, i.e. between micro-batch flushes — which also bumps the
    engine's table version (salting + invalidating the LRU so no stale
    answer survives), keeps the recall estimator on the store's live host
    mirror, and re-derives the (eps, delta) schedule only when the
    store's monotonic value range grows past the calibrated bound.
    Returned ids are the store's stable *external* ids.  The engine
    adopts the store's ``tile``/``block`` geometry and (for a
    `DynamicTableStore` int8 shadow) its ``precision``.

    Failure modes: queries must be (N,) float and finite — NaN/inf
    propagate into scores and poison the LRU line; `submit` raises on a
    shape mismatch.  The engine is not thread-safe; drive it from one
    loop.
    """

    def __init__(self, table, *, K: int = 1, eps: float = 0.1,
                 delta: float = 0.1, value_range: Optional[float] = None,
                 qmax_hint: float = 1.0, tile: int = 8, block: int = 512,
                 batch_size: int = 8, deadline_ms: float = 2.0,
                 cache_entries: int = 512, cache_resolution: float = 1e-3,
                 mesh=None, model_axis: str = "model",
                 n_valid: Optional[int] = None,
                 recall_sample_rate: float = 0.0,
                 use_pallas: Optional[bool] = None,
                 precision: str = "fp32", range_slack: float = 1.0,
                 adaptive: bool = False, bound: str = "hoeffding",
                 seed: int = 0):
        from repro.core.mips import table_abs_max
        from repro.store import DynamicTableStore, ShardedTableStore

        self._store = table if isinstance(
            table, (DynamicTableStore, ShardedTableStore)) else None
        self._qmax_hint = float(qmax_hint)
        self._range_slack = float(range_slack)
        self._use_pallas = use_pallas
        if self._store is not None:
            store = self._store
            if isinstance(store, ShardedTableStore):
                if mesh is not None and mesh is not store.mesh:
                    raise ValueError("mesh differs from the store's mesh")
                mesh = store.mesh
                model_axis = store.model_axis
            elif mesh is not None:
                raise ValueError(
                    "serving a mesh needs a ShardedTableStore")
            if n_valid is not None:
                raise ValueError("n_valid is store-managed")
            # the store owns the kernel geometry (its int8 shadow and the
            # engine's plan must agree tile-for-tile)
            tile, block = store.tile, store.block
            if store.precision == "int8":
                precision = "int8"
            n, N = store.capacity_rows, store.N
            # clamp to the store's observed range exactly as apply_updates
            # would on growth: a churned engine and a fresh engine on the
            # store's snapshot then always calibrate identical plans
            # (range_slack=1.0)
            floor = 2.0 * self._qmax_hint * max(store.value_abs_max, 1e-30)
            value_range = (floor if value_range is None
                           else max(float(value_range), floor))
        else:
            self._table = jnp.asarray(table)
            n, N = self._table.shape
            if value_range is None:
                # a-priori product-range bound: callers who know their
                # query norms should pass an explicit value_range instead
                value_range = 2.0 * qmax_hint * table_abs_max(self._table)
        self.n, self.N, self.K = n, N, K
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_ms) * 1e-3
        self._mesh = mesh
        self._model_axis = model_axis
        self._eps, self._delta = float(eps), float(delta)
        self._tile, self._block = int(tile), min(int(block), N)
        self._precision = precision
        self._adaptive = bool(adaptive)
        self._bound = bound
        self._n_valid = n_valid
        self._use_shadow = (self._store is not None and mesh is None
                            and self._store.precision == "int8")

        self._build(float(value_range))   # sets plan (+ shard geometry)
        if mesh is not None and self._store is None:
            from repro.distributed.specs import serving_table_sharding
            n_valid_eff = n if n_valid is None else n_valid
            self._n_valid = n_valid_eff   # recall must mask pad rows too
            if self._n_pad:  # ragged: pad rows host-side ONCE, pre-placing
                self._table = jnp.pad(self._table,
                                      ((0, self._n_pad), (0, 0)))
            self._table = jax.device_put(
                self._table, serving_table_sharding(mesh, model_axis))
            # static per-shard validity prefixes, passed traced per flush
            self._nv_static = np.clip(
                n_valid_eff
                - np.arange(mesh.shape[model_axis]) * self._n_local,
                0, self._n_local).astype(np.int32)
        elif mesh is None:
            nv = n if n_valid is None else n_valid
            self._nv_static = np.int32(nv)
        self._key = jax.random.PRNGKey(seed)
        self.cache = QuantizedLRU(cache_entries, cache_resolution)
        self._version = 0 if self._store is None else self._store.version
        self._pending: List[_Pending] = []
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._next_id = 0
        self._recall_rate = float(recall_sample_rate)
        self._recall_rng = np.random.default_rng(seed)
        self._table_np = None   # host copy, materialized only for recall
        self._lat: List[float] = []
        self._recalls: List[float] = []
        self._rounds: List[int] = []   # adaptive: per-query exit rounds
        self.n_requests = 0
        self.n_cache_hits = 0
        self.n_batches = 0
        self.n_deadline_flushes = 0
        self.n_full_flushes = 0
        self.n_updates = 0
        self.n_update_flushes = 0
        self.n_recalibrations = 0
        self._update_time_s = 0.0
        self._occupancy: List[int] = []

    def _build(self, value_range: float) -> None:
        """(Re)build the static plan + jitted flush fn for a value range.

        Called once at construction and again only when `apply_updates`
        observes the store's monotonic value range outgrowing the
        calibrated bound — the single event that changes the schedule
        (and therefore recompiles) on the dynamic path.
        """
        from repro.core.boundedme_jax import bounded_me_decode, make_plan

        self._plan_value_range = float(value_range)
        mesh, model_axis = self._mesh, self._model_axis
        K, eps, delta = self.K, self._eps, self._delta
        tile, block = self._tile, self._block
        precision, use_pallas = self._precision, self._use_pallas
        adaptive, bound = self._adaptive, self._bound
        if mesh is not None:
            from repro.distributed.sharding import (make_shard_plan,
                                                    sharded_bounded_me_decode)
            self.plan, self._n_local, self._n_pad, _ = make_shard_plan(
                self.n, self.N, mesh.shape[model_axis], K=K, eps=eps,
                delta=delta, value_range=value_range, tile=tile, block=block,
                precision=precision, bound=bound)

            def _flush_fn(tbl, Qbuf, key, nv):
                out = sharded_bounded_me_decode(
                    tbl, Qbuf, key, mesh=mesh, K=K, model_axis=model_axis,
                    n_valid=nv, eps=eps, delta=delta,
                    value_range=value_range, tile=tile, block=block,
                    final_exact=True, use_pallas=use_pallas,
                    precision=precision, adaptive=adaptive, bound=bound)
                # rounds_used is (B, shards) when adaptive, else absent
                return out[0], out[1], (out[3] if adaptive else None)

            donate = 1
        else:
            plan = make_plan(self.n, self.N, K=K, eps=eps, delta=delta,
                             value_range=value_range, tile=tile,
                             block=block, precision=precision, bound=bound)
            self.plan = plan
            if self._use_shadow:
                # the store maintains the int8 shadow incrementally; the
                # flush consumes it instead of re-quantizing the table
                def _flush_fn(tbl, V8, vscale, Qbuf, key, nv):
                    out = bounded_me_decode(
                        tbl, Qbuf, key, plan=plan, final_exact=True,
                        use_pallas=use_pallas, n_valid=nv,
                        quantized=(V8, vscale), adaptive=adaptive)
                    return (out if adaptive else (*out, None))

                donate = 3
            else:
                def _flush_fn(tbl, Qbuf, key, nv):
                    # padding/dead rows are masked inside the cascade, so
                    # they can never occupy the returned top-K slots
                    out = bounded_me_decode(
                        tbl, Qbuf, key, plan=plan, final_exact=True,
                        use_pallas=use_pallas, n_valid=nv, adaptive=adaptive)
                    return (out if adaptive else (*out, None))

                donate = 1

        # donate the query buffer: steady-state flushes recycle the same
        # (batch_size, N) device allocation (no-op on backends without
        # donation support, e.g. CPU)
        self._fn = jax.jit(_flush_fn, donate_argnums=(donate,))

    # ---- request path ---------------------------------------------------

    @property
    def pending_count(self) -> int:
        """Requests accepted but not yet served (excludes cache hits)."""
        return len(self._pending)

    def submit(self, q, now: Optional[float] = None) -> int:
        """Accept one (N,) query; returns its request id.

        Cache hits complete immediately (latency ~0); misses queue for the
        next micro-batch.  ``now`` (seconds, any monotonic origin) defaults
        to wall clock — pass a virtual clock for simulation.  Staged store
        mutations are drained first: a query submitted after an upsert
        must never be answered from a pre-upsert cache line or table.
        """
        q = np.asarray(q, np.float32)
        if q.shape != (self.N,):
            raise ValueError(f"query shape {q.shape} != ({self.N},)")
        self.apply_updates()
        now = time.perf_counter() if now is None else now
        rid = self._next_id
        self._next_id += 1
        self.n_requests += 1
        # lookups are salted with the *current* (table version, K): a
        # result cached before an update can never answer a post-update
        # query, even if an invalidation were missed
        ck = self.cache.key(q) if self.cache.capacity > 0 else None
        if ck is not None:
            hit = self.cache.get(self._salted(ck))
            if hit is not None:
                self._results[rid] = hit
                self.n_cache_hits += 1
                self._lat.append(0.0)
                return rid
        self._pending.append(_Pending(rid, q, now, ck))
        return rid

    def _salted(self, base_key: bytes) -> bytes:
        """Prefix an LRU base key with the live (version, K) salt."""
        return struct.pack("<qi", self._version, self.K) + base_key

    def poll(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Flush micro-batches whose trigger fired; returns (ids, busy_s).

        Triggers: ``batch_size`` requests waiting (full flush), or the
        oldest pending request older than the batch deadline (deadline
        flush).  ``busy_s`` is the wall time spent in compute, so virtual-
        clock drivers can advance time by it.  Store-backed engines drain
        staged table mutations first (`apply_updates`), so a flush never
        serves a torn table and an update submitted before a query is
        visible to it.
        """
        now = time.perf_counter() if now is None else now
        self.apply_updates()
        done: List[int] = []
        busy = 0.0
        while self._pending:
            full = len(self._pending) >= self.batch_size
            aged = now - self._pending[0].t_submit >= self.deadline_s
            if not (full or aged):
                break
            if full:
                self.n_full_flushes += 1
            else:
                self.n_deadline_flushes += 1
            ids, dt = self._flush(now + busy)
            done.extend(ids)
            busy += dt
        return done, busy

    def drain(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Flush everything pending regardless of triggers (shutdown).

        Also drains staged store mutations first, like `poll`.
        """
        now = time.perf_counter() if now is None else now
        self.apply_updates()
        done: List[int] = []
        busy = 0.0
        while self._pending:
            self.n_deadline_flushes += 1
            ids, dt = self._flush(now + busy)
            done.extend(ids)
            busy += dt
        return done, busy

    def result(self, req_id: int):
        """Pop the (ids, scores) result for a completed request, or None."""
        return self._results.pop(req_id, None)

    # ---- updates (store-backed engines) ---------------------------------

    def apply_updates(self) -> int:
        """Drain the store's staged mutations; returns rows applied.

        Runs between micro-batch flushes (`poll` / `drain` call it first),
        so in-flight queries never observe a half-applied update burst.
        On any applied mutation: bumps the engine's table version (the
        LRU is invalidated and its keys salted so no pre-update answer
        survives), drops the stale recall mirror (the estimator reads the
        store's always-fresh host mirror anyway), and — only if the
        store's monotonic value range grew past the calibrated bound —
        re-derives the (eps, delta) schedule at ``range * range_slack``
        (the lone recompile-triggering event, counted in
        ``stats()["updates"]["recalibrations"]``).  No-op without a store.
        """
        store = self._store
        if store is None:
            return 0
        applied = 0
        if store.pending_updates:
            t0 = time.perf_counter()
            info = store.flush_updates()
            applied = info["applied"]
            self.n_updates += applied
            self.n_update_flushes += 1
            self._update_time_s += time.perf_counter() - t0
        if store.version != self._version:
            # covers staged mutations AND out-of-band ones (grow())
            self._version = store.version
            self.cache.invalidate()
            self._table_np = None   # never serve stale recall ground truth
        if store.capacity_rows != self.n:
            # the store grew: shapes changed, rebuild plan + flush fn
            self.n = store.capacity_rows
            self._build(self._plan_value_range)
            self.n_recalibrations += 1
        needed = 2.0 * self._qmax_hint * store.value_abs_max
        if needed > self._plan_value_range:
            # value-range growth is the only other event that re-derives
            # the schedule; range_slack > 1 buys headroom so a growing
            # corpus recalibrates O(log growth) times, not per update
            self._build(needed * self._range_slack)
            self.n_recalibrations += 1
        return applied

    # ---- flush ----------------------------------------------------------

    def _flush_args(self, Qbuf, key):
        """Assemble per-flush operands (table/shadow/validity) in order."""
        store = self._store
        if store is None:
            return (self._table, Qbuf, key, self._nv_static)
        tbl = store.device_table()
        if self._mesh is not None:
            nv = store.n_valid_vector()
        else:
            nv = np.int32(store.n_live)
        if self._use_shadow:
            V8, vscale = store.quantized()
            return (tbl, V8, vscale, Qbuf, key, nv)
        return (tbl, Qbuf, key, nv)

    def _flush(self, now: float) -> Tuple[List[int], float]:
        batch = self._pending[:self.batch_size]
        self._pending = self._pending[len(batch):]
        Qbuf = np.zeros((self.batch_size, self.N), np.float32)
        for i, p in enumerate(batch):
            Qbuf[i] = p.q
        key = jax.random.fold_in(self._key, self.n_batches)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # CPU backends warn that donation is unimplemented; harmless
            warnings.filterwarnings("ignore",
                                    message=".*[Dd]onat.*")
            ids, scores, rounds = self._fn(
                *self._flush_args(jnp.asarray(Qbuf), key))
            jax.block_until_ready(scores)
        dt = time.perf_counter() - t0
        ids = np.asarray(ids)[:len(batch)]
        scores = np.asarray(scores)[:len(batch)]
        if rounds is not None:
            # (B,) single-device, (B, shards) sharded: histogram every
            # shard's exit round for the real (non-padding) batch rows
            self._rounds.extend(
                np.asarray(rounds)[:len(batch)].reshape(-1).tolist())
        self.n_batches += 1
        self._occupancy.append(len(batch))
        done = []
        for i, p in enumerate(batch):
            # store-backed engines answer with stable external ids, never
            # raw slots (a slot's occupant changes across swap-deletes)
            out_ids = (self._store.external_ids(ids[i])
                       if self._store is not None else ids[i].copy())
            res = (out_ids, scores[i].copy())
            self._results[p.req_id] = res
            if p.cache_key is not None:
                # salt at put time: if the version bumped while this
                # request was queued, the result files under the live
                # version (not a dead pre-update key)
                self.cache.put(self._salted(p.cache_key), res)
            self._lat.append((now - p.t_submit) + dt)
            if (self._recall_rate > 0.0
                    and self._recall_rng.random() < self._recall_rate):
                self._recalls.append(self._recall_of(p.q, ids[i]))
            done.append(p.req_id)
        if len(self._lat) > 100_000:       # bound the stats memory
            self._lat = self._lat[-10_000:]
        if len(self._occupancy) > 100_000:
            self._occupancy = self._occupancy[-10_000:]
        if len(self._recalls) > 100_000:
            self._recalls = self._recalls[-10_000:]
        if len(self._rounds) > 100_000:
            self._rounds = self._rounds[-10_000:]
        return done, dt

    def _recall_of(self, q: np.ndarray, got_slots: np.ndarray) -> float:
        if self._store is not None:
            # the store's host mirror is updated in O(rows touched) at
            # every apply_updates, so live recall never goes stale
            tbl = self._store.host_table()
            s = tbl @ q
            s[~self._store.live_mask()] = -np.inf
        else:
            if self._table_np is None:
                self._table_np = np.asarray(self._table)
            s = self._table_np @ q
            if self._n_valid is not None:
                s[self._n_valid:] = -np.inf
        exact = np.argpartition(-s, self.K - 1)[:self.K]
        return len(set(exact.tolist()) & set(got_slots.tolist())) / self.K

    # ---- observability --------------------------------------------------

    def _adaptive_stats(self) -> dict:
        """Early-exit telemetry: rounds_used histogram + mean pull frac."""
        out = {"enabled": self._adaptive, "bound": self._bound}
        if not self._adaptive:
            return out
        from repro.core.schedule import pulls_through_round
        hist: Dict[int, int] = {}
        for r in self._rounds:
            hist[int(r)] = hist.get(int(r), 0) + 1
        pulls = pulls_through_round(self.plan.schedule)
        total = max(1, int(pulls[-1]))
        samples = max(1, len(self._rounds))
        mean_pulls = sum(int(pulls[min(r, len(pulls) - 1)]) * c
                         for r, c in hist.items()) / samples
        out.update({
            "samples": len(self._rounds),
            "rounds_hist": {str(k): v for k, v in sorted(hist.items())},
            "mean_rounds": (float(np.mean(self._rounds))
                            if self._rounds else 0.0),
            "mean_pull_frac": mean_pulls / total,
        })
        return out

    def stats(self) -> dict:
        """Per-request latency/recall counters as a plain dict.

        latency_ms percentiles include cache hits (latency 0); recall is
        over the sampled fraction only (``nan`` when nothing was sampled).
        """
        lat = np.asarray(self._lat, np.float64) * 1e3
        occ = np.asarray(self._occupancy, np.float64)
        return {
            "requests": self.n_requests,
            "completed": self.n_requests - len(self._pending),
            "pending": len(self._pending),
            "batches": self.n_batches,
            "full_flushes": self.n_full_flushes,
            "deadline_flushes": self.n_deadline_flushes,
            "mean_batch_occupancy": float(occ.mean()) if occ.size else 0.0,
            "cache": {"hits": self.cache.hits, "misses": self.cache.misses,
                      "entries": len(self.cache),
                      "hit_rate": (self.cache.hits
                                   / max(1, self.cache.hits
                                         + self.cache.misses))},
            "latency_ms": {
                "mean": float(lat.mean()) if lat.size else 0.0,
                "p50": float(np.percentile(lat, 50)) if lat.size else 0.0,
                "p95": float(np.percentile(lat, 95)) if lat.size else 0.0,
                "max": float(lat.max()) if lat.size else 0.0},
            "recall": {"samples": len(self._recalls),
                       "mean": (float(np.mean(self._recalls))
                                if self._recalls else float("nan"))},
            "plan": {"rounds": len(self.plan.schedule.rounds),
                     "pull_speedup": self.plan.schedule.speedup},
            "adaptive": self._adaptive_stats(),
            "updates": {
                "applied": self.n_updates,
                "update_flushes": self.n_update_flushes,
                "recalibrations": self.n_recalibrations,
                "version": self._version,
                "cache_invalidations": self.cache.invalidations,
                "rows_per_s": (self.n_updates / self._update_time_s
                               if self._update_time_s > 0 else 0.0)},
            **({"store": self._store.stats()}
               if self._store is not None else {}),
        }


def simulate_stream(engine: MIPSServeEngine, queries, *,
                    interarrival_ms: float = 0.1, churn=None) -> dict:
    """Drive a query stream through the engine on a virtual clock.

    Arrivals are spaced ``interarrival_ms`` apart on a simulated clock that
    only advances by (a) arrival spacing and (b) *measured* compute time of
    each flush — so batching/deadline dynamics are exercised exactly as in
    wall-clock serving, without sleeps.  ``churn`` (optional) is called as
    ``churn(engine, i)`` before each arrival — stage store mutations there
    to simulate a live corpus; the engine drains them at its next poll
    (mixed read/write streams, BENCH_PR4.json).  Returns the engine stats
    dict plus ``virtual_s`` and ``throughput_rps``.
    """
    now = 0.0
    for i, q in enumerate(queries):
        now = max(now, i * interarrival_ms * 1e-3)
        if churn is not None:
            churn(engine, i)
        engine.submit(q, now=now)
        _, busy = engine.poll(now=now)
        now += busy
    while engine.pending_count:
        now += engine.deadline_s
        _, busy = engine.poll(now=now)
        now += busy
    n = max(1, len(queries))
    return {"virtual_s": now, "throughput_rps": n / max(now, 1e-9),
            **engine.stats()}


def _run_loop(args) -> None:
    """--loop mode: serve a synthetic query stream against the unembedding.

    With ``--dynamic`` the vocab table is wrapped in a
    `repro.store.DynamicTableStore` (or `ShardedTableStore` under
    ``--shards``) and ``--churn-rate`` of the arrivals additionally stage
    an embedding upsert or a delete+append pair — the live-corpus
    scenario (DESIGN.md §11): a growing vocabulary served with zero
    engine rebuilds.
    """
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    mesh = None
    if args.shards > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.shards)
    block = min(512, cfg.d_model)
    churn = None
    if args.dynamic:
        from repro.store import DynamicTableStore, ShardedTableStore
        table = np.asarray(table, np.float32)[:cfg.vocab]
        if mesh is not None:
            store = ShardedTableStore(
                table, mesh=mesh, block=block,
                capacity_slack=args.capacity_slack)
        else:
            store = DynamicTableStore(
                table, block=block, capacity_slack=args.capacity_slack,
                precision=args.precision)
        engine = MIPSServeEngine(
            store, K=args.topk, eps=args.eps, delta=args.delta,
            batch_size=args.batch, deadline_ms=args.deadline_ms,
            mesh=mesh, recall_sample_rate=args.recall_rate,
            cache_entries=args.cache_entries, precision=args.precision,
            adaptive=args.adaptive, bound=args.bound)
        if args.churn_rate > 0:
            crng = np.random.default_rng(1)
            scale = float(np.abs(table).max())

            def churn(eng, i):
                if crng.random() >= args.churn_rate:
                    return
                row = (scale * crng.normal(size=eng.N) / np.sqrt(eng.N)
                       ).astype(np.float32)
                live = store.live_ids()
                if crng.random() < 0.7 or live.size == 0:
                    tgt = (int(crng.choice(live)) if live.size
                           else store.append(row) or 0)
                    store.upsert(tgt, row)
                elif store.free_rows > 0:
                    store.delete(int(crng.choice(live)))
                    store.append(row)
    else:
        engine = MIPSServeEngine(
            table, K=args.topk, eps=args.eps, delta=args.delta,
            batch_size=args.batch, deadline_ms=args.deadline_ms,
            block=block, n_valid=cfg.vocab, mesh=mesh,
            recall_sample_rate=args.recall_rate,
            cache_entries=args.cache_entries, precision=args.precision,
            adaptive=args.adaptive, bound=args.bound)
    print(f"[serve] loop: table=({engine.n},{engine.N}) K={args.topk} "
          f"eps={args.eps} batch={args.batch} "
          f"deadline={args.deadline_ms}ms "
          f"shards={mesh.shape['model'] if mesh else 1} "
          f"dynamic={bool(args.dynamic)} churn={args.churn_rate} "
          f"rounds={len(engine.plan.schedule.rounds)} "
          f"precision={engine.plan.precision} "
          f"adaptive={args.adaptive} bound={args.bound} "
          f"eps_eff={engine.plan.eps_effective:.4f} "
          f"pull_speedup={engine.plan.schedule.speedup:.2f}x")
    rng = np.random.default_rng(0)
    qs = rng.normal(size=(args.requests, engine.N)).astype(np.float32)
    if args.repeat_rate > 0:                  # cacheable duplicate queries
        n_dup = int(args.requests * args.repeat_rate)
        idx = rng.integers(0, max(1, args.requests - n_dup), n_dup)
        qs[args.requests - n_dup:] = qs[idx]
    stats = simulate_stream(engine, qs,
                            interarrival_ms=args.interarrival_ms,
                            churn=churn)
    print(json.dumps(stats, indent=2))


def _run_decode_demo(args) -> None:
    """Default mode: batched prefill + greedy decode with MIPS logits."""
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, mips_mode=args.mips, mips_eps=args.eps,
                              mips_delta=args.delta,
                              mips_precision=args.precision)

    if cfg.mips_mode == "boundedme":
        # the decode hot path runs the whole bandit as ONE fused kernel
        # dispatch per batch (DESIGN.md §3); surface the static plan so the
        # (eps, delta) <-> pull-count trade is visible at launch
        from repro.core.schedule import flatten_schedule
        from repro.kernels.ops import on_tpu
        from repro.models.steps import make_mips_plan
        plan = make_mips_plan(cfg, K=1)
        flat = flatten_schedule(plan.schedule, final_coverage=True)
        path = ("fused pallas_call, dispatches_per_decode_batch=1"
                if on_tpu() else "jnp scan fallback (non-TPU backend)")
        print(f"[serve] fused cascade: rounds={len(plan.schedule.rounds)} "
              f"grid_steps={flat.n_steps} "
              f"precision={plan.precision} "
              f"pull_speedup={plan.schedule.speedup:.2f}x path={path}")

    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    cache_len = P + args.tokens
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        kw["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    t0 = time.time()
    _, caches = prefill_step(params, cfg, prompt, cache_len=cache_len, **kw)
    jax.block_until_ready(caches)
    t_prefill = time.time() - t0

    dfn = jax.jit(lambda p, c, t, pos, k: decode_step(p, cfg, c, t, pos,
                                                      key=k))
    tok = prompt[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.tokens):
        pos = jnp.int32(P + i)
        nxt, caches = dfn(params, caches, tok, pos,
                          jax.random.PRNGKey(i))
        out.append(np.asarray(nxt))
        tok = nxt[:, None]
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"[serve] arch={cfg.name} mips={cfg.mips_mode} "
          f"eps={cfg.mips_eps} batch={B}")
    print(f"[serve] prefill {P} toks: {t_prefill*1e3:.1f} ms; "
          f"decode {args.tokens} toks: {t_decode*1e3:.1f} ms "
          f"({t_decode/args.tokens*1e3:.2f} ms/tok)")
    print(f"[serve] first sequences: {gen[0][:16].tolist()}")


def main():
    """CLI: `--loop` for the request loop, default for the decode demo."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mips", default="exact",
                    choices=["exact", "boundedme"])
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--delta", type=float, default=0.1)
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "int8"],
                    help="sampling arithmetic of the cascade "
                         "(int8 = quantized pulls, widened bounds)")
    ap.add_argument("--adaptive", action="store_true",
                    help="certify per-query early exit at round "
                         "boundaries (DESIGN.md §12); easy queries stop "
                         "pulling inside the same (eps, delta) contract")
    ap.add_argument("--bound", default="hoeffding",
                    choices=["hoeffding", "bernstein"],
                    help="certification radius family for --adaptive "
                         "(bernstein = variance-aware, more pulls/round)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    # request-loop mode
    ap.add_argument("--loop", action="store_true",
                    help="run the micro-batching MIPS request loop")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    ap.add_argument("--interarrival-ms", type=float, default=0.1)
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--cache-entries", type=int, default=512)
    ap.add_argument("--repeat-rate", type=float, default=0.1,
                    help="fraction of requests repeating an earlier query")
    ap.add_argument("--recall-rate", type=float, default=0.05)
    ap.add_argument("--dynamic", action="store_true",
                    help="serve from a mutable DynamicTableStore "
                         "(zero-rebuild upserts/deletes, DESIGN.md §11)")
    ap.add_argument("--churn-rate", type=float, default=0.0,
                    help="fraction of arrivals that also mutate the "
                         "table (needs --dynamic)")
    ap.add_argument("--capacity-slack", type=float, default=1.5,
                    help="store capacity headroom factor (--dynamic)")
    args = ap.parse_args()
    if args.loop:
        _run_loop(args)
    else:
        _run_decode_demo(args)


if __name__ == "__main__":
    main()
