"""Parse compiled/lowered HLO text for collective traffic + roofline terms.

cost_analysis() gives FLOPs and touched bytes, but not collective bytes —
those are summed here from the operand shapes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
(post-SPMD) HLO.
"""

from __future__ import annotations

import re
from typing import Dict

__all__ = ["collective_bytes", "DTYPE_BYTES", "shape_bytes"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def shape_bytes(shape_str: str) -> int:
    """Total bytes of e.g. 'bf16[256,4096]' or a tuple '(f32[8], f32[8])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (output-shape accounting).

    '-start' ops are counted, their '-done' twins skipped, so async
    collectives are not double counted.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += shape_bytes(shape_str)
        counts[kind] += 1
    out_total = {f"{k}_bytes": v for k, v in out.items()}
    out_total.update({f"{k}_count": c for k, c in counts.items()})
    out_total["total_bytes"] = sum(out.values())
    return out_total
