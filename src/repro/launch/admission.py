"""Admission control for the serving runtime (DESIGN.md §13).

The paper's user-facing (eps, delta) knob is also the system's *overload*
lever: unlike index-based MIPS (whose accuracy is frozen into the index),
BoundedME can re-calibrate per dispatch, so a saturated server can shed
**quality** — provably, inside the contract — before it sheds
**availability**.  This module holds the policy half of that story:

  * :class:`PriorityClass` — a named traffic class with a scheduling
    priority and a per-request completion deadline;
  * :class:`ServeResult` — the typed terminal outcome of every request.
    The runtime *never* raises on bad input or overload: a request ends
    as exactly one of ``ok`` / ``degraded`` / ``rejected`` /
    ``overloaded`` / ``failed``, always carrying the (eps, delta) it was
    actually served under (``eps_served``);
  * :class:`AdmissionController` — a bounded priority queue with
    poison-query validation (NaN/Inf/wrong-dim rejected at the door),
    a quarantine of fingerprints that previously broke a dispatch,
    displacement of lower-priority work when a full queue meets a more
    urgent request, and deadline expiry at batch-assembly time;
  * :class:`DegradationLadder` — the load -> eps policy: a precompiled
    ladder of (eps) rungs from the contract eps up to a configured
    ``eps_floor``; queue pressure picks the rung, so overload first
    relaxes accuracy toward the floor and only then rejects.

Everything here is host-side policy with no jax dependency — the
scheduler/executor halves live in `repro.launch.engine`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import heapq
import math
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import MetricsRegistry

__all__ = [
    "STATUSES", "PriorityClass", "ServeResult", "Ticket",
    "AdmissionController", "DegradationLadder", "DeficitRoundRobin",
]

#: The closed set of terminal request outcomes.  ``ok`` and ``degraded``
#: carry answers (degraded = served under a relaxed eps, recorded in
#: ``eps_served``); the other three are typed refusals, never exceptions.
STATUSES = ("ok", "degraded", "rejected", "overloaded", "failed")


@dataclasses.dataclass(frozen=True)
class PriorityClass:
    """A named traffic class: scheduling priority + completion deadline.

    ``priority`` orders batch assembly (lower = more urgent; FIFO within
    a class).  ``deadline_ms`` is the per-request completion budget from
    submit time: a request still queued past it is shed with a typed
    ``overloaded`` result instead of serving an answer nobody is waiting
    for.  ``sheddable=False`` exempts the class from displacement when
    the queue is full (it can still expire on its own deadline).
    """

    name: str
    priority: int = 1
    deadline_ms: float = 50.0
    sheddable: bool = True

    @property
    def deadline_s(self) -> float:
        """The deadline budget in seconds (``inf`` when non-positive)."""
        return self.deadline_ms * 1e-3 if self.deadline_ms > 0 else math.inf


@dataclasses.dataclass
class ServeResult:
    """Typed terminal outcome of one request (DESIGN.md §13 failure model).

    ``status`` is one of `STATUSES`.  ``ids``/``scores`` are set iff the
    request was answered (``ok`` or ``degraded``); ``eps_served`` /
    ``delta_served`` record the contract the answer actually met —
    ``eps_served > eps`` marks graceful degradation under load, never
    silently.  ``reason`` explains refusals (``poison: ...``,
    ``queue full``, ``deadline``, ``quarantined``, dispatch error text);
    ``retries`` counts dispatch retries this request rode through.
    """

    status: str
    ids: Optional[np.ndarray] = None
    scores: Optional[np.ndarray] = None
    eps_served: Optional[float] = None
    delta_served: Optional[float] = None
    reason: str = ""
    cls: str = "default"
    latency_s: float = 0.0
    retries: int = 0
    cached: bool = False
    #: which tenant's table served this request ("" on the
    #: single-table runtimes; set by `repro.launch.tenancy`)
    tenant: str = ""

    @property
    def answered(self) -> bool:
        """True iff this outcome carries (ids, scores) meeting a contract."""
        return self.status in ("ok", "degraded")


@dataclasses.dataclass
class Ticket:
    """One admitted request waiting in the queue."""

    req_id: int
    q: np.ndarray
    cls: PriorityClass
    t_submit: float
    t_deadline: float
    cache_key: Optional[bytes]
    fingerprint: bytes


def _fingerprint(q: np.ndarray) -> bytes:
    """Stable 16-byte digest of a query's exact float32 bytes."""
    return hashlib.blake2b(np.ascontiguousarray(q, np.float32).tobytes(),
                           digest_size=16).digest()


class AdmissionController:
    """Bounded priority queue + request validation + quarantine.

    The runtime's front door (DESIGN.md §13): every query passes
    `validate` (shape / dtype / finiteness — poison queries are rejected
    here, before they can reach a kernel), then the quarantine check
    (fingerprints that previously broke a dispatch are refused outright),
    then capacity admission.  A full queue refuses with a typed
    ``overloaded`` result — or, when the incoming request outranks queued
    sheddable work, displaces the lowest-priority youngest victim
    instead.  `take` assembles dispatch batches in (priority, FIFO)
    order and expires tickets whose class deadline already passed.

    All methods are O(log depth); no jax, no clock reads (callers pass
    ``now`` explicitly, so virtual-clock simulation is exact).
    """

    def __init__(self, dim: int, *, queue_capacity: int = 64,
                 classes: Optional[Dict[str, PriorityClass]] = None,
                 default_class: str = "default",
                 quarantine_capacity: int = 256,
                 metrics: Optional[MetricsRegistry] = None):
        if queue_capacity < 1:
            raise ValueError(f"queue_capacity must be >= 1, "
                             f"got {queue_capacity}")
        self.dim = int(dim)
        self.queue_capacity = int(queue_capacity)
        self.classes = dict(classes) if classes else {}
        if default_class not in self.classes:
            self.classes[default_class] = PriorityClass(default_class)
        self.default_class = default_class
        self._heap: List[Tuple[int, float, int, Ticket]] = []
        self._seq = 0
        self._quarantine: "OrderedDict[bytes, str]" = OrderedDict()
        self.quarantine_capacity = int(quarantine_capacity)
        self.peak_depth = 0
        self._depth_sum = 0.0
        self._depth_samples = 0
        # counters live on the obs registry (shared with the runtime when
        # it passes its own); the legacy n_* attributes read through
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._c_admitted = m.counter(
            "admission_admitted_total", "Tickets enqueued.")
        self._c_rejected = m.counter(
            "admission_rejected_total",
            "Requests refused at the door, by reason.", ("reason",))
        self._c_rejected.seed(reason="poison")
        self._c_rejected.seed(reason="quarantined")
        self._c_overloaded = m.counter(
            "admission_overloaded_total",
            "Requests refused because the queue was full.")
        self._c_displaced = m.counter(
            "admission_displaced_total",
            "Queued sheddable tickets evicted for higher-priority work.")
        self._c_expired = m.counter(
            "admission_expired_total",
            "Tickets shed at batch assembly past their class deadline.")
        g = m.gauge("admission_queue_depth",
                    "Tickets currently queued.")
        g.set_fn(lambda: len(self._heap))
        g = m.gauge("admission_peak_depth",
                    "High-water mark of the queue depth.")
        g.set_fn(lambda: self.peak_depth)
        g = m.gauge("admission_quarantine_entries",
                    "Fingerprints currently quarantined.")
        g.set_fn(lambda: len(self._quarantine))

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def n_admitted(self) -> int:
        """Tickets enqueued."""
        return int(self._c_admitted.total())

    @property
    def n_rejected_poison(self) -> int:
        """Poison (NaN/Inf/shape) rejections (see `count_poison`)."""
        return int(self._c_rejected.get(reason="poison"))

    @property
    def n_rejected_quarantined(self) -> int:
        """Quarantine-hit rejections."""
        return int(self._c_rejected.get(reason="quarantined"))

    @property
    def n_overloaded(self) -> int:
        """Full-queue refusals (no displaceable victim)."""
        return int(self._c_overloaded.total())

    @property
    def n_displaced(self) -> int:
        """Queued tickets evicted by higher-priority arrivals."""
        return int(self._c_displaced.total())

    @property
    def n_expired(self) -> int:
        """Tickets shed past their deadline at batch assembly."""
        return int(self._c_expired.total())

    def count_poison(self) -> None:
        """Count one poison rejection.

        `validate` classifies but doesn't count — the runtime decides
        what a failed validation *means* (it may not even be a request),
        so it calls this when it actually refuses one.
        """
        self._c_rejected.inc(reason="poison")

    # ---- validation / quarantine ----------------------------------------

    def validate(self, q) -> Tuple[Optional[np.ndarray], str]:
        """Coerce one query to (dim,) float32; returns ``(q, "")`` or
        ``(None, reason)`` for poison input (wrong shape / dtype /
        NaN / Inf).  Rejection happens here, at admission — a poison
        query must never reach a dispatch, where its NaNs would poison
        every lane of the micro-batch."""
        try:
            arr = np.asarray(q, np.float32)
        except (TypeError, ValueError):
            return None, "poison: not castable to float32"
        if arr.shape != (self.dim,):
            return None, (f"poison: query shape {arr.shape} != "
                          f"({self.dim},)")
        if not np.all(np.isfinite(arr)):
            return None, "poison: non-finite (NaN/Inf) coordinates"
        return arr, ""

    def quarantined(self, fingerprint: bytes) -> Optional[str]:
        """The quarantine reason for a fingerprint, or None."""
        return self._quarantine.get(fingerprint)

    def add_quarantine(self, fingerprint: bytes, reason: str) -> None:
        """Quarantine a query fingerprint (bounded LRU of offenders).

        Called by the runtime when a dispatch containing this query
        failed past its retry budget: resubmissions of the same bytes
        are refused at admission instead of re-breaking dispatches.
        """
        self._quarantine[fingerprint] = reason
        self._quarantine.move_to_end(fingerprint)
        while len(self._quarantine) > self.quarantine_capacity:
            self._quarantine.popitem(last=False)

    @staticmethod
    def fingerprint(q: np.ndarray) -> bytes:
        """Stable digest used for quarantine identity (exact bytes)."""
        return _fingerprint(q)

    # ---- queue -----------------------------------------------------------

    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet dispatched)."""
        return len(self._heap)

    def resolve_class(self, cls: Optional[str]) -> PriorityClass:
        """Look up a class by name (None = the default class)."""
        name = self.default_class if cls is None else cls
        try:
            return self.classes[name]
        except KeyError:
            raise KeyError(
                f"unknown priority class {name!r}; configured: "
                f"{sorted(self.classes)}") from None

    def admit(self, ticket: Ticket) -> Tuple[
            Optional[ServeResult], List[Tuple[Ticket, ServeResult]]]:
        """Try to enqueue a validated ticket.

        Returns ``(verdict, displaced)``: ``verdict`` is None on success
        or a typed ``rejected``/``overloaded`` `ServeResult`; ``displaced``
        lists (ticket, overloaded-result) pairs for queued lower-priority
        work evicted to make room.  Quarantined fingerprints are refused
        here; capacity refusal prefers displacing the *lowest-priority,
        youngest* sheddable victim when the incoming request strictly
        outranks it.
        """
        reason = self.quarantined(ticket.fingerprint)
        if reason is not None:
            self._c_rejected.inc(reason="quarantined")
            return ServeResult(status="rejected", cls=ticket.cls.name,
                               reason=f"quarantined: {reason}"), []
        displaced: List[Tuple[Ticket, ServeResult]] = []
        if len(self._heap) >= self.queue_capacity:
            victim_i = None
            for i, (pri, t_sub, seq, tk) in enumerate(self._heap):
                if not tk.cls.sheddable or pri <= ticket.cls.priority:
                    continue
                if victim_i is None:
                    victim_i = i
                    continue
                vp, vt, vs, _ = self._heap[victim_i]
                if (pri, t_sub, seq) > (vp, vt, vs):
                    victim_i = i
            if victim_i is None:
                self._c_overloaded.inc()
                return ServeResult(
                    status="overloaded", cls=ticket.cls.name,
                    reason=f"queue full ({self.queue_capacity})"), []
            _, _, _, victim = self._heap.pop(victim_i)
            heapq.heapify(self._heap)
            self._c_displaced.inc()
            displaced.append((victim, ServeResult(
                status="overloaded", cls=victim.cls.name,
                reason="displaced by higher-priority request")))
        heapq.heappush(self._heap, (ticket.cls.priority, ticket.t_submit,
                                    self._seq, ticket))
        self._seq += 1
        self._c_admitted.inc()
        self.peak_depth = max(self.peak_depth, len(self._heap))
        return None, displaced

    def oldest_submit(self) -> Optional[float]:
        """Earliest ``t_submit`` among queued tickets (None when empty)."""
        if not self._heap:
            return None
        return min(item[1] for item in self._heap)

    def take(self, now: float, max_n: int, *, expire: bool = True) -> Tuple[
            List[Ticket], List[Tuple[Ticket, ServeResult]]]:
        """Pop up to ``max_n`` tickets in (priority, FIFO) order.

        Tickets whose class deadline has already passed are *expired*
        instead (typed ``overloaded`` with ``reason='deadline'``) — the
        lane is better spent on a request someone is still waiting for.
        ``expire=False`` (shutdown drain) serves them anyway.  Returns
        ``(batch, expired)``.
        """
        batch: List[Ticket] = []
        expired: List[Tuple[Ticket, ServeResult]] = []
        while self._heap and len(batch) < max_n:
            _, _, _, tk = heapq.heappop(self._heap)
            if expire and now > tk.t_deadline:
                self._c_expired.inc()
                expired.append((tk, ServeResult(
                    status="overloaded", cls=tk.cls.name,
                    reason="deadline",
                    latency_s=now - tk.t_submit)))
                continue
            batch.append(tk)
        self._depth_sum += len(self._heap)
        self._depth_samples += 1
        return batch, expired

    def load(self) -> float:
        """Queue pressure in [0, 1+]: depth / capacity."""
        return len(self._heap) / self.queue_capacity

    def stats(self) -> dict:
        """Admission counters + queue depth telemetry as a plain dict."""
        return {
            "depth": len(self._heap),
            "capacity": self.queue_capacity,
            "peak_depth": self.peak_depth,
            "mean_depth_at_dispatch": (
                self._depth_sum / self._depth_samples
                if self._depth_samples else 0.0),
            "admitted": self.n_admitted,
            "rejected_poison": self.n_rejected_poison,
            "rejected_quarantined": self.n_rejected_quarantined,
            "overloaded": self.n_overloaded,
            "displaced": self.n_displaced,
            "expired_deadline": self.n_expired,
            "quarantine_entries": len(self._quarantine),
        }


class DegradationLadder:
    """Load -> eps policy: relax accuracy toward a floor before refusing.

    Precomputes ``rungs`` eps values geometrically interpolated from the
    contract ``eps`` (rung 0) up to ``eps_floor`` (the worst accuracy the
    operator will serve; DESIGN.md §13 degradation ladder).  `rung(load)`
    maps queue pressure to a rung: below ``start`` load the ladder stays
    at rung 0 (full quality); between ``start`` and 1.0 it climbs
    linearly; at/above full queue it serves the floor.  The runtime
    compiles one executor per rung, so switching rungs costs nothing at
    dispatch time, and each response records its actual ``eps_served`` —
    degradation is always visible, never silent.
    """

    def __init__(self, eps: float, eps_floor: Optional[float] = None, *,
                 rungs: int = 3, start: float = 0.5):
        if eps_floor is None:
            eps_floor = eps
        if eps_floor < eps:
            raise ValueError(
                f"eps_floor ({eps_floor}) must be >= eps ({eps}): "
                f"degradation relaxes eps toward the floor, it cannot "
                f"tighten it")
        if not 0.0 < start <= 1.0:
            raise ValueError(f"start must be in (0, 1], got {start}")
        rungs = max(1, int(rungs))
        if eps_floor == eps:
            rungs = 1
        if rungs == 1:
            self.eps_values = [float(eps)]
        else:
            # geometric interpolation: early rungs give up little
            # accuracy, the last rung lands exactly on the floor
            ratio = (eps_floor / eps) ** (1.0 / (rungs - 1))
            self.eps_values = [float(eps * ratio ** i)
                               for i in range(rungs)]
            self.eps_values[-1] = float(eps_floor)
        self.eps = float(eps)
        self.eps_floor = float(eps_floor)
        self.start = float(start)

    @property
    def n_rungs(self) -> int:
        """Number of rungs (1 = degradation disabled)."""
        return len(self.eps_values)

    def rung(self, load: float) -> int:
        """Map queue pressure (depth/capacity) to a ladder rung index."""
        if self.n_rungs == 1 or load < self.start:
            return 0
        if load >= 1.0:
            return self.n_rungs - 1
        frac = (load - self.start) / (1.0 - self.start)
        return min(self.n_rungs - 1, 1 + int(frac * (self.n_rungs - 1)))


class DeficitRoundRobin:
    """Deficit-round-robin service allocator for cross-tenant fairness.

    Classic DRR (Shreedhar & Varghese) over named flows: each round, a
    *backlogged* flow's deficit grows by ``quantum * weight`` (capped at
    ``cap_rounds`` rounds' worth so an intermittently-backlogged flow
    cannot hoard service credit), and the flow may serve work costing up
    to its current deficit.  A flow whose queue empties forfeits its
    remaining deficit (`reset`) — credit never survives idleness, which is
    what bounds any flow's burst to O(quantum) over fair share.  The
    service order rotates one flow per round so ties break fairly.

    The multi-tenant runtime uses request count as the cost unit with
    ``quantum = lanes``: with every tenant backlogged, each gets about
    one full dispatch per round regardless of arrival-rate skew — an
    8x-hot tenant is throttled to its share instead of starving the
    rest, and an idle tenant costs nothing (work-conserving).

    Host-side policy only; no clock, no jax.
    """

    def __init__(self, quantum: float, *, cap_rounds: float = 2.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        if cap_rounds < 1.0:
            raise ValueError(f"cap_rounds must be >= 1, got {cap_rounds}")
        self.quantum = float(quantum)
        self.cap_rounds = float(cap_rounds)
        self._order: List[str] = []
        self._weight: Dict[str, float] = {}
        self._deficit: Dict[str, float] = {}

    def add_flow(self, name: str, weight: float = 1.0) -> None:
        """Register a flow at ``weight`` x the base quantum (idempotent;
        re-adding updates the weight, keeps the deficit)."""
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        if name not in self._weight:
            self._order.append(name)
            self._deficit[name] = 0.0
        self._weight[name] = float(weight)

    def remove_flow(self, name: str) -> None:
        """Drop a flow and its deficit (no-op if unknown)."""
        if name in self._weight:
            self._order.remove(name)
            del self._weight[name]
            del self._deficit[name]

    def flows(self) -> List[str]:
        """Current service order (rotates one step per `rotate`)."""
        return list(self._order)

    def start_round(self, backlogged: Dict[str, bool]) -> None:
        """Grant each backlogged flow its per-round quantum (capped)."""
        for name in self._order:
            if backlogged.get(name, False):
                w = self._weight[name]
                self._deficit[name] = min(
                    self._deficit[name] + self.quantum * w,
                    self.cap_rounds * self.quantum * w)

    def allowance(self, name: str) -> int:
        """Whole service units the flow may consume right now."""
        return int(self._deficit[name])

    def consume(self, name: str, cost: float) -> None:
        """Charge served work against the flow's deficit."""
        self._deficit[name] = max(0.0, self._deficit[name] - float(cost))

    def reset(self, name: str) -> None:
        """Forfeit a now-idle flow's deficit (credit never survives
        idleness — the DRR burst bound depends on this)."""
        self._deficit[name] = 0.0

    def rotate(self) -> None:
        """Advance the service order by one flow (fair tie-breaking)."""
        if len(self._order) > 1:
            self._order.append(self._order.pop(0))
