"""Multi-tenant, multi-table serving on one device pool (DESIGN.md §16).

The paper's no-preprocessing selling point is what makes per-tenant
tables cheap: BoundedME needs no index build, so spinning up a corpus is
one store construction and serving it is one calibrated plan — unlike
LSH/PCA-tree baselines that pay a rebuild per corpus.  This module turns
that into a serving architecture, completing the ROADMAP's scheduler /
table-manager / executor split:

  * :class:`TenantConfig` — one tenant's serving contract: (eps, delta)
    with an optional degradation floor, precision tier, bound family,
    pull mode, priority/deadline class, queue capacity, DRR weight,
    store capacity and residency pinning.
  * :class:`TableRegistry` — the **table manager**: named
    `repro.store.DynamicTableStore` / `ShardedTableStore` instances
    under a device-memory byte budget.  Hot tables stay resident; cold
    tables are paged out LRU-by-last-serve (`DynamicTableStore.
    page_state` round-trips bit-identically — version, shadow, codebook
    and staged mutations preserved) so registering a new tenant *never*
    OOMs the pool: it either fits after evictions or is refused with a
    typed :class:`TenancyError`.  Pinned and in-flight tables are never
    evicted; sharded tables are auto-pinned (their per-shard slot pools
    are device-pool state with no page image).  The registry also owns
    the bounded **per-table executor cache**: degradation ladders of
    `repro.launch.engine.CascadeExecutor` keyed on (tenant, store
    identity, capacity, codebook refreshes) — the salt is what
    invalidates stale executors on `grow()` / `refresh_codebook()` /
    page-in, and value-range growth rebuilds on acquire (the same
    recalibration rule `CascadeExecutor.sync_store` applies).
  * :class:`MultiTenantRuntime` — the **scheduler**: per-tenant
    admission queues (a flood or poison storm from one tenant can only
    fill its own queue), per-tenant degradation ladders, caches and PRNG
    streams, and deficit-round-robin batch assembly
    (`repro.launch.admission.DeficitRoundRobin`) across tenants so one
    hot tenant cannot starve the rest.  Per-tenant serving state is
    deliberately identical to a dedicated single-tenant `ServeRuntime`
    with the same config — the tenant-isolation suite asserts answers
    are *bit-identical* to dedicated engines.

Observability: every ``serve_*`` family carries a ``tenant`` label,
spans are annotated with the tenant at `request_begin`, and the flight
recorder logs registration / eviction / page-in / executor-rebuild
events.  Store registries are **not** adopted (two stores' ``store_*``
gauges would collide); per-tenant store stats surface through
``stats()["tenants"]`` instead.
"""

from __future__ import annotations

import contextlib
import dataclasses
import struct
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.launch.admission import (AdmissionController, DeficitRoundRobin,
                                    DegradationLadder, PriorityClass,
                                    ServeResult, Ticket)
from repro.launch.engine import (CascadeExecutor, DispatchFailed,
                                 QuantizedLRU, dispatch_with_retries)
from repro.obs.metrics import (MetricsRegistry, PULL_FRAC_BUCKETS,
                               summarize_latencies)

__all__ = ["TenancyError", "TenantConfig", "TableRegistry",
           "MultiTenantRuntime"]

_PRECISIONS = ("fp32", "int8", "int4", "pq")


class TenancyError(RuntimeError):
    """Typed refusal from the table registry.

    Raised instead of letting the device pool OOM: a registration that
    cannot fit inside the byte budget even after evicting every
    evictable table, an eviction of a pinned/in-flight/sharded table,
    or an operation on an unknown tenant.  The pool's resident state is
    unchanged when this raises.
    """


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's serving contract and placement policy.

    The serving knobs mirror `repro.launch.engine.ServeRuntime`'s
    constructor — a tenant served through `MultiTenantRuntime` under
    this config gets answers bit-identical to a dedicated single-tenant
    runtime built with the same arguments and seed.  The placement
    knobs are tenancy-specific: ``weight`` scales the tenant's
    deficit-round-robin share, ``priority`` / ``deadline_ms`` define its
    single priority class, ``queue_capacity`` bounds its private
    admission queue (flood isolation), ``capacity`` provisions its
    store, and ``pinned`` exempts its table from LRU eviction.
    """

    # serving contract
    K: int = 1
    eps: float = 0.1
    delta: float = 0.1
    eps_floor: Optional[float] = None
    degrade_rungs: int = 3
    degrade_start: float = 0.5
    precision: str = "fp32"
    bound: str = "hoeffding"
    pull_mode: str = "row"
    coord_block: int = 128
    quant_err: Optional[float] = None
    pq_subdims: int = 8
    pq_codes: int = 16
    adaptive: bool = False
    value_range: Optional[float] = None
    qmax_hint: float = 1.0
    range_slack: float = 1.0
    tile: int = 8
    block: int = 512
    # per-tenant cache
    cache_entries: int = 512
    cache_resolution: float = 1e-3
    # placement / scheduling policy
    weight: float = 1.0
    priority: int = 1
    deadline_ms: float = 50.0
    queue_capacity: int = 64
    capacity: Optional[int] = None
    pinned: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.precision not in _PRECISIONS:
            raise ValueError(f"unknown precision {self.precision!r} "
                             f"(expected one of {_PRECISIONS})")
        if self.eps <= 0:
            raise ValueError(f"eps must be > 0, got {self.eps}")
        if not 0.0 < self.delta < 1.0:
            raise ValueError(f"delta must be in (0, 1), got {self.delta}")
        if self.weight <= 0:
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.queue_capacity < 1:
            raise ValueError(f"queue_capacity must be >= 1, "
                             f"got {self.queue_capacity}")

    def ladder(self) -> DegradationLadder:
        """This tenant's degradation ladder (eps -> eps_floor rungs)."""
        return DegradationLadder(self.eps, self.eps_floor,
                                 rungs=self.degrade_rungs,
                                 start=self.degrade_start)

    def priority_classes(self) -> Dict[str, PriorityClass]:
        """The tenant's single admission class, from priority/deadline."""
        return {"default": PriorityClass("default", priority=self.priority,
                                         deadline_ms=self.deadline_ms)}


@dataclasses.dataclass
class _TableEntry:
    """Registry-internal record of one tenant's table."""

    name: str
    config: TenantConfig
    store: object                    # live store, or None while paged out
    page: Optional[dict]             # page_state image while paged out
    nbytes: int
    pinned: bool
    sharded: bool
    mesh: object
    last_serve: int
    in_flight: bool = False
    exec_salt: Optional[tuple] = None


class TableRegistry:
    """Byte-budgeted registry of named tenant tables + executor cache.

    The table-manager layer (DESIGN.md §16).  `register` builds (or
    adopts) a store per tenant and admits it against ``byte_budget``,
    evicting cold tables LRU-by-last-serve first — registration either
    fits or raises a typed `TenancyError`, never an OOM.  `executors`
    hands out each tenant's degradation ladder of compiled
    `CascadeExecutor` rungs from a bounded LRU cache whose key is
    salted with (store identity, ``capacity_rows``,
    ``codebook_refreshes``): `grow()`, `refresh_codebook()` and a
    page-in each change the salt and force a rebuild (re-measuring pq
    ``quant_err`` against the new codebook — the stale-executor fix),
    while value-range growth recalibrates on acquire via
    `CascadeExecutor.sync_store`.

    Invariants (enforced here, asserted by the registry property
    suite): resident bytes never exceed ``byte_budget``; pinned,
    in-flight and sharded tables are never evicted; evictions always
    pick the least-recently-served evictable table; a paged table
    round-trips bit-identically (`DynamicTableStore.page_state`).

    Not thread-safe; drive it from the runtime's loop.
    """

    def __init__(self, *, byte_budget: Optional[int] = None,
                 max_executors: int = 8, lanes: int = 8,
                 use_pallas: Optional[bool] = None,
                 warm_on_build: bool = True,
                 metrics: Optional[MetricsRegistry] = None,
                 flight=None):
        if max_executors < 1:
            raise ValueError(f"max_executors must be >= 1, "
                             f"got {max_executors}")
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        self.byte_budget = None if byte_budget is None else int(byte_budget)
        self.max_executors = int(max_executors)
        self.lanes = int(lanes)
        self.use_pallas = use_pallas
        self.warm_on_build = bool(warm_on_build)
        self.flight = flight
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._entries: "OrderedDict[str, _TableEntry]" = OrderedDict()
        self._exec_cache: "OrderedDict[tuple, list]" = OrderedDict()
        self._serve_clock = 0
        m = self.metrics
        self._c_registrations = m.counter(
            "tenancy_registrations_total", "Tenant tables registered.",
            ("tenant",))
        self._c_evictions = m.counter(
            "tenancy_evictions_total",
            "Tables paged out of device memory.", ("tenant",))
        self._c_page_ins = m.counter(
            "tenancy_page_ins_total",
            "Tables paged back into device memory.", ("tenant",))
        self._c_exec_builds = m.counter(
            "tenancy_executor_builds_total",
            "Executor-ladder (re)builds, by cause.", ("tenant", "cause"))
        self._h_page_in = m.histogram(
            "tenancy_page_in_ms", "Page-in (store rebuild) cost (ms).")
        self._h_warm = m.histogram(
            "tenancy_warm_ms",
            "Off-clock jit warm cost per executor-ladder build (ms).")
        m.gauge("tenancy_resident_bytes",
                "Device bytes of resident tenant tables.",
                ).set_fn(self.resident_bytes)
        m.gauge("tenancy_byte_budget", "Configured device byte budget.",
                ).set_fn(lambda: (-1 if self.byte_budget is None
                                  else self.byte_budget))
        m.gauge("tenancy_tables_resident", "Tables currently resident.",
                ).set_fn(lambda: sum(1 for e in self._entries.values()
                                     if e.store is not None))
        m.gauge("tenancy_executor_cache_entries",
                "Cached executor ladders.",
                ).set_fn(lambda: len(self._exec_cache))

    # ---- introspection ----------------------------------------------------

    def tenants(self) -> List[str]:
        """Registered tenant names, in registration order."""
        return list(self._entries)

    def config(self, name: str) -> TenantConfig:
        """A tenant's config."""
        return self._entry(name).config

    def is_resident(self, name: str) -> bool:
        """True iff the tenant's table is on device right now."""
        return self._entry(name).store is not None

    def is_pinned(self, name: str) -> bool:
        """True iff the tenant's table is exempt from eviction."""
        return self._entry(name).pinned

    def table_bytes(self, name: str) -> int:
        """Device bytes the tenant's table occupies when resident."""
        return self._entry(name).nbytes

    def resident_bytes(self) -> int:
        """Total device bytes of currently-resident tables."""
        return sum(e.nbytes for e in self._entries.values()
                   if e.store is not None)

    def store(self, name: str):
        """The tenant's live store, or None while paged out (use
        `ensure_resident` to page in)."""
        return self._entry(name).store

    def lru_order(self) -> List[str]:
        """Evictable resident tenants, least-recently-served first."""
        evictable = [e for e in self._entries.values()
                     if self._evictable(e)]
        return [e.name for e in sorted(evictable,
                                       key=lambda e: e.last_serve)]

    def _entry(self, name: str) -> _TableEntry:
        try:
            return self._entries[name]
        except KeyError:
            raise TenancyError(f"unknown tenant {name!r}; registered: "
                               f"{sorted(self._entries)}") from None

    # ---- registration / residency -----------------------------------------

    def register(self, name: str, table, config: Optional[TenantConfig]
                 = None, *, mesh=None):
        """Admit a new tenant table under the byte budget; returns it.

        ``table`` may be raw (n, N) rows (a store is built with the
        config's geometry/precision/capacity), or an existing
        `DynamicTableStore` / `ShardedTableStore` to adopt.  ``mesh``
        builds a `ShardedTableStore` over the device pool — sharded
        tables are auto-pinned.  If admitting the table would exceed
        ``byte_budget``, cold evictable tables are paged out LRU-first;
        when even that cannot make room the registration is refused
        with `TenancyError` and the pool is left exactly as it was —
        registering a tenant never OOMs.
        """
        if name in self._entries:
            raise TenancyError(f"tenant {name!r} already registered")
        config = config if config is not None else TenantConfig()
        from repro.store import DynamicTableStore, ShardedTableStore
        if isinstance(table, (DynamicTableStore, ShardedTableStore)):
            store = table
        elif mesh is not None:
            store = ShardedTableStore(
                table, mesh=mesh, capacity=config.capacity,
                tile=config.tile, block=config.block)
        else:
            store = DynamicTableStore(
                table, capacity=config.capacity, tile=config.tile,
                block=config.block, precision=config.precision,
                pq_subdims=config.pq_subdims, pq_codes=config.pq_codes)
        sharded = isinstance(store, ShardedTableStore)
        nbytes = int(store.resident_bytes())
        if self.byte_budget is not None and nbytes > self.byte_budget:
            raise TenancyError(
                f"tenant {name!r} needs {nbytes} bytes > budget "
                f"{self.byte_budget}: table cannot fit even alone")
        self._make_room(nbytes)
        self._serve_clock += 1
        entry = _TableEntry(
            name=name, config=config, store=store, page=None,
            nbytes=nbytes, pinned=bool(config.pinned) or sharded,
            sharded=sharded, mesh=mesh, last_serve=self._serve_clock)
        self._entries[name] = entry
        self._c_registrations.inc(tenant=name)
        if self.flight is not None:
            self.flight.record("tenant_registered", None, tenant=name,
                               bytes=nbytes, pinned=entry.pinned,
                               sharded=sharded,
                               resident_bytes=self.resident_bytes())
        return store

    def remove(self, name: str) -> None:
        """Drop a tenant entirely (store, page image, cached executors)."""
        entry = self._entry(name)
        if entry.in_flight:
            raise TenancyError(f"tenant {name!r} is in flight")
        self._drop_executors(name)
        del self._entries[name]

    def _evictable(self, entry: _TableEntry) -> bool:
        return (entry.store is not None and not entry.pinned
                and not entry.in_flight and not entry.sharded)

    def _make_room(self, incoming: int) -> None:
        """Page out LRU evictable tables until ``incoming`` bytes fit."""
        if self.byte_budget is None:
            return
        while self.resident_bytes() + incoming > self.byte_budget:
            order = self.lru_order()
            if not order:
                raise TenancyError(
                    f"cannot make room for {incoming} bytes: "
                    f"{self.resident_bytes()} resident, every table "
                    f"pinned or in flight (budget {self.byte_budget})")
            self.evict(order[0])

    def evict(self, name: str) -> None:
        """Page one table out of device memory (refuses pinned /
        in-flight / sharded tables with `TenancyError`).

        The page image (`DynamicTableStore.page_state`) preserves rows,
        ids, version, value range, the frozen pq codebook and staged
        mutations, so the next serve's page-in rebuilds the store
        bit-identically.  Cached executors for the tenant are dropped
        (they hold the dead store object).
        """
        entry = self._entry(name)
        if entry.store is None:
            return
        if entry.sharded:
            raise TenancyError(f"tenant {name!r} is sharded (auto-pinned: "
                               f"per-shard slot pools have no page image)")
        if entry.pinned:
            raise TenancyError(f"tenant {name!r} is pinned; unpin before "
                               f"evicting")
        if entry.in_flight:
            raise TenancyError(f"tenant {name!r} is in flight")
        entry.page = entry.store.page_state()
        entry.store = None
        self._drop_executors(name)
        self._c_evictions.inc(tenant=name)
        if self.flight is not None:
            self.flight.record("tenant_evicted", None, tenant=name,
                               bytes=entry.nbytes,
                               resident_bytes=self.resident_bytes())

    def _reaccount(self, entry: _TableEntry) -> None:
        """Refresh one resident table's byte accounting and rebalance.

        ``grow()`` happens on the store, outside the registry — the next
        acquire lands here and trues up ``entry.nbytes``.  If growth
        pushed the pool over budget, colder evictable tables are paged
        out first; when nothing else is evictable the grown table itself
        is paged back out and the acquire refused with `TenancyError` —
        unless it is pinned, the one operator action allowed to override
        the budget (kept resident, surfaced on the flight recorder).
        """
        store = entry.store
        if store is None:
            return
        nb = int(store.resident_bytes())
        if nb == entry.nbytes:
            return
        entry.nbytes = nb
        if self.byte_budget is None:
            return
        guard = entry.in_flight
        entry.in_flight = True
        try:
            self._make_room(0)
            return
        except TenancyError:
            pass
        finally:
            entry.in_flight = guard
        if entry.pinned:
            if self.flight is not None:
                self.flight.record("budget_overridden", None,
                                   tenant=entry.name, bytes=nb,
                                   budget=self.byte_budget)
            return
        entry.in_flight = False
        try:
            self.evict(entry.name)
        finally:
            entry.in_flight = guard
        raise TenancyError(
            f"tenant {entry.name!r} grew to {nb} bytes and nothing else "
            f"is evictable (budget {self.byte_budget}); paged back out")

    def ensure_resident(self, name: str) -> float:
        """Page the tenant's table in if needed; returns page-in seconds.

        Page-in may itself evict colder tables to fit the budget; the
        in-flight flag protects the paging tenant from being chosen as
        its own victim.  A resident table is re-accounted against the
        budget (its store may have grown since the last acquire — see
        `_reaccount`).
        """
        entry = self._entry(name)
        if entry.store is not None:
            self._reaccount(entry)
            return 0.0
        from repro.store import DynamicTableStore
        t0 = time.perf_counter()
        guard = entry.in_flight
        entry.in_flight = True
        try:
            self._make_room(entry.nbytes)
            entry.store = DynamicTableStore.from_page(entry.page)
        finally:
            entry.in_flight = guard
        entry.page = None
        dt = time.perf_counter() - t0
        self._c_page_ins.inc(tenant=name)
        self._h_page_in.observe(dt * 1e3)
        if self.flight is not None:
            self.flight.record("tenant_paged_in", None, tenant=name,
                               bytes=entry.nbytes, seconds=dt,
                               resident_bytes=self.resident_bytes())
        return dt

    def pin(self, name: str) -> None:
        """Exempt a tenant's table from LRU eviction."""
        self._entry(name).pinned = True

    def unpin(self, name: str) -> None:
        """Make a tenant's table evictable again (sharded tables stay
        pinned — they have no page image).

        If pinned growth had pushed the pool past the budget (the
        operator override `_reaccount` allows), releasing a pin
        rebalances immediately: newly-evictable tables are paged out
        LRU-first until the budget holds again.
        """
        entry = self._entry(name)
        if entry.sharded:
            return
        entry.pinned = False
        if self.byte_budget is not None:
            try:
                self._make_room(0)
            except TenancyError:
                pass    # remaining overage is all pinned growth

    def touch(self, name: str) -> None:
        """Record a serve for LRU purposes (freshest = last evicted)."""
        self._serve_clock += 1
        self._entry(name).last_serve = self._serve_clock

    @contextlib.contextmanager
    def serving(self, name: str):
        """Mark a tenant in-flight for the duration of a dispatch:
        in-flight tables are never chosen as eviction victims."""
        entry = self._entry(name)
        entry.in_flight = True
        try:
            yield entry
        finally:
            entry.in_flight = False

    # ---- executor cache ---------------------------------------------------

    def _salt(self, entry: _TableEntry) -> tuple:
        store = entry.store
        return (id(store), store.capacity_rows,
                getattr(store, "codebook_refreshes", 0))

    def _drop_executors(self, name: str) -> None:
        for key in [k for k in self._exec_cache if k[0] == name]:
            del self._exec_cache[key]

    def executors(self, name: str) -> Tuple[List[CascadeExecutor], float]:
        """The tenant's degradation-ladder executors, cache- and
        residency-managed; returns ``(executors, page_in_seconds)``.

        Ensures the table is resident (paging it in if evicted) and
        touches its LRU stamp.  The cache key is salted with the store
        object's identity, ``capacity_rows`` and ``codebook_refreshes``
        — so `grow()`, `refresh_codebook()` and page-in each miss and
        rebuild (a pq rebuild re-measures ``quant_err`` against the
        current codebook).  On a hit, `CascadeExecutor.sync_store` still
        runs per rung, recalibrating in place when the store's monotonic
        value range outgrew the plan.  The cache holds at most
        ``max_executors`` ladders, LRU-evicted — invalidated or evicted
        ladders are rebuilt on the next acquire, so a bounded jit cache
        is the only cost of many tenants.
        """
        page_s = self.ensure_resident(name)
        entry = self._entry(name)
        self.touch(name)
        salt = self._salt(entry)
        key = (name, salt)
        execs = self._exec_cache.get(key)
        if execs is not None:
            self._exec_cache.move_to_end(key)
            for ex in execs:
                ex.sync_store()
            return execs, page_s
        cause = "new"
        if entry.exec_salt is not None:
            old = entry.exec_salt
            # store identity first: a page-in rebuilds the store object,
            # restarting its churn counters, so refresh/capacity deltas
            # are only meaningful for the SAME store object
            if salt[0] != old[0]:
                cause = "page_in"
            elif salt[2] != old[2]:
                cause = "codebook_refresh"
            elif salt[1] != old[1]:
                cause = "grow"
            else:
                cause = "cache_evicted"
        self._drop_executors(name)
        cfg = entry.config
        ladder = cfg.ladder()
        execs = [CascadeExecutor(
            entry.store, K=cfg.K, eps=e, delta=cfg.delta,
            value_range=cfg.value_range, qmax_hint=cfg.qmax_hint,
            tile=cfg.tile, block=cfg.block, lanes=self.lanes,
            mesh=entry.mesh, use_pallas=self.use_pallas,
            precision=cfg.precision, range_slack=cfg.range_slack,
            adaptive=cfg.adaptive, bound=cfg.bound,
            pull_mode=cfg.pull_mode, coord_block=cfg.coord_block,
            quant_err=cfg.quant_err, pq_subdims=cfg.pq_subdims,
            pq_codes=cfg.pq_codes, metrics=self.metrics,
            metrics_labels={"tenant": name, "rung": str(i)})
            for i, e in enumerate(ladder.eps_values)]
        entry.exec_salt = salt
        self._exec_cache[key] = execs
        self._c_exec_builds.inc(tenant=name, cause=cause)
        warm_s = 0.0
        if self.warm_on_build:
            # compile off the serving clock, like ServeRuntime.warmup:
            # otherwise the first dispatch after a page-in/grow rebuild
            # is charged the whole jit retrace and reads as an overload
            t0 = time.perf_counter()
            Qz = np.zeros((self.lanes, entry.store.N), np.float32)
            wkey = jax.random.PRNGKey(0)
            for ex in execs:
                ex.dispatch(Qz, wkey)
            warm_s = time.perf_counter() - t0
            self._h_warm.observe(warm_s * 1e3)
        if self.flight is not None and cause != "new":
            self.flight.record("executor_rebuild", None, tenant=name,
                               cause=cause, warm_ms=warm_s * 1e3)
        while len(self._exec_cache) > self.max_executors:
            self._exec_cache.popitem(last=False)
        return execs, page_s

    def executor_cache_size(self) -> int:
        """Cached executor ladders (bounded by ``max_executors``)."""
        return len(self._exec_cache)

    def executor_builds(self, name: str) -> Dict[str, int]:
        """Per-cause ladder (re)build counts for one tenant."""
        out: Dict[str, int] = {}
        for labels, value in self._c_exec_builds.rows():
            if labels["tenant"] == name:
                out[labels["cause"]] = int(value)
        return out

    def stats(self) -> dict:
        """Registry telemetry: budget, residency, per-tenant placement."""
        return {
            "byte_budget": self.byte_budget,
            "resident_bytes": self.resident_bytes(),
            "tables": len(self._entries),
            "tables_resident": sum(1 for e in self._entries.values()
                                   if e.store is not None),
            "executor_cache_entries": len(self._exec_cache),
            "evictions": int(self._c_evictions.total()),
            "page_ins": int(self._c_page_ins.total()),
            "tenants": {e.name: {
                "resident": e.store is not None,
                "bytes": e.nbytes,
                "pinned": e.pinned,
                "sharded": e.sharded,
                "last_serve": e.last_serve,
                "executor_builds": self.executor_builds(e.name),
            } for e in self._entries.values()},
        }


class _TenantState:
    """Runtime-internal per-tenant serving state.

    Deliberately mirrors a dedicated `ServeRuntime`'s internals — own
    admission queue, ladder, result cache, PRNG key and dispatch
    sequence — so serving through the multi-tenant scheduler is
    bit-identical to a dedicated engine given the same config/seed and
    batch composition.
    """

    def __init__(self, name: str, config: TenantConfig, dim: int,
                 store_version: int, refreshes: int):
        self.name = name
        self.config = config
        self.ladder = config.ladder()
        # private metrics registry: per-tenant AdmissionControllers must
        # not share gauge rows (set_fn would be overwritten); per-tenant
        # queue stats surface via stats()["tenants"] instead
        self.admission = AdmissionController(
            dim, queue_capacity=config.queue_capacity,
            classes=config.priority_classes(),
            metrics=MetricsRegistry())
        self.cache = QuantizedLRU(config.cache_entries,
                                  config.cache_resolution)
        self.key = jax.random.PRNGKey(config.seed)
        self.dispatch_seq = 0
        self.version = store_version
        self.seen_refreshes = refreshes
        self.lat: List[float] = []
        self.requests = 0
        self.outcomes = {s: 0 for s in ("ok", "degraded", "rejected",
                                        "overloaded", "failed")}

    def salted(self, base_key: bytes) -> bytes:
        return struct.pack("<qi", self.version, self.config.K) + base_key


class MultiTenantRuntime:
    """Fair cross-tenant continuous-batching scheduler (DESIGN.md §16).

    Drives many tenants' tables through one device pool: requests carry
    a ``tenant`` id at `submit`, land in that tenant's *private*
    admission queue (poison floods and overload from one tenant can
    only fill its own bounded queue — isolation by construction), and
    `poll` assembles per-(table, plan) micro-batches under
    deficit-round-robin: each round, every backlogged tenant's deficit
    grows by ``lanes * weight`` and it may dispatch up to its deficit —
    with every tenant backlogged each gets about one full dispatch per
    round regardless of arrival skew, so a hot tenant is throttled to
    its fair share rather than starving the rest, while idle tenants
    cost nothing (work-conserving).  Executors come from the
    `TableRegistry`'s bounded cache; acquiring them pages the tenant's
    table back in when it was evicted (the page-in cost is charged to
    the dispatch's virtual busy time), and the in-flight guard keeps
    the serving table off the eviction candidate list.

    Per-tenant results are bit-identical to a dedicated single-tenant
    `ServeRuntime` with the same `TenantConfig` and batch composition:
    each tenant has its own PRNG key (``PRNGKey(config.seed)`` folded
    on a private dispatch sequence), ladder, cache and queue, and the
    dispatch path is the same retry/fault machinery
    (`repro.launch.engine.dispatch_with_retries`).  Every request
    terminates as a typed `ServeResult` (with ``tenant`` set); traffic
    never raises.

    ``stats()`` keeps the single-runtime top-level shape (``requests``
    / ``completed`` / ``outcomes`` / ``latency_ms`` ...) aggregated
    across tenants — stream drivers and ``--check-outcomes`` work
    unchanged — plus ``tenants`` (per-tenant breakdowns) and
    ``registry`` (residency/eviction telemetry).
    """

    def __init__(self, registry: TableRegistry, *,
                 batch_wait_ms: float = 2.0, max_retries: int = 2,
                 retry_backoff_ms: float = 1.0,
                 dispatch_timeout_ms: Optional[float] = None,
                 fault_injector=None, recall_sample_rate: float = 0.0,
                 drr_cap_rounds: float = 2.0, seed: int = 0,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None, flight=None):
        if batch_wait_ms <= 0:
            raise ValueError(f"batch_wait_ms must be > 0, "
                             f"got {batch_wait_ms}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.registry = registry
        self.lanes = registry.lanes
        self.batch_wait_s = float(batch_wait_ms) * 1e-3
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_ms) * 1e-3
        self.dispatch_timeout_s = (None if dispatch_timeout_ms is None
                                   else float(dispatch_timeout_ms) * 1e-3)
        self.injector = fault_injector
        self.metrics = metrics if metrics is not None else registry.metrics
        if self.metrics is not registry.metrics:
            self.metrics.adopt(registry.metrics)
        if fault_injector is not None:
            self.metrics.adopt(fault_injector.metrics)
        self.tracer = tracer
        self.flight = flight if flight is not None else registry.flight
        self.drr = DeficitRoundRobin(self.lanes, cap_rounds=drr_cap_rounds)
        self._states: Dict[str, _TenantState] = {}
        self._results: Dict[int, ServeResult] = {}
        self._next_id = 0
        self._recall_rate = float(recall_sample_rate)
        self._recall_rng = np.random.default_rng(seed)
        self._recalls: List[float] = []
        self._lat: List[float] = []
        self._occupancy: List[int] = []
        self._pull_fracs: List[float] = []
        m = self.metrics
        self._c_requests = m.counter(
            "serve_requests_total", "Requests submitted, by tenant/class.",
            ("tenant", "priority_class"))
        self._c_outcomes = m.counter(
            "serve_outcomes_total",
            "Terminal request outcomes, by tenant.", ("tenant", "outcome"))
        self._c_cache_hits = m.counter(
            "serve_cache_hits_total",
            "Requests answered from a tenant LRU.", ("tenant",))
        self._c_dispatches = m.counter(
            "serve_dispatches_total",
            "Batch dispatches, by tenant and lane occupancy.",
            ("tenant", "filled"))
        self._c_retries = m.counter(
            "serve_retries_total", "Dispatch retry attempts.", ("tenant",))
        self._c_dispatch_errors = m.counter(
            "serve_dispatch_errors_total",
            "Dispatch attempts that raised (injected or real).",
            ("tenant",))
        self._c_failed_batches = m.counter(
            "serve_failed_batches_total",
            "Micro-batches failed past the retry budget.", ("tenant",))
        self._c_slow = m.counter(
            "serve_slow_dispatches_total",
            "Dispatches exceeding dispatch_timeout_ms.", ("tenant",))
        self._c_flush_failures = m.counter(
            "serve_store_flush_failures_total",
            "Store flushes failed by StoreFlushError (retried later).",
            ("tenant",))
        self._c_update_errors = m.counter(
            "serve_update_errors_total",
            "Store flushes that raised a non-flush error.", ("tenant",))
        self._c_update_rows = m.counter(
            "serve_update_rows_total", "Store mutations applied.",
            ("tenant",))
        self._c_rung = m.counter(
            "serve_rung_served_total",
            "Requests answered per tenant ladder rung.",
            ("tenant", "rung"))
        self._h_latency = m.histogram(
            "serve_latency_ms",
            "Answered-request latency (ms), by tenant and outcome.",
            ("tenant", "outcome"))
        self._h_queue_wait = m.histogram(
            "serve_queue_wait_ms",
            "Submit-to-dispatch queue wait (ms) of dispatched requests.",
            ("tenant",))
        self._h_occupancy = m.histogram(
            "serve_batch_occupancy", "Filled lanes per dispatch.",
            ("tenant",),
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0))
        self._h_pull_frac = m.histogram(
            "serve_pull_frac",
            "Executed pull fraction per dispatch (pulls / budget).",
            ("tenant",), buckets=PULL_FRAC_BUCKETS)

    # ---- tenant state -----------------------------------------------------

    def _state(self, name: str) -> _TenantState:
        st = self._states.get(name)
        if st is None:
            cfg = self.registry.config(name)
            store = self.registry.store(name)
            if store is None:
                # paged out: dim/version ride in the page image
                entry = self.registry._entry(name)
                dim = int(entry.page["dim"])
                version = int(entry.page["version"])
                refreshes = 0
            else:
                dim = store.N
                version = store.version
                refreshes = getattr(store, "codebook_refreshes", 0)
            st = _TenantState(name, cfg, dim, version, refreshes)
            self._states[name] = st
            self.drr.add_flow(name, cfg.weight)
            for s in st.outcomes:
                self._c_outcomes.seed(tenant=name, outcome=s)
            for i in range(st.ladder.n_rungs):
                self._c_rung.seed(tenant=name, rung=str(i))
        return st

    # ---- compat surface for stream drivers --------------------------------

    @property
    def deadline_s(self) -> float:
        """Batch-assembly wait in seconds (simulate_stream drain step)."""
        return self.batch_wait_s

    @property
    def pending_count(self) -> int:
        """Requests admitted but not yet dispatched, over all tenants."""
        return sum(st.admission.depth for st in self._states.values())

    def result(self, req_id: int) -> Optional[ServeResult]:
        """Pop the typed `ServeResult` for a finished request, or None."""
        return self._results.pop(req_id, None)

    def warmup(self) -> float:
        """Compile every registered tenant's ladder off the serving
        clock; returns wall seconds (same rationale as
        `ServeRuntime.warmup`)."""
        t0 = time.perf_counter()
        for name in self.registry.tenants():
            st = self._state(name)
            execs, _ = self.registry.executors(name)
            store = self.registry.store(name)
            Qbuf = np.zeros((self.lanes, store.N), np.float32)
            for ex in execs:
                ex.dispatch(Qbuf, st.key)
        return time.perf_counter() - t0

    # ---- request path -----------------------------------------------------

    def _finish(self, st: _TenantState, rid: int, res: ServeResult,
                t: Optional[float] = None) -> None:
        res.tenant = st.name
        self._results[rid] = res
        st.outcomes[res.status] += 1
        self._c_outcomes.inc(tenant=st.name, outcome=res.status)
        if res.answered:
            st.lat.append(res.latency_s)
            self._lat.append(res.latency_s)
            self._h_latency.observe(res.latency_s * 1e3, tenant=st.name,
                                    outcome=res.status)
            if len(self._lat) > 100_000:
                self._lat = self._lat[-10_000:]
            if len(st.lat) > 100_000:
                st.lat = st.lat[-10_000:]
        if self.tracer is not None and t is not None:
            self.tracer.request_end(
                rid, t, res.status,
                **({"reason": res.reason} if res.reason else {}))
        if self.flight is not None and res.status == "failed":
            self.flight.record("request_failed", t, rid=rid,
                               tenant=st.name, reason=res.reason)

    def submit(self, q, *, tenant: str, now: Optional[float] = None,
               cls: Optional[str] = None) -> int:
        """Accept one query for a tenant; always returns a request id.

        The tenant must be registered (`TenancyError` otherwise — a
        routing bug, not traffic).  The query itself never raises: it
        runs the tenant's private admission pipeline (poison validation
        -> quarantine -> version-salted cache -> bounded queue) exactly
        like a dedicated `ServeRuntime.submit`.
        """
        now = time.perf_counter() if now is None else now
        st = self._state(tenant)
        rid = self._next_id
        self._next_id += 1
        pcls = st.admission.resolve_class(cls)
        st.requests += 1
        self._c_requests.inc(tenant=tenant, priority_class=pcls.name)
        if self.tracer is not None:
            self.tracer.request_begin(rid, now, tenant=tenant,
                                      priority_class=pcls.name)
        self.apply_updates(tenant, now)
        arr, reason = st.admission.validate(q)
        if arr is None:
            st.admission.count_poison()
            if self.tracer is not None:
                self.tracer.instant(rid, "rejected", now, reason=reason)
            if self.flight is not None:
                self.flight.record("rejected_poison", now, rid=rid,
                                   tenant=tenant, reason=reason)
            self._finish(st, rid, ServeResult(status="rejected",
                                              reason=reason), t=now)
            return rid
        ck = st.cache.key(arr) if st.cache.capacity > 0 else None
        if ck is not None:
            hit = st.cache.get(st.salted(ck))
            if hit is not None:
                ids, scores = hit
                self._c_cache_hits.inc(tenant=tenant)
                if self.tracer is not None:
                    self.tracer.instant(rid, "cache_hit", now)
                self._finish(st, rid, ServeResult(
                    status="ok", ids=ids, scores=scores,
                    eps_served=st.config.eps, delta_served=st.config.delta,
                    cached=True), t=now)
                return rid
        ticket = Ticket(rid, arr, pcls, now, now + pcls.deadline_s, ck,
                        st.admission.fingerprint(arr))
        verdict, displaced = st.admission.admit(ticket)
        for victim, vres in displaced:
            vres.latency_s = now - victim.t_submit
            if self.tracer is not None:
                self.tracer.instant(victim.req_id, "displaced", now, by=rid)
            if self.flight is not None:
                self.flight.record("displacement", now, rid=victim.req_id,
                                   by=rid, tenant=tenant)
            self._finish(st, victim.req_id, vres, t=now)
        if verdict is not None:
            if self.tracer is not None:
                self.tracer.instant(rid, verdict.status, now,
                                    reason=verdict.reason or "")
            if self.flight is not None:
                self.flight.record("refused", now, rid=rid, tenant=tenant,
                                   status=verdict.status,
                                   reason=verdict.reason)
            self._finish(st, rid, verdict, t=now)
        else:
            if self.tracer is not None:
                self.tracer.instant(rid, "admitted", now,
                                    depth=st.admission.depth)
            if self.flight is not None:
                self.flight.record("admitted", now, rid=rid, tenant=tenant,
                                   depth=st.admission.depth)
        return rid

    # ---- updates ----------------------------------------------------------

    def apply_updates(self, tenant: str,
                      now: Optional[float] = None) -> int:
        """Drain one tenant's staged store mutations fault-tolerantly.

        Same contract as `ServeRuntime.apply_updates` (flush failures
        counted + retried, version bump invalidates the tenant's
        cache); executor recalibration is the registry's job (the
        salted cache key + `sync_store` on acquire).  No-op while the
        tenant's table is paged out — staged mutations ride in the page
        image and flush after page-in.
        """
        from repro.store import StoreFlushError
        store = self.registry.store(tenant)
        if store is None:
            return 0
        st = self._state(tenant)
        if self.injector is not None and store.fault_hook is None:
            self.injector.attach(store)
        applied = 0
        if store.pending_updates:
            try:
                info = store.flush_updates()
                applied = info["applied"]
                self._c_update_rows.inc(applied, tenant=tenant)
            except StoreFlushError as e:
                self._c_flush_failures.inc(tenant=tenant)
                if self.flight is not None:
                    self.flight.record("store_flush_error", now,
                                       tenant=tenant, error=str(e),
                                       pending=store.pending_updates)
                    self.flight.dump("store_flush_error", now)
            except Exception as e:
                self._c_update_errors.inc(tenant=tenant)
                if self.flight is not None:
                    self.flight.record("store_update_error", now,
                                       tenant=tenant, error=str(e))
        if store.version != st.version:
            st.version = store.version
            st.cache.invalidate()
        refreshes = getattr(store, "codebook_refreshes", 0)
        if refreshes != st.seen_refreshes:
            st.seen_refreshes = refreshes
            if self.flight is not None:
                self.flight.record("codebook_refresh", now, tenant=tenant,
                                   refreshes=refreshes,
                                   version=store.version)
        return applied

    # ---- scheduler --------------------------------------------------------

    def poll(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Run one deficit-round-robin scheduling pass over all tenants.

        Per tenant, the `ServeRuntime` dispatch triggers apply (full
        batch queued, oldest request aged past ``batch_wait_ms``, or
        the executor already ran this poll); across tenants, DRR meters
        how many requests each backlogged tenant may dispatch per round
        so arrival skew cannot translate into service skew.  Returns
        ``(finished request ids, virtual busy seconds)``.
        """
        now = time.perf_counter() if now is None else now
        for name in self.registry.tenants():
            self.apply_updates(name, now)
        done: List[int] = []
        busy = 0.0
        progress = True
        while progress:
            progress = False
            backlogged = {name: st.admission.depth > 0
                          for name, st in self._states.items()}
            if not any(backlogged.values()):
                break
            self.drr.start_round(backlogged)
            for name in self.drr.flows():
                st = self._states.get(name)
                if st is None:
                    continue
                while st.admission.depth:
                    t = now + busy
                    oldest = st.admission.oldest_submit()
                    full = st.admission.depth >= self.lanes
                    aged = (oldest is not None
                            and t - oldest >= self.batch_wait_s)
                    if not (full or aged or busy > 0.0):
                        break
                    allow = self.drr.allowance(name)
                    if allow < 1:
                        break
                    batch, expired = st.admission.take(
                        t, min(self.lanes, allow))
                    for tk, res in expired:
                        if self.flight is not None:
                            self.flight.record("deadline_expired", t,
                                               rid=tk.req_id, tenant=name)
                        self._finish(st, tk.req_id, res, t=t)
                        done.append(tk.req_id)
                    if not batch:
                        if not expired:
                            break
                        continue
                    self.drr.consume(name, len(batch))
                    served, dt = self._dispatch(st, batch, t)
                    done.extend(served)
                    busy += dt
                    progress = True
                if st.admission.depth == 0:
                    self.drr.reset(name)
            self.drr.rotate()
        return done, busy

    def drain(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Serve everything queued, tenant by tenant, ignoring triggers
        and deadlines (shutdown semantics, like `ServeRuntime.drain`).

        Each tenant drains sequentially in (priority, FIFO) order — the
        batch compositions and per-tenant dispatch sequences are exactly
        a dedicated engine's, which is what the bit-identity suite
        leans on.
        """
        now = time.perf_counter() if now is None else now
        done: List[int] = []
        busy = 0.0
        for name in self.registry.tenants():
            self.apply_updates(name, now)
            st = self._states.get(name)
            if st is None:
                continue
            while st.admission.depth:
                batch, _ = st.admission.take(now + busy, self.lanes,
                                             expire=False)
                if not batch:
                    break
                served, dt = self._dispatch(st, batch, now + busy)
                done.extend(served)
                busy += dt
        return done, busy

    # ---- dispatch ---------------------------------------------------------

    def _fail_batch(self, st: _TenantState, batch: List[Ticket], t: float,
                    exc: Exception, retries: int,
                    backoff: float) -> List[int]:
        self._c_failed_batches.inc(tenant=st.name)
        reason = f"dispatch failed after {retries} retries: {exc}"
        for tk in batch:
            st.admission.add_quarantine(tk.fingerprint, "dispatch failure")
            if self.flight is not None:
                self.flight.record("quarantine_add", t + backoff,
                                   rid=tk.req_id, tenant=st.name,
                                   fingerprint=repr(tk.fingerprint))
            self._finish(st, tk.req_id, ServeResult(
                status="failed", reason=reason,
                latency_s=(t + backoff) - tk.t_submit, retries=retries),
                t=t + backoff)
        if self.flight is not None:
            self.flight.dump("request_failed", t + backoff)
        return [tk.req_id for tk in batch]

    def _dispatch(self, st: _TenantState, batch: List[Ticket],
                  t: float) -> Tuple[List[int], float]:
        name = st.name
        load = ((st.admission.depth + len(batch))
                / st.admission.queue_capacity)
        urgency = 0.0
        for tk in batch:
            budget = tk.t_deadline - tk.t_submit
            if np.isfinite(budget) and budget > 0:
                urgency = max(urgency, (t - tk.t_submit) / budget)
        rung = st.ladder.rung(max(load, urgency))
        with self.registry.serving(name):
            try:
                execs, page_s = self.registry.executors(name)
            except TenancyError as e:
                # residency refusal (e.g. the table grew past what the
                # budget can rebalance): typed failed results, no
                # quarantine — the queries were fine, the table wasn't
                self._c_failed_batches.inc(tenant=name)
                if self.flight is not None:
                    self.flight.record("table_unavailable", t,
                                       tenant=name, error=str(e))
                for tk in batch:
                    self._finish(st, tk.req_id, ServeResult(
                        status="failed",
                        reason=f"table unavailable: {e}",
                        latency_s=t - tk.t_submit), t=t)
                return [tk.req_id for tk in batch], 0.0
            ex = execs[rung]
            Qbuf = np.zeros((self.lanes, ex.N), np.float32)
            for i, tk in enumerate(batch):
                Qbuf[i] = tk.q
            # per-tenant PRNG stream: fold the tenant's key on its own
            # dispatch sequence, exactly like a dedicated runtime would
            key = jax.random.fold_in(st.key, st.dispatch_seq)
            didx = st.dispatch_seq
            st.dispatch_seq += 1
            self._c_dispatches.inc(
                tenant=name,
                filled="full" if len(batch) == self.lanes else "partial")

            def on_error(e, attempt, injected):
                self._c_dispatch_errors.inc(tenant=name)
                if self.flight is not None:
                    self.flight.record(
                        "fault_dispatch_error", t, tenant=name, didx=didx,
                        attempt=attempt, injected=injected, error=str(e))

            def on_retry(attempt, backoff):
                self._c_retries.inc(tenant=name)
                if self.tracer is not None:
                    for tk in batch:
                        self.tracer.instant(tk.req_id, "retry",
                                            t + backoff, attempt=attempt,
                                            didx=didx)

            try:
                ids, scores, rounds, dt, attempt, backoff, spike = \
                    dispatch_with_retries(
                        ex, Qbuf, key, didx=didx, injector=self.injector,
                        max_retries=self.max_retries,
                        retry_backoff_s=self.retry_backoff_s,
                        on_error=on_error, on_retry=on_retry)
            except DispatchFailed as df:
                return (self._fail_batch(st, batch, t, df.cause,
                                         df.retries, df.backoff),
                        page_s + df.backoff)
        if spike > 0.0 and self.flight is not None:
            self.flight.record("fault_latency", t, tenant=name, didx=didx,
                               spike_ms=spike * 1e3)
        # page-in is real serving cost: charge it to this dispatch's
        # virtual busy time so eviction thrash is visible in latency
        dt += page_s
        if (self.dispatch_timeout_s is not None
                and dt > self.dispatch_timeout_s):
            self._c_slow.inc(tenant=name)
        ids = ids[:len(batch)]
        scores = scores[:len(batch)]
        self._occupancy.append(len(batch))
        self._h_occupancy.observe(len(batch), tenant=name)
        from repro.distributed.sharding import dispatch_lane_stats
        lane = dispatch_lane_stats(
            None if rounds is None else rounds[:len(batch)],
            schedule=ex.plan.schedule, lanes=self.lanes,
            filled=len(batch))
        self._pull_fracs.append(lane["executed_pull_frac"])
        self._h_pull_frac.observe(lane["executed_pull_frac"], tenant=name)
        eps_r = st.ladder.eps_values[rung]
        self._c_rung.inc(len(batch), tenant=name, rung=str(rung))
        if self.tracer is not None:
            args = {"tenant": name, "didx": didx, "rung": rung,
                    "eps_served": eps_r, "occupancy": len(batch),
                    "retries": attempt,
                    "pull_frac": lane["executed_pull_frac"]}
            if page_s > 0.0:
                args["page_in_ms"] = page_s * 1e3
            self.tracer.global_span(f"dispatch {name}/{didx}", t, t + dt,
                                    **args)
        done = []
        for i, tk in enumerate(batch):
            out_ids = ex.external_ids(ids[i])
            self._h_queue_wait.observe((t - tk.t_submit) * 1e3,
                                       tenant=name)
            if self.tracer is not None:
                self.tracer.span(tk.req_id, "queued", tk.t_submit, t,
                                 didx=didx)
                self.tracer.span(tk.req_id, "serve", t, t + dt,
                                 rung=rung, eps_served=eps_r,
                                 retries=attempt, didx=didx)
            res = ServeResult(
                status="ok" if rung == 0 else "degraded",
                ids=out_ids, scores=scores[i].copy(),
                eps_served=eps_r, delta_served=st.config.delta,
                latency_s=(t + dt) - tk.t_submit, retries=attempt)
            self._finish(st, tk.req_id, res, t=t + dt)
            if rung == 0 and tk.cache_key is not None:
                st.cache.put(st.salted(tk.cache_key),
                             (out_ids, scores[i].copy()))
            if (self._recall_rate > 0.0
                    and self._recall_rng.random() < self._recall_rate):
                self._recalls.append(ex.recall_of(tk.q, ids[i]))
            done.append(tk.req_id)
        for buf_name in ("_occupancy", "_pull_fracs", "_recalls"):
            buf = getattr(self, buf_name)
            if len(buf) > 100_000:
                setattr(self, buf_name, buf[-10_000:])
        return done, dt

    # ---- observability ----------------------------------------------------

    def stats(self) -> dict:
        """Aggregate + per-tenant telemetry.

        Top level keeps the dedicated-runtime shape (``requests`` /
        ``completed`` / ``pending`` / ``outcomes`` / ``latency_ms`` /
        ``lanes`` / ``faults`` / ``recall``) aggregated over tenants so
        stream drivers and outcome gates work unchanged; ``tenants``
        maps each tenant to its own requests/outcomes/latency/queue/
        store breakdown and ``registry`` carries residency, eviction
        and executor-cache telemetry.
        """
        occ = np.asarray(self._occupancy, np.float64)
        states = self._states
        requests = sum(st.requests for st in states.values())
        outcomes = {s: sum(st.outcomes[s] for st in states.values())
                    for s in ("ok", "degraded", "rejected", "overloaded",
                              "failed")}
        pending = self.pending_count
        answered = outcomes["ok"] + outcomes["degraded"]
        per_tenant = {}
        for name, st in states.items():
            entry = self.registry.stats()["tenants"].get(name, {})
            store = self.registry.store(name)
            per_tenant[name] = {
                "requests": st.requests,
                "outcomes": dict(st.outcomes),
                "latency_ms": summarize_latencies(st.lat),
                "queue": st.admission.stats(),
                "weight": st.config.weight,
                "eps": st.config.eps,
                "precision": st.config.precision,
                "cache": {"hits": st.cache.hits,
                          "misses": st.cache.misses,
                          "entries": len(st.cache)},
                "placement": entry,
            }
            if store is not None:
                per_tenant[name]["store"] = store.stats()
        out = {
            "requests": requests,
            "completed": requests - pending,
            "pending": pending,
            "answered": answered,
            "availability": answered / max(1, requests),
            "dispatches": int(self._c_dispatches.total()),
            "outcomes": outcomes,
            "latency_ms": summarize_latencies(self._lat),
            "lanes": {
                "lanes": self.lanes,
                "mean_occupancy": float(occ.mean()) if occ.size else 0.0,
                "mean_lane_util": (float(occ.mean()) / self.lanes
                                   if occ.size else 0.0),
                "mean_executed_pull_frac": (
                    float(np.mean(self._pull_fracs))
                    if self._pull_fracs else 1.0),
            },
            "faults": {
                "retries": int(self._c_retries.total()),
                "dispatch_errors": int(self._c_dispatch_errors.total()),
                "failed_batches": int(self._c_failed_batches.total()),
                "slow_dispatches": int(self._c_slow.total()),
                "store_flush_failures": int(
                    self._c_flush_failures.total()),
                "update_errors": int(self._c_update_errors.total()),
            },
            "recall": {"samples": len(self._recalls),
                       "mean": (float(np.mean(self._recalls))
                                if self._recalls else float("nan"))},
            "tenants": per_tenant,
            "registry": self.registry.stats(),
        }
        if self.injector is not None:
            out["faults"]["injected"] = self.injector.stats()
        return out
