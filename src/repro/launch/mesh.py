"""Production mesh construction (single pod 16x16 / multi-pod 2x16x16).

A function, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_serving_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (data, model) mesh, or 2x16x16 with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(model: Optional[int] = None):
    """1-D ('model',) mesh for the sharded MIPS serving engine.

    The serving engine shards the item matrix on rows over a single
    'model' axis (DESIGN.md §7); queries arrive replicated from the
    request loop, so no 'data' axis is needed.  ``model`` defaults to
    every visible device.  Returns None on a single device — callers fall
    back to the single-device fused path, keeping the engine code
    mesh-agnostic.
    """
    n = len(jax.devices()) if model is None else min(model, len(jax.devices()))
    if n <= 1:
        return None
    return jax.make_mesh((n,), ("model",))
