"""Training launcher: data-parallel+TP training with checkpoint/restart.

Production use (per-host, multi-pod) would run this under the cluster's
process launcher with jax.distributed.initialize(); on this container it
runs the smoke-scale config on local devices.  Fault tolerance: on start it
restores the latest checkpoint (if any) and resumes at exactly the right
data batch (the stream is step-indexable); checkpoints are atomic.
Straggler mitigation is checkpoint-restart at the step granularity plus a
per-step wall-clock deadline alarm (SIGALRM) that aborts a hung collective
so the job controller can reschedule — see README §Fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointer import (latest_step, restore_checkpoint,
                                           save_checkpoint)
from repro.configs import get_config
from repro.data.synthetic import LMStream
from repro.distributed.sharding import logical_mesh
from repro.distributed.specs import batch_pspecs, param_pspecs
from repro.launch.mesh import make_local_mesh
from repro.models.model import init_params
from repro.models.steps import train_step
from repro.optim.adamw import AdamWConfig, init_opt


class StepDeadline:
    """SIGALRM-based per-step deadline: a hung collective (dead peer,
    straggler) raises instead of blocking forever, so the controller can
    restart from the last checkpoint."""

    def __init__(self, seconds: int):
        self.seconds = seconds

    def __enter__(self):
        if self.seconds > 0:
            signal.signal(signal.SIGALRM,
                          lambda *a: (_ for _ in ()).throw(
                              TimeoutError("step deadline exceeded")))
            signal.alarm(self.seconds)
        return self

    def __exit__(self, *exc):
        if self.seconds > 0:
            signal.alarm(0)
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", action="store_true",
                    help="bf16+error-feedback gradient compression")
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--step-deadline", type=int, default=0,
                    help="seconds; 0 disables the straggler alarm")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_local_mesh(args.data_par, args.model_par)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    stream = LMStream(cfg.vocab, batch=args.batch, seq=args.seq, seed=0)

    with logical_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        pspecs = param_pspecs(cfg, params, mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        params = jax.device_put(params, psh)
        opt = init_opt(params, with_err=args.compress)
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            restored, start = restore_checkpoint(args.ckpt_dir,
                                                 {"params": params,
                                                  "opt": opt})
            params, opt = restored["params"], restored["opt"]
            params = jax.device_put(params, psh)
            print(f"[train] resumed from step {start}")

        fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, opt_cfg,
                                                compress=args.compress))
        t0 = time.time()
        for step in range(start, args.steps):
            b = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
            with StepDeadline(args.step_deadline):
                params, opt, m = fn(params, opt, b)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step={step} loss={float(m['loss']):.4f} "
                      f"acc={float(m['acc']):.3f} "
                      f"gnorm={float(m['grad_norm']):.2f} "
                      f"lr={float(m['lr']):.2e} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt})
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps,
                            {"params": params, "opt": opt})
        print(f"[train] done: {args.steps} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
