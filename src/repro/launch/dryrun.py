import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, record memory/cost/collective analysis.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
is a bug.  Results are cached as JSON under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, cells, get_config, get_shape
from repro.configs.base import ArchConfig, RunShape
from repro.distributed.sharding import logical_mesh, spec_of
from repro.distributed.specs import (batch_axes, batch_pspecs, cache_pspecs,
                                     param_pspecs, tree_pspecs)
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.models.model import forward, init_params
from repro.models.steps import decode_step, prefill_step, train_step
from repro.optim.adamw import AdamWConfig, init_opt

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# models whose f32 optimizer moments would blow past a v5e's HBM
_BF16_MOMENTS_ABOVE = 50e9
_FSDP_ABOVE = 8e9


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_sds(cfg: ArchConfig, shape: RunShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    b = {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        b["enc_frames"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                               jnp.dtype(cfg.dtype))
    return b


def rules_for(cfg: ArchConfig, shape: RunShape, mesh) -> Dict[str, Any]:
    """Per-cell logical-axis overrides (DESIGN.md §6)."""
    rules: Dict[str, Any] = {}
    baxes = batch_axes(mesh, shape.global_batch)
    rules["batch"] = baxes
    if shape.name == "long_500k":
        # batch=1: parallelize over the sequence instead
        rules["kvseq"] = tuple(a for a in ("data", "model")
                               if a in mesh.axis_names)
        rules["seq"] = "data" if "data" in mesh.axis_names else None
    if cfg.ssm_heads and (cfg.ssm_heads % mesh.shape["model"]
                          or cfg.d_inner % mesh.shape["model"]):
        rules["dinner"] = None
    if cfg.n_experts and cfg.n_experts % mesh.shape["model"]:
        rules["experts"] = None  # TP-inside-experts instead (param specs)
    return rules


def lower_cell(cfg: ArchConfig, shape: RunShape, mesh,
               mips_mode: Optional[str] = None, unroll: bool = False):
    """Returns (lowered, meta) for one (arch x shape x mesh) cell."""
    import dataclasses
    if mips_mode is not None:
        cfg = dataclasses.replace(cfg, mips_mode=mips_mode)
    if unroll:
        # full layer unroll: cost_analysis counts scan bodies once, so the
        # roofline runs lower with unrolled stacks for true HLO FLOPs
        cfg = dataclasses.replace(cfg, scan_unroll=0)
    key = jax.random.PRNGKey(0)
    abstract_params = jax.eval_shape(functools.partial(init_params, cfg), key)
    fsdp = cfg.n_params() * 2 > _FSDP_ABOVE and shape.kind == "train"
    pspecs = param_pspecs(cfg, abstract_params, mesh, fsdp=fsdp)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    rules = rules_for(cfg, shape, mesh)
    B, S = shape.global_batch, shape.seq_len

    with logical_mesh(mesh, rules):
        if shape.kind == "train":
            moments = (jnp.bfloat16 if cfg.n_params() > _BF16_MOMENTS_ABOVE
                       else jnp.float32)
            opt_cfg = AdamWConfig()
            abstract_opt = jax.eval_shape(
                functools.partial(init_opt, moments_dtype=moments,
                                  with_err=False), abstract_params)
            # opt moments inherit param sharding; step scalar replicated
            opt_specs = abstract_opt._replace(
                step=P(), mu=pspecs, nu=pspecs, err=None)
            osh = jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs)
            b = batch_sds(cfg, shape)
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               batch_pspecs(mesh, B, b))
            fn = functools.partial(train_step, cfg=cfg, opt_cfg=opt_cfg)
            jfn = jax.jit(fn, in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, None))
            lowered = jfn.lower(abstract_params, abstract_opt, b)
        elif shape.kind == "prefill":
            b = batch_sds(cfg, shape)
            tokens = b["tokens"]
            bsh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               batch_pspecs(mesh, B, b))
            extra_names = sorted(k for k in b if k not in ("labels", "tokens"))
            extra_vals = [b[k] for k in extra_names]
            extra_sh = [bsh[k] for k in extra_names]

            def pf(p, t, *extra):
                kw = dict(zip(extra_names, extra))
                return prefill_step(p, cfg, t, cache_len=S, **kw)
            jfn = jax.jit(pf, in_shardings=(psh, bsh["tokens"], *extra_sh),
                          out_shardings=None)
            lowered = jfn.lower(abstract_params, tokens, *extra_vals)
        else:  # decode
            b = batch_sds(cfg, shape)
            extras = {k: v for k, v in b.items()
                      if k not in ("labels", "tokens")}
            # cache structure from an abstract prefill of the full context
            _, abstract_caches = jax.eval_shape(
                functools.partial(forward, cfg=cfg, cache_len=S),
                abstract_params,
                tokens=_sds((B, S), jnp.int32),
                **{k: v for k, v in extras.items()
                   if k in ("patch_embeds",)},
                **({"enc_frames": extras["enc_frames"]}
                   if "enc_frames" in extras else {}))
            seq_axes = rules.get("kvseq", "model")
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                cache_pspecs(mesh, B, abstract_caches, seq_axes=seq_axes))
            tok = _sds((B, 1), jnp.int32)
            tok_sh = NamedSharding(mesh, P(batch_axes(mesh, B), None))
            pos_sh = NamedSharding(mesh, P())
            def df(p, c, t, pos):
                return decode_step(p, cfg, c, t, pos)
            jfn = jax.jit(df, in_shardings=(psh, csh, tok_sh, pos_sh),
                          out_shardings=(None, csh))
            lowered = jfn.lower(abstract_params, abstract_caches, tok,
                                _sds((), jnp.int32))
    meta = {"fsdp": fsdp, "rules": {k: str(v) for k, v in rules.items()}}
    return lowered, meta


def run_cell(cfg: ArchConfig, shape: RunShape, mesh_name: str,
             mips_mode: Optional[str] = None, unroll: bool = False,
             save: bool = True) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    tag = f"{cfg.name}_{shape.name}_{mesh_name}" + (
        f"_{mips_mode}" if mips_mode else "") + ("_unrolled" if unroll
                                                 else "")
    out_path = os.path.join(RESULTS_DIR, tag + ".json")
    if save and os.path.exists(out_path):
        with open(out_path) as f:
            prev = json.load(f)
        if prev.get("ok"):          # never cache failures
            return prev
    rec: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind, "mips_mode": mips_mode or cfg.mips_mode,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
    }
    rec["unrolled"] = unroll
    t0 = time.time()
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, mips_mode=mips_mode,
                                   unroll=unroll)
        rec.update(meta)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        cost = compiled.cost_analysis() or {}
        rec["flops"] = float(cost.get("flops", -1))
        rec["hlo_bytes_accessed"] = float(cost.get("bytes accessed", -1))
        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                rec[attr] = int(getattr(mem, attr, -1))
        rec["collectives"] = collective_bytes(compiled.as_text())
        rec["ok"] = True
    except Exception as e:  # a failure here is a bug in the system
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mips-mode", default=None,
                    choices=[None, "exact", "boundedme"])
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans for exact HLO FLOPs")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    todo = []
    for cfg, shp, skip in cells():
        if args.arch and cfg.name != args.arch:
            continue
        if args.shape and shp.name != args.shape:
            continue
        if not args.all and not (args.arch or args.shape):
            continue
        todo.append((cfg, shp, skip))
    if not todo:
        ap.error("nothing selected: pass --all or --arch/--shape")

    n_ok = n_fail = n_skip = 0
    for cfg, shp, skip in todo:
        for mesh_name in meshes:
            tag = f"{cfg.name} x {shp.name} x {mesh_name}"
            if skip:
                print(f"[skip] {tag}: {skip}", flush=True)
                n_skip += 1
                continue
            rec = run_cell(cfg, shp, mesh_name, mips_mode=args.mips_mode,
                           unroll=args.unroll)
            if rec["ok"]:
                n_ok += 1
                print(f"[ok]   {tag}: flops={rec['flops']:.3e} "
                      f"coll={rec['collectives']['total_bytes']:.3e}B "
                      f"lower={rec.get('lower_s')}s "
                      f"compile={rec.get('compile_s')}s", flush=True)
            else:
                n_fail += 1
                print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
