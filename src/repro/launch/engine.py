"""Serving engine internals: executor, micro-batch engine, async runtime.

This module is the scheduler/executor half of the serving stack
(DESIGN.md §13; the admission/policy half lives in
`repro.launch.admission`, fault injection in `repro.launch.faults`, the
CLI and stream driver in `repro.launch.serve`):

  * :class:`CascadeExecutor` — the **executor layer**: owns the table or
    `repro.store` handle, the calibrated (eps, delta) plan and the jitted
    fused-cascade flush function for ONE eps point; `dispatch` serves a
    padded lane buffer in a single kernel launch and `sync_store`
    re-derives the plan only when the store's capacity or value range
    outgrows it.
  * :class:`MIPSServeEngine` — the PR-2 micro-batching request loop,
    now a thin scheduler over one `CascadeExecutor` (behaviour and stats
    unchanged: batch/deadline triggers, `QuantizedLRU`, live-store
    draining).
  * :class:`ServeRuntime` — the continuous-batching runtime: a bounded
    priority queue (`AdmissionController`) feeds fixed kernel lanes that
    are *refilled between dispatches* (work-conserving: once the
    executor is busy, freed lanes take whatever is queued instead of
    waiting out the batch deadline), a `DegradationLadder` of
    precompiled executors relaxes eps toward a configured floor under
    queue pressure before anything is refused, dispatch is wrapped in
    retry-with-backoff + poison quarantine so a bad micro-batch can
    never kill the engine, and `stats()` exports p50/p95/p99 latency,
    queue depth, shed/reject/retry/degraded counters and per-dispatch
    lane accounting.

Every request submitted to `ServeRuntime` terminates as a typed
`repro.launch.admission.ServeResult` — ``ok``/``degraded`` with answers
meeting the recorded ``eps_served``, or ``rejected``/``overloaded``/
``failed`` refusals.  The runtime never raises on traffic.
"""

from __future__ import annotations

import collections
import dataclasses
import struct
import time
import warnings
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.admission import (AdmissionController, DegradationLadder,
                                    PriorityClass, ServeResult, Ticket)
from repro.obs.metrics import (PULL_FRAC_BUCKETS, MetricsRegistry,
                               summarize_latencies)

__all__ = ["QuantizedLRU", "CascadeExecutor", "MIPSServeEngine",
           "ServeRuntime", "DispatchFailed", "dispatch_with_retries"]


class DispatchFailed(RuntimeError):
    """A dispatch exhausted its retry budget (`dispatch_with_retries`).

    Carries the last ``cause`` exception, the number of ``retries``
    burned and the accumulated virtual ``backoff`` seconds so the
    caller can fail the batch with honest accounting.
    """

    def __init__(self, cause: Exception, retries: int, backoff: float):
        super().__init__(f"dispatch failed after {retries} retries: {cause}")
        self.cause = cause
        self.retries = retries
        self.backoff = backoff


def dispatch_with_retries(ex, Qbuf, key, *, didx: int, injector=None,
                          max_retries: int = 2,
                          retry_backoff_s: float = 1e-3,
                          on_error=None, on_retry=None):
    """One executor dispatch under the runtime's fault contract.

    Runs ``ex.dispatch(Qbuf, key)`` with exponential-backoff retries,
    consulting the deterministic fault ``injector`` (attempt-level
    injected errors, post-success latency spikes) exactly like
    `ServeRuntime` always has; extracted so the multi-tenant runtime
    (`repro.launch.tenancy`) shares one implementation instead of
    drifting.  ``on_error(exc, attempt, injected)`` fires per failing
    attempt, ``on_retry(attempt, backoff)`` per retry decision — both
    before the backoff grows.  Returns ``(ids, scores, rounds, dt,
    retries, backoff, spike)`` where ``dt`` already includes the
    injected ``spike`` and accumulated ``backoff`` (virtual seconds);
    raises `DispatchFailed` past ``max_retries``.
    """
    attempt = 0
    backoff = 0.0
    while True:
        injected = (injector.dispatch_error(didx, attempt)
                    if injector is not None else None)
        try:
            if injected is not None:
                raise injected
            ids, scores, rounds, dt = ex.dispatch(Qbuf, key)
            break
        except Exception as e:
            if on_error is not None:
                on_error(e, attempt, injected is not None)
            if attempt >= max_retries:
                raise DispatchFailed(e, attempt, backoff) from e
            if on_retry is not None:
                on_retry(attempt, backoff)
            backoff += retry_backoff_s * (2.0 ** attempt)
            attempt += 1
    spike = injector.latency_s(didx) if injector is not None else 0.0
    return ids, scores, rounds, dt + spike + backoff, attempt, backoff, spike


class QuantizedLRU:
    """LRU result cache keyed on quantized queries.

    Keys are the bytes of ``round(q / resolution)`` (int32): any two
    queries within ``resolution`` per coordinate share a cache line, which
    is exactly the granularity at which an (eps, delta)-approximate answer
    is reusable.  ``resolution=0`` disables quantization sharing (exact
    byte equality only).  Capacity 0 disables the cache entirely.
    """

    def __init__(self, capacity: int, resolution: float = 1e-3):
        self.capacity = int(capacity)
        self.resolution = float(resolution)
        self._od: "collections.OrderedDict[bytes, object]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def key(self, q: np.ndarray) -> bytes:
        """Quantize a (N,) query to its cache key."""
        if self.resolution > 0:
            return np.round(np.asarray(q, np.float32)
                            / self.resolution).astype(np.int64).tobytes()
        return np.asarray(q, np.float32).tobytes()   # exact bytes only

    def get(self, key: bytes):
        """Return the cached value or None; counts the hit/miss."""
        hit = self._od.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._od.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: bytes, value) -> None:
        """Insert/update; evicts the least-recently-used past capacity."""
        if self.capacity <= 0:
            return
        self._od[key] = value
        self._od.move_to_end(key)
        while len(self._od) > self.capacity:
            self._od.popitem(last=False)

    def invalidate(self) -> None:
        """Drop every entry (table version bump: cached answers are stale).

        Hit/miss counters survive; ``invalidations`` counts the calls.
        The engine additionally salts its keys with the table version, so
        even an entry that somehow survived an invalidation could never
        answer a post-update query.
        """
        self._od.clear()
        self.invalidations += 1

    def __len__(self) -> int:
        return len(self._od)


@dataclasses.dataclass
class _Pending:
    req_id: int
    q: np.ndarray
    t_submit: float
    cache_key: Optional[bytes]


class CascadeExecutor:
    """The executor layer: one calibrated (eps, delta) dispatch path.

    Owns the item table — a static array, a device-put sharded copy
    under ``mesh``, or a live `repro.store.DynamicTableStore` /
    `ShardedTableStore` — plus the `make_plan` calibration and the
    jitted single-dispatch flush function for exactly one eps point.
    Schedulers (`MIPSServeEngine`, `ServeRuntime`) own queues, caches
    and results; the executor only knows how to serve a full lane
    buffer:

      * `dispatch` runs one fused-cascade launch over a padded
        ``(lanes, N)`` query buffer (donated to jit so steady state
        recycles the device allocation) and returns host arrays plus
        the measured compute seconds;
      * `sync_store` re-derives the plan when the store's capacity or
        monotonic value range outgrows the calibrated bound — the only
        recompile-triggering events on the dynamic path (counted in
        ``n_recalibrations``);
      * `recall_of` rescoring a query exhaustively against the live
        table (the engine's sampled recall estimator).

    A `ServeRuntime` holds one executor per degradation-ladder rung —
    they share the same table/store object, so a rung switch costs
    nothing but the (already compiled) alternative flush function.
    """

    def __init__(self, table, *, K: int = 1, eps: float = 0.1,
                 delta: float = 0.1, value_range: Optional[float] = None,
                 qmax_hint: float = 1.0, tile: int = 8, block: int = 512,
                 lanes: int = 8, mesh=None, model_axis: str = "model",
                 n_valid: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 precision: str = "fp32", range_slack: float = 1.0,
                 adaptive: bool = False, bound: str = "hoeffding",
                 pull_mode: str = "row", coord_block: int = 128,
                 quant_err: Optional[float] = None,
                 pq_subdims: int = 8, pq_codes: int = 16,
                 metrics: Optional[MetricsRegistry] = None,
                 metrics_labels: Optional[Dict[str, str]] = None):
        from repro.core.mips import table_abs_max
        from repro.store import DynamicTableStore, ShardedTableStore

        self.store = table if isinstance(
            table, (DynamicTableStore, ShardedTableStore)) else None
        self._qmax_hint = float(qmax_hint)
        self._range_slack = float(range_slack)
        self._use_pallas = use_pallas
        if self.store is not None:
            store = self.store
            if isinstance(store, ShardedTableStore):
                if mesh is not None and mesh is not store.mesh:
                    raise ValueError("mesh differs from the store's mesh")
                mesh = store.mesh
                model_axis = store.model_axis
            elif mesh is not None:
                raise ValueError(
                    "serving a mesh needs a ShardedTableStore")
            if n_valid is not None:
                raise ValueError("n_valid is store-managed")
            # the store owns the kernel geometry (its quantized shadow and
            # the executor's plan must agree tile-for-tile)
            tile, block = store.tile, store.block
            if store.precision != "fp32":
                precision = store.precision
                if store.precision == "pq":
                    pq_subdims = store.pq_subdims
                    pq_codes = store.pq_codes
            n, N = store.capacity_rows, store.N
            # clamp to the store's observed range exactly as sync_store
            # would on growth: a churned executor and a fresh executor on
            # the store's snapshot then always calibrate identical plans
            # (range_slack=1.0)
            floor = 2.0 * self._qmax_hint * max(store.value_abs_max, 1e-30)
            value_range = (floor if value_range is None
                           else max(float(value_range), floor))
        else:
            self._table = jnp.asarray(table)
            n, N = self._table.shape
            if value_range is None:
                # a-priori product-range bound: callers who know their
                # query norms should pass an explicit value_range instead
                value_range = 2.0 * qmax_hint * table_abs_max(self._table)
        self.n, self.N, self.K = n, N, K
        self.lanes = int(lanes)
        self.mesh = mesh
        self._model_axis = model_axis
        self.eps, self.delta = float(eps), float(delta)
        self._tile, self._block = int(tile), min(int(block), N)
        self.precision = precision
        self.adaptive = bool(adaptive)
        self._bound = bound
        self.pull_mode = pull_mode
        self._coord_block = int(coord_block)
        self._n_valid = n_valid
        self._quant_err = quant_err
        self._pq_subdims = int(pq_subdims)
        self._pq_codes = int(pq_codes)
        self._use_shadow = (self.store is not None and mesh is None
                            and self.store.precision != "fp32")
        if self._use_shadow and pull_mode != "row":
            # the store's incrementally maintained quantized shadow
            # (int8/int4 scales, pq codes) is encoded at the store's own
            # (tile, block) cells; a coord (or coord-resolvable hybrid)
            # plan re-blocks the feature axis at coord_block width, which
            # the shadow cannot serve.  fp32 stores and sharded stores
            # (which quantize in-jit at the plan's geometry) support
            # every pull mode.
            raise ValueError(
                f"pull_mode={pull_mode!r} is incompatible with a "
                f"single-device {self.store.precision} store shadow "
                f"(its quantization cells are fixed at the store's block "
                f"width); use pull_mode='row', an fp32 store, or a "
                f"ShardedTableStore")
        # cascade_* metrics: one labeled row per executor identity so a
        # ladder of rung executors shares metric families without
        # colliding (the runtime adds a "rung" label via metrics_labels)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        lbl = {"precision": str(self.precision),
               "pull_mode": str(self.pull_mode),
               "eps": f"{self.eps:.6g}"}
        for k, v in (metrics_labels or {}).items():
            lbl[str(k)] = str(v)
        self._mlabels = lbl
        keys = tuple(lbl)
        self._c_dispatch = self.metrics.counter(
            "cascade_dispatches_total",
            "Fused-cascade kernel launches (includes warmup).", keys)
        self._c_recal = self.metrics.counter(
            "cascade_recalibrations_total",
            "Plan re-derivations triggered by store growth.", keys)
        self._h_dispatch = self.metrics.histogram(
            "cascade_dispatch_ms",
            "Measured blocking compute time per dispatch (ms).", keys)
        self._c_dispatch.seed(**lbl)
        self._c_recal.seed(**lbl)
        self._seen_version = (0 if self.store is None
                              else self.store.version)
        self._table_np = None   # host copy, materialized only for recall

        self._build(float(value_range))   # sets plan (+ shard geometry)
        if mesh is not None and self.store is None:
            from repro.distributed.specs import serving_table_sharding
            n_valid_eff = n if n_valid is None else n_valid
            self._n_valid = n_valid_eff   # recall must mask pad rows too
            if self._n_pad:  # ragged: pad rows host-side ONCE, pre-placing
                self._table = jnp.pad(self._table,
                                      ((0, self._n_pad), (0, 0)))
            self._table = jax.device_put(
                self._table, serving_table_sharding(mesh, model_axis))
            # static per-shard validity prefixes, passed traced per flush
            self._nv_static = np.clip(
                n_valid_eff
                - np.arange(mesh.shape[model_axis]) * self._n_local,
                0, self._n_local).astype(np.int32)
        elif mesh is None:
            nv = n if n_valid is None else n_valid
            self._nv_static = np.int32(nv)

    @property
    def n_recalibrations(self) -> int:
        """Schedule re-derivations observed (registry-backed)."""
        return int(self._c_recal.get(**self._mlabels))

    def _build(self, value_range: float) -> None:
        """(Re)build the static plan + jitted flush fn for a value range.

        Called once at construction and again only when `sync_store`
        observes the store's capacity or monotonic value range outgrowing
        the calibrated bound — the only events that change the schedule
        (and therefore recompile) on the dynamic path.
        """
        from repro.core.boundedme_jax import bounded_me_decode, make_plan

        self._plan_value_range = float(value_range)
        mesh, model_axis = self.mesh, self._model_axis
        K, eps, delta = self.K, self.eps, self.delta
        tile, block = self._tile, self._block
        precision, use_pallas = self.precision, self._use_pallas
        adaptive, bound = self.adaptive, self._bound
        pull_mode, coord_block = self.pull_mode, self._coord_block
        pq_subdims, pq_codes = self._pq_subdims, self._pq_codes
        quant_err = self._quant_err
        if precision == "pq" and quant_err is None:
            # pq has no a-priori worst-case model: calibrate a measured
            # per-pull bound on the served table (re-measured at every
            # rebuild event, so growth/refresh re-anchor it).  Hybrid
            # plans price two pull widths with different codebooks; take
            # the conservative max across candidate widths.
            from repro.core.boundedme_jax import measured_plan_quant_err
            V_cal = (self.store.host_table() if self.store is not None
                     else self._table)
            widths = {"row": (block,), "coord": (coord_block,),
                      "hybrid": (block, coord_block)}[pull_mode]
            quant_err = max(measured_plan_quant_err(
                V_cal, precision="pq", tile=tile, block=w,
                pq_subdims=pq_subdims, pq_codes=pq_codes)
                for w in widths)
        if mesh is not None:
            from repro.distributed.sharding import (make_shard_plan,
                                                    sharded_bounded_me_decode)
            self.plan, self._n_local, self._n_pad, _ = make_shard_plan(
                self.n, self.N, mesh.shape[model_axis], K=K, eps=eps,
                delta=delta, value_range=value_range, tile=tile, block=block,
                precision=precision, bound=bound, pull_mode=pull_mode,
                coord_block=coord_block, quant_err=quant_err,
                pq_subdims=pq_subdims, pq_codes=pq_codes)

            def _flush_fn(tbl, Qbuf, key, nv):
                out = sharded_bounded_me_decode(
                    tbl, Qbuf, key, mesh=mesh, K=K, model_axis=model_axis,
                    n_valid=nv, eps=eps, delta=delta,
                    value_range=value_range, tile=tile, block=block,
                    final_exact=True, use_pallas=use_pallas,
                    precision=precision, adaptive=adaptive, bound=bound,
                    pull_mode=pull_mode, coord_block=coord_block,
                    quant_err=quant_err, pq_subdims=pq_subdims,
                    pq_codes=pq_codes)
                # rounds_used is (B, shards) when adaptive, else absent
                return out[0], out[1], (out[3] if adaptive else None)

            donate = 1
        else:
            plan = make_plan(self.n, self.N, K=K, eps=eps, delta=delta,
                             value_range=value_range, tile=tile,
                             block=block, precision=precision, bound=bound,
                             pull_mode=pull_mode, coord_block=coord_block,
                             quant_err=quant_err, pq_subdims=pq_subdims,
                             pq_codes=pq_codes)
            self.plan = plan
            if self._use_shadow:
                # the store maintains the quantized shadow incrementally
                # (int8/int4 codes + scales, or pq codes + codebook); the
                # flush consumes it instead of re-encoding the table
                def _flush_fn(tbl, Vq, vaux, Qbuf, key, nv):
                    out = bounded_me_decode(
                        tbl, Qbuf, key, plan=plan, final_exact=True,
                        use_pallas=use_pallas, n_valid=nv,
                        quantized=(Vq, vaux), adaptive=adaptive)
                    return (out if adaptive else (*out, None))

                donate = 3
            else:
                def _flush_fn(tbl, Qbuf, key, nv):
                    # padding/dead rows are masked inside the cascade, so
                    # they can never occupy the returned top-K slots
                    out = bounded_me_decode(
                        tbl, Qbuf, key, plan=plan, final_exact=True,
                        use_pallas=use_pallas, n_valid=nv, adaptive=adaptive)
                    return (out if adaptive else (*out, None))

                donate = 1

        # donate the query buffer: steady-state flushes recycle the same
        # (lanes, N) device allocation (no-op on backends without
        # donation support, e.g. CPU)
        self._fn = jax.jit(_flush_fn, donate_argnums=(donate,))

    def sync_store(self) -> int:
        """Re-derive the plan if the store outgrew it; returns rebuilds.

        Checks, in order: a version change drops the stale recall
        mirror; capacity growth (``grow()``) rebuilds plan + flush fn at
        the new shapes; monotonic value-range growth past the calibrated
        bound re-derives the schedule at ``range * range_slack``.  The
        two rebuild events are the only recompile triggers on the
        dynamic path, counted in ``n_recalibrations``.  No-op without a
        store.
        """
        store = self.store
        if store is None:
            return 0
        rebuilt = 0
        if store.version != self._seen_version:
            self._seen_version = store.version
            self._table_np = None   # never serve stale recall ground truth
        if store.capacity_rows != self.n:
            # the store grew: shapes changed, rebuild plan + flush fn
            self.n = store.capacity_rows
            self._build(self._plan_value_range)
            rebuilt += 1
        needed = 2.0 * self._qmax_hint * store.value_abs_max
        if needed > self._plan_value_range:
            # value-range growth is the only other event that re-derives
            # the schedule; range_slack > 1 buys headroom so a growing
            # corpus recalibrates O(log growth) times, not per update
            self._build(needed * self._range_slack)
            rebuilt += 1
        if rebuilt:
            self._c_recal.inc(rebuilt, **self._mlabels)
        return rebuilt

    def _flush_args(self, Qbuf, key):
        """Assemble per-flush operands (table/shadow/validity) in order."""
        store = self.store
        if store is None:
            return (self._table, Qbuf, key, self._nv_static)
        tbl = store.device_table()
        if self.mesh is not None:
            nv = store.n_valid_vector()
        else:
            nv = np.int32(store.n_live)
        if self._use_shadow:
            Vq, vaux = store.quantized()
            return (tbl, Vq, vaux, Qbuf, key, nv)
        return (tbl, Qbuf, key, nv)

    def dispatch(self, Qbuf: np.ndarray, key) -> Tuple[
            np.ndarray, np.ndarray, Optional[np.ndarray], float]:
        """Serve one padded (lanes, N) buffer in a single kernel launch.

        Returns ``(ids, scores, rounds_used, seconds)`` as host arrays
        (``rounds_used`` is None unless adaptive); ``seconds`` is the
        measured blocking compute time, which virtual-clock drivers add
        to their clock.  Raises whatever the dispatch raises — callers
        (the runtime's retry wrapper) own failure policy.
        """
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # CPU backends warn that donation is unimplemented; harmless
            warnings.filterwarnings("ignore",
                                    message=".*[Dd]onat.*")
            ids, scores, rounds = self._fn(
                *self._flush_args(jnp.asarray(Qbuf), key))
            jax.block_until_ready(scores)
        dt = time.perf_counter() - t0
        self._c_dispatch.inc(**self._mlabels)
        self._h_dispatch.observe(dt * 1e3, **self._mlabels)
        return (np.asarray(ids), np.asarray(scores),
                None if rounds is None else np.asarray(rounds), dt)

    def recall_of(self, q: np.ndarray, got_slots: np.ndarray) -> float:
        """Exact-top-K overlap of a served answer (host rescore)."""
        if self.store is not None:
            # the store's host mirror is updated in O(rows touched) at
            # every apply_updates, so live recall never goes stale
            tbl = self.store.host_table()
            s = tbl @ q
            s[~self.store.live_mask()] = -np.inf
        else:
            if self._table_np is None:
                self._table_np = np.asarray(self._table)
            s = self._table_np @ q
            if self._n_valid is not None:
                s[self._n_valid:] = -np.inf
        exact = np.argpartition(-s, self.K - 1)[:self.K]
        return len(set(exact.tolist()) & set(got_slots.tolist())) / self.K

    def external_ids(self, slots: np.ndarray) -> np.ndarray:
        """Map cascade slots to stable external ids (store) or copy."""
        if self.store is not None:
            return self.store.external_ids(slots)
        return slots.copy()

    @property
    def plan_value_range(self) -> float:
        """The value range the current plan was calibrated at.

        The registry's executor cache (`repro.launch.tenancy`) compares
        ``2 * qmax_hint * store.value_abs_max`` against this to decide
        whether a cached ladder is still a valid bound or must be
        rebuilt (the range-recalibration salt of the cache key).
        """
        return self._plan_value_range

    @property
    def qmax_hint(self) -> float:
        """The |q| bound the value-range calibration assumes."""
        return self._qmax_hint


class MIPSServeEngine:
    """Micro-batching MIPS request loop over a fixed item table.

    Requests (`submit`) are answered from the LRU when a quantized-equal
    query was served recently; otherwise they queue until either
    ``batch_size`` requests are waiting or the oldest has aged past
    ``deadline_ms`` (`poll` applies both triggers), then the whole
    micro-batch is served by ONE fused-cascade dispatch through a
    `CascadeExecutor`.  The padded (batch_size, N) query buffer is
    donated to jit so steady-state serving re-uses its device allocation
    instead of growing one per flush.

    With ``mesh`` the flush runs `sharded_bounded_me_decode` (shard-local
    cascades + exact cross-shard merge, DESIGN.md §7); otherwise the
    single-device `bounded_me_decode`.  Results arrive via `result` as
    ``(ids (K,), scores (K,))`` numpy arrays.

    ``recall_sample_rate`` > 0 additionally rescoring a random fraction of
    requests exhaustively on the host and folds top-K recall into
    `stats` — the live accuracy counter for the (eps, delta) knob.

    ``precision='int8'`` serves every flush on int8-quantized tiles under
    quantization-widened confidence bounds (DESIGN.md §10, docs/TUNING.md):
    roughly half the sampling-phase memory traffic per flush, with returned
    scores still fp32-exact (candidate rescore).  The live ``recall``
    stat is the operator's check that the widened (eps, delta) calibration
    holds on real traffic.

    ``adaptive=True`` (DESIGN.md §12) lets every query in a flush certify
    early exit at round boundaries under the ``bound`` radius family
    ('hoeffding' reuses the schedule's events; 'bernstein' is
    variance-aware): easy queries stop pulling rounds early inside the
    same (eps, delta) contract, and `stats()["adaptive"]` exports the
    per-query ``rounds_used`` histogram plus the mean executed-pull
    fraction.  Works on every path — single-device, sharded
    (shard-local certification), and store-backed including the int8
    shadow (certification radii carry the quantization bias).

    ``pull_mode`` selects the reward stream per flush (DESIGN.md §14):
    'row' (default), 'coord' (the BanditMIPS coordinate estimator —
    narrow ``coord_block``-wide feature tiles, certified pull cost
    sublinear in d; best for high-dimensional embedding tables) or
    'hybrid' (the executor prices both candidate plans and serves the
    cheaper, row-preferred within a 10% multiply margin).  One
    incompatibility, rejected at construction: a single-device quantized
    store shadow (int8/int4/pq) fixes the quantization-block geometry,
    so it serves ``pull_mode='row'`` only.

    **Live corpora** (DESIGN.md §11): ``table`` may be a
    `repro.store.DynamicTableStore` (or `ShardedTableStore` for
    multi-device serving) instead of a static array.  The engine then
    serves the store's preallocated capacity buffer with the live-row
    count riding in as a traced ``n_valid`` every flush, so
    upsert/delete/append streams recompile nothing; staged mutations are
    drained by `apply_updates` — called automatically at every `poll` /
    `drain`, i.e. between micro-batch flushes — which also bumps the
    engine's table version (salting + invalidating the LRU so no stale
    answer survives), keeps the recall estimator on the store's live host
    mirror, and re-derives the (eps, delta) schedule only when the
    store's monotonic value range grows past the calibrated bound.
    Returned ids are the store's stable *external* ids.  The engine
    adopts the store's ``tile``/``block`` geometry and (for a
    `DynamicTableStore` quantized shadow — int8, int4 or pq) its
    ``precision`` and pq codebook geometry; a pq plan's measured
    ``quant_err`` is auto-calibrated on the served table unless passed
    explicitly.

    Failure modes: queries must be (N,) float and finite — NaN/inf
    propagate into scores and poison the LRU line; `submit` raises on a
    shape mismatch.  The engine is not thread-safe; drive it from one
    loop.  (`ServeRuntime` is the hardened front: typed refusals instead
    of exceptions, admission control, overload shedding.)
    """

    def __init__(self, table, *, K: int = 1, eps: float = 0.1,
                 delta: float = 0.1, value_range: Optional[float] = None,
                 qmax_hint: float = 1.0, tile: int = 8, block: int = 512,
                 batch_size: int = 8, deadline_ms: float = 2.0,
                 cache_entries: int = 512, cache_resolution: float = 1e-3,
                 mesh=None, model_axis: str = "model",
                 n_valid: Optional[int] = None,
                 recall_sample_rate: float = 0.0,
                 use_pallas: Optional[bool] = None,
                 precision: str = "fp32", range_slack: float = 1.0,
                 adaptive: bool = False, bound: str = "hoeffding",
                 pull_mode: str = "row", coord_block: int = 128,
                 quant_err: Optional[float] = None,
                 pq_subdims: int = 8, pq_codes: int = 16,
                 seed: int = 0,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._exec = CascadeExecutor(
            table, K=K, eps=eps, delta=delta, value_range=value_range,
            qmax_hint=qmax_hint, tile=tile, block=block, lanes=batch_size,
            mesh=mesh, model_axis=model_axis, n_valid=n_valid,
            use_pallas=use_pallas, precision=precision,
            range_slack=range_slack, adaptive=adaptive, bound=bound,
            pull_mode=pull_mode, coord_block=coord_block,
            quant_err=quant_err, pq_subdims=pq_subdims, pq_codes=pq_codes,
            metrics=self.metrics)
        self.K = K
        self.batch_size = int(batch_size)
        self.deadline_s = float(deadline_ms) * 1e-3
        self._eps, self._delta = float(eps), float(delta)
        self._adaptive = bool(adaptive)
        self._bound = bound
        self._key = jax.random.PRNGKey(seed)
        self.cache = QuantizedLRU(cache_entries, cache_resolution)
        self._store = self._exec.store
        self._version = 0 if self._store is None else self._store.version
        self._pending: List[_Pending] = []
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._next_id = 0
        self._recall_rate = float(recall_sample_rate)
        self._recall_rng = np.random.default_rng(seed)
        self._lat: List[float] = []
        self._recalls: List[float] = []
        self._rounds: List[int] = []   # adaptive: per-query exit rounds
        if self._store is not None:
            self.metrics.adopt(self._store.metrics)
        self._c_requests = self.metrics.counter(
            "serve_requests_total", "Requests submitted.")
        self._c_cache_hits = self.metrics.counter(
            "serve_cache_hits_total", "Requests answered from the LRU.")
        self._c_batches = self.metrics.counter(
            "serve_batches_total", "Micro-batch flushes by trigger.",
            ("trigger",))
        self._c_batches.seed(trigger="full")
        self._c_batches.seed(trigger="deadline")
        self._c_update_rows = self.metrics.counter(
            "serve_update_rows_total", "Store mutations applied.")
        self._c_update_flushes = self.metrics.counter(
            "serve_update_flushes_total", "Store flush_updates calls.")
        self._h_latency = self.metrics.histogram(
            "serve_latency_ms", "Per-request latency (ms), cache hits at 0.")
        self._h_occupancy = self.metrics.histogram(
            "serve_batch_occupancy", "Filled lanes per micro-batch flush.",
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0))
        self.metrics.gauge(
            "serve_pending", "Requests accepted but not yet served.",
        ).set_fn(lambda: len(self._pending))
        self.metrics.gauge(
            "serve_cache_entries", "Live LRU cache entries.",
        ).set_fn(lambda: len(self.cache))
        #: plain dispatch sequence for PRNG fold keys — deliberately NOT
        #: registry-backed so metric wiring (or the NullRegistry hard-off
        #: switch) can never perturb sampling keys
        self._batch_seq = 0
        self._update_time_s = 0.0
        self._occupancy: List[int] = []

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def n_requests(self) -> int:
        """Requests submitted (registry-backed)."""
        return int(self._c_requests.total())

    @property
    def n_cache_hits(self) -> int:
        """Cache-answered requests (registry-backed)."""
        return int(self._c_cache_hits.total())

    @property
    def n_batches(self) -> int:
        """Micro-batch flushes, all triggers (registry-backed)."""
        return int(self._c_batches.total())

    @property
    def n_full_flushes(self) -> int:
        """Flushes triggered by a full batch (registry-backed)."""
        return int(self._c_batches.get(trigger="full"))

    @property
    def n_deadline_flushes(self) -> int:
        """Flushes triggered by the batch deadline (registry-backed)."""
        return int(self._c_batches.get(trigger="deadline"))

    @property
    def n_updates(self) -> int:
        """Store mutations applied (registry-backed)."""
        return int(self._c_update_rows.total())

    @property
    def n_update_flushes(self) -> int:
        """Store flush_updates calls (registry-backed)."""
        return int(self._c_update_flushes.total())

    # ---- executor delegation (back-compat surface) -----------------------

    @property
    def n(self) -> int:
        """Row capacity of the served table (executor-owned)."""
        return self._exec.n

    @property
    def N(self) -> int:
        """Query/item dimensionality."""
        return self._exec.N

    @property
    def plan(self):
        """The executor's calibrated BlockedPlan."""
        return self._exec.plan

    @property
    def n_recalibrations(self) -> int:
        """Schedule re-derivations observed (executor-owned)."""
        return self._exec.n_recalibrations

    @property
    def _fn(self):
        return self._exec._fn

    @property
    def _plan_value_range(self) -> float:
        return self._exec._plan_value_range

    # ---- request path ---------------------------------------------------

    @property
    def pending_count(self) -> int:
        """Requests accepted but not yet served (excludes cache hits)."""
        return len(self._pending)

    def submit(self, q, now: Optional[float] = None) -> int:
        """Accept one (N,) query; returns its request id.

        Cache hits complete immediately (latency ~0); misses queue for the
        next micro-batch.  ``now`` (seconds, any monotonic origin) defaults
        to wall clock — pass a virtual clock for simulation.  Staged store
        mutations are drained first: a query submitted after an upsert
        must never be answered from a pre-upsert cache line or table.
        """
        q = np.asarray(q, np.float32)
        if q.shape != (self.N,):
            raise ValueError(f"query shape {q.shape} != ({self.N},)")
        self.apply_updates()
        now = time.perf_counter() if now is None else now
        rid = self._next_id
        self._next_id += 1
        self._c_requests.inc()
        # lookups are salted with the *current* (table version, K): a
        # result cached before an update can never answer a post-update
        # query, even if an invalidation were missed
        ck = self.cache.key(q) if self.cache.capacity > 0 else None
        if ck is not None:
            hit = self.cache.get(self._salted(ck))
            if hit is not None:
                self._results[rid] = hit
                self._c_cache_hits.inc()
                self._lat.append(0.0)
                self._h_latency.observe(0.0)
                return rid
        self._pending.append(_Pending(rid, q, now, ck))
        return rid

    def _salted(self, base_key: bytes) -> bytes:
        """Prefix an LRU base key with the live (version, K) salt."""
        return struct.pack("<qi", self._version, self.K) + base_key

    def poll(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Flush micro-batches whose trigger fired; returns (ids, busy_s).

        Triggers: ``batch_size`` requests waiting (full flush), or the
        oldest pending request older than the batch deadline (deadline
        flush).  ``busy_s`` is the wall time spent in compute, so virtual-
        clock drivers can advance time by it.  Store-backed engines drain
        staged table mutations first (`apply_updates`), so a flush never
        serves a torn table and an update submitted before a query is
        visible to it.
        """
        now = time.perf_counter() if now is None else now
        self.apply_updates()
        done: List[int] = []
        busy = 0.0
        while self._pending:
            full = len(self._pending) >= self.batch_size
            aged = now - self._pending[0].t_submit >= self.deadline_s
            if not (full or aged):
                break
            self._c_batches.inc(trigger="full" if full else "deadline")
            ids, dt = self._flush(now + busy)
            done.extend(ids)
            busy += dt
        return done, busy

    def drain(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Flush everything pending regardless of triggers (shutdown).

        Also drains staged store mutations first, like `poll`.
        """
        now = time.perf_counter() if now is None else now
        self.apply_updates()
        done: List[int] = []
        busy = 0.0
        while self._pending:
            self._c_batches.inc(trigger="deadline")
            ids, dt = self._flush(now + busy)
            done.extend(ids)
            busy += dt
        return done, busy

    def result(self, req_id: int):
        """Pop the (ids, scores) result for a completed request, or None."""
        return self._results.pop(req_id, None)

    # ---- updates (store-backed engines) ---------------------------------

    def apply_updates(self) -> int:
        """Drain the store's staged mutations; returns rows applied.

        Runs between micro-batch flushes (`poll` / `drain` call it first),
        so in-flight queries never observe a half-applied update burst.
        On any applied mutation: bumps the engine's table version (the
        LRU is invalidated and its keys salted so no pre-update answer
        survives), drops the stale recall mirror (the estimator reads the
        store's always-fresh host mirror anyway), and — only if the
        store's monotonic value range grew past the calibrated bound —
        re-derives the (eps, delta) schedule at ``range * range_slack``
        (the lone recompile-triggering event, counted in
        ``stats()["updates"]["recalibrations"]``).  No-op without a store.
        """
        store = self._store
        if store is None:
            return 0
        applied = 0
        if store.pending_updates:
            t0 = time.perf_counter()
            info = store.flush_updates()
            applied = info["applied"]
            self._c_update_rows.inc(applied)
            self._c_update_flushes.inc()
            self._update_time_s += time.perf_counter() - t0
        if store.version != self._version:
            # covers staged mutations AND out-of-band ones (grow())
            self._version = store.version
            self.cache.invalidate()
        self._exec.sync_store()
        return applied

    # ---- flush ----------------------------------------------------------

    def _flush(self, now: float) -> Tuple[List[int], float]:
        batch = self._pending[:self.batch_size]
        self._pending = self._pending[len(batch):]
        Qbuf = np.zeros((self.batch_size, self.N), np.float32)
        for i, p in enumerate(batch):
            Qbuf[i] = p.q
        # fold on the plain dispatch sequence, NOT a registry counter:
        # sampling keys must be invariant to observability wiring
        key = jax.random.fold_in(self._key, self._batch_seq)
        ids, scores, rounds, dt = self._exec.dispatch(Qbuf, key)
        ids = ids[:len(batch)]
        scores = scores[:len(batch)]
        if rounds is not None:
            # (B,) single-device, (B, shards) sharded: histogram every
            # shard's exit round for the real (non-padding) batch rows
            self._rounds.extend(
                rounds[:len(batch)].reshape(-1).tolist())
        self._batch_seq += 1
        self._occupancy.append(len(batch))
        self._h_occupancy.observe(len(batch))
        done = []
        for i, p in enumerate(batch):
            # store-backed engines answer with stable external ids, never
            # raw slots (a slot's occupant changes across swap-deletes)
            res = (self._exec.external_ids(ids[i]), scores[i].copy())
            self._results[p.req_id] = res
            if p.cache_key is not None:
                # salt at put time: if the version bumped while this
                # request was queued, the result files under the live
                # version (not a dead pre-update key)
                self.cache.put(self._salted(p.cache_key), res)
            self._lat.append((now - p.t_submit) + dt)
            self._h_latency.observe(((now - p.t_submit) + dt) * 1e3)
            if (self._recall_rate > 0.0
                    and self._recall_rng.random() < self._recall_rate):
                self._recalls.append(self._exec.recall_of(p.q, ids[i]))
            done.append(p.req_id)
        if len(self._lat) > 100_000:       # bound the stats memory
            self._lat = self._lat[-10_000:]
        if len(self._occupancy) > 100_000:
            self._occupancy = self._occupancy[-10_000:]
        if len(self._recalls) > 100_000:
            self._recalls = self._recalls[-10_000:]
        if len(self._rounds) > 100_000:
            self._rounds = self._rounds[-10_000:]
        return done, dt

    # ---- observability --------------------------------------------------

    def _adaptive_stats(self) -> dict:
        """Early-exit telemetry: rounds_used histogram + mean pull frac."""
        out = {"enabled": self._adaptive, "bound": self._bound}
        if not self._adaptive:
            return out
        from repro.core.schedule import pulls_through_round
        hist: Dict[int, int] = {}
        for r in self._rounds:
            hist[int(r)] = hist.get(int(r), 0) + 1
        pulls = pulls_through_round(self.plan.schedule)
        total = max(1, int(pulls[-1]))
        samples = max(1, len(self._rounds))
        mean_pulls = sum(int(pulls[min(r, len(pulls) - 1)]) * c
                         for r, c in hist.items()) / samples
        out.update({
            "samples": len(self._rounds),
            "rounds_hist": {str(k): v for k, v in sorted(hist.items())},
            "mean_rounds": (float(np.mean(self._rounds))
                            if self._rounds else 0.0),
            "mean_pull_frac": mean_pulls / total,
        })
        return out

    def stats(self) -> dict:
        """Per-request latency/recall counters as a plain dict.

        latency_ms percentiles include cache hits (latency 0); recall is
        over the sampled fraction only (``nan`` when nothing was sampled).
        """
        occ = np.asarray(self._occupancy, np.float64)
        return {
            "requests": self.n_requests,
            "completed": self.n_requests - len(self._pending),
            "pending": len(self._pending),
            "batches": self.n_batches,
            "full_flushes": self.n_full_flushes,
            "deadline_flushes": self.n_deadline_flushes,
            "mean_batch_occupancy": float(occ.mean()) if occ.size else 0.0,
            "cache": {"hits": self.cache.hits, "misses": self.cache.misses,
                      "entries": len(self.cache),
                      "hit_rate": (self.cache.hits
                                   / max(1, self.cache.hits
                                         + self.cache.misses))},
            "latency_ms": summarize_latencies(
                self._lat, keys=("mean", "p50", "p95", "max")),
            "recall": {"samples": len(self._recalls),
                       "mean": (float(np.mean(self._recalls))
                                if self._recalls else float("nan"))},
            "plan": {"rounds": len(self.plan.schedule.rounds),
                     "pull_speedup": self.plan.schedule.speedup},
            "adaptive": self._adaptive_stats(),
            "updates": {
                "applied": self.n_updates,
                "update_flushes": self.n_update_flushes,
                "recalibrations": self.n_recalibrations,
                "version": self._version,
                "cache_invalidations": self.cache.invalidations,
                "rows_per_s": (self.n_updates / self._update_time_s
                               if self._update_time_s > 0 else 0.0)},
            **({"store": self._store.stats()}
               if self._store is not None else {}),
        }


class ServeRuntime:
    """Continuous-batching serving runtime with admission + degradation.

    The hardened request front (DESIGN.md §13).  Three layers:

      * **admission** (`repro.launch.admission.AdmissionController`):
        every `submit` is validated (poison NaN/Inf/wrong-dim queries are
        rejected at the door), checked against the quarantine, and
        enqueued into a bounded priority queue — a full queue refuses
        with a typed ``overloaded`` result or displaces lower-priority
        sheddable work;
      * **scheduler** (this class): `poll` assembles dispatch batches in
        (priority, FIFO) order onto ``lanes`` fixed kernel lanes and is
        *work-conserving* — while the executor is busy, freed lanes are
        refilled from the queue between dispatches instead of waiting
        out the batch deadline, so a burst drains at full lane
        occupancy.  Requests queued past their class deadline are shed
        (typed ``overloaded``/``deadline``) rather than served late;
      * **executor** (`CascadeExecutor`, one per degradation rung):
        under queue pressure the `DegradationLadder` relaxes eps toward
        ``eps_floor`` — each response records the ``eps_served`` it
        actually met, degraded responses are *never* written to the
        full-quality cache, and only when the ladder is exhausted does
        admission refuse outright.  Dispatch is wrapped in
        retry-with-backoff; a micro-batch that keeps failing is failed
        *alone* (typed ``failed`` results + fingerprint quarantine) and
        the engine keeps serving.

    A store-backed runtime drains staged mutations between dispatches
    like `MIPSServeEngine`; a failing store flush (`StoreFlushError`)
    leaves the staged ops intact, is counted, and is retried at the next
    poll while serving continues on the current table.

    `stats()` exports p50/p95/p99 latency, queue depth/peak, outcome and
    shed/reject/retry/degraded counters, per-rung eps_served counts and
    per-dispatch lane accounting.  Drive it exactly like the engine:
    ``submit(q, now=...)`` / ``poll(now=...)`` / ``result(rid)`` — every
    request terminates as a typed `ServeResult`; traffic never raises.
    """

    def __init__(self, table, *, K: int = 1, eps: float = 0.1,
                 delta: float = 0.1, eps_floor: Optional[float] = None,
                 degrade_rungs: int = 3, degrade_start: float = 0.5,
                 lanes: int = 8, batch_wait_ms: float = 2.0,
                 queue_capacity: int = 64,
                 classes: Optional[Dict[str, PriorityClass]] = None,
                 default_class: str = "default",
                 max_retries: int = 2, retry_backoff_ms: float = 1.0,
                 dispatch_timeout_ms: Optional[float] = None,
                 fault_injector=None,
                 cache_entries: int = 512, cache_resolution: float = 1e-3,
                 recall_sample_rate: float = 0.0,
                 value_range: Optional[float] = None,
                 qmax_hint: float = 1.0, tile: int = 8, block: int = 512,
                 mesh=None, model_axis: str = "model",
                 n_valid: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 precision: str = "fp32", range_slack: float = 1.0,
                 adaptive: bool = False, bound: str = "hoeffding",
                 pull_mode: str = "row", coord_block: int = 128,
                 quant_err: Optional[float] = None,
                 pq_subdims: int = 8, pq_codes: int = 16,
                 seed: int = 0,
                 metrics: Optional[MetricsRegistry] = None,
                 tracer=None, flight=None):
        if batch_wait_ms <= 0:
            raise ValueError(f"batch_wait_ms must be > 0, "
                             f"got {batch_wait_ms}")
        if lanes < 1:
            raise ValueError(f"lanes must be >= 1, got {lanes}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        #: optional `repro.obs.trace.SpanTracer` / `repro.obs.flight.
        #: FlightRecorder`; None disables that pillar entirely
        self.tracer = tracer
        self.flight = flight
        self.ladder = DegradationLadder(eps, eps_floor, rungs=degrade_rungs,
                                        start=degrade_start)
        # pull_mode='hybrid' resolves per rung: relaxed-eps rungs have
        # smaller schedules, so the row/coord winner may legitimately
        # differ across the ladder (each rung's plan records its own
        # resolved mode)
        self._rung_execs = [CascadeExecutor(
            table, K=K, eps=e, delta=delta, value_range=value_range,
            qmax_hint=qmax_hint, tile=tile, block=block, lanes=lanes,
            mesh=mesh, model_axis=model_axis, n_valid=n_valid,
            use_pallas=use_pallas, precision=precision,
            range_slack=range_slack, adaptive=adaptive, bound=bound,
            pull_mode=pull_mode, coord_block=coord_block,
            quant_err=quant_err, pq_subdims=pq_subdims, pq_codes=pq_codes,
            metrics=self.metrics, metrics_labels={"rung": str(i)})
            for i, e in enumerate(self.ladder.eps_values)]
        ex0 = self._rung_execs[0]
        self.K = K
        self.lanes = int(lanes)
        self.batch_wait_s = float(batch_wait_ms) * 1e-3
        self._eps, self._delta = float(eps), float(delta)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_ms) * 1e-3
        self.dispatch_timeout_s = (None if dispatch_timeout_ms is None
                                   else float(dispatch_timeout_ms) * 1e-3)
        self.admission = AdmissionController(
            ex0.N, queue_capacity=queue_capacity, classes=classes,
            default_class=default_class, metrics=self.metrics)
        self.injector = fault_injector
        self._store = ex0.store
        if fault_injector is not None:
            self.metrics.adopt(fault_injector.metrics)
        if fault_injector is not None and self._store is not None:
            fault_injector.attach(self._store)
        if self._store is not None:
            self.metrics.adopt(self._store.metrics)
        self._version = 0 if self._store is None else self._store.version
        self._key = jax.random.PRNGKey(seed)
        self.cache = QuantizedLRU(cache_entries, cache_resolution)
        self._results: Dict[int, ServeResult] = {}
        self._next_id = 0
        self._recall_rate = float(recall_sample_rate)
        self._recall_rng = np.random.default_rng(seed)
        self._lat: List[float] = []
        self._occupancy: List[int] = []
        self._pull_fracs: List[float] = []
        self._recalls: List[float] = []
        self._c_requests = self.metrics.counter(
            "serve_requests_total", "Requests submitted, by class.",
            ("priority_class",))
        self._c_outcomes = self.metrics.counter(
            "serve_outcomes_total",
            "Terminal request outcomes (the typed ServeResult statuses).",
            ("outcome",))
        for s in ("ok", "degraded", "rejected", "overloaded", "failed"):
            self._c_outcomes.seed(outcome=s)
        self._c_class = self.metrics.counter(
            "serve_class_events_total",
            "Per-priority-class accounting events.",
            ("priority_class", "event"))
        self._c_rung = self.metrics.counter(
            "serve_rung_served_total",
            "Requests answered per degradation-ladder rung.", ("rung",))
        for i in range(self.ladder.n_rungs):
            self._c_rung.seed(rung=str(i))
        self._c_cache_hits = self.metrics.counter(
            "serve_cache_hits_total", "Requests answered from the LRU.")
        self._c_dispatches = self.metrics.counter(
            "serve_dispatches_total",
            "Batch dispatches, by lane occupancy.", ("filled",))
        self._c_dispatches.seed(filled="full")
        self._c_dispatches.seed(filled="partial")
        self._c_retries = self.metrics.counter(
            "serve_retries_total", "Dispatch retry attempts.")
        self._c_dispatch_errors = self.metrics.counter(
            "serve_dispatch_errors_total",
            "Dispatch attempts that raised (injected or real).")
        self._c_failed_batches = self.metrics.counter(
            "serve_failed_batches_total",
            "Micro-batches failed past the retry budget.")
        self._c_slow = self.metrics.counter(
            "serve_slow_dispatches_total",
            "Dispatches exceeding dispatch_timeout_ms.")
        self._c_flush_failures = self.metrics.counter(
            "serve_store_flush_failures_total",
            "Store flushes failed by StoreFlushError (retried later).")
        self._c_update_errors = self.metrics.counter(
            "serve_update_errors_total",
            "Store flushes that raised a non-flush error.")
        self._c_update_rows = self.metrics.counter(
            "serve_update_rows_total", "Store mutations applied.")
        self._h_latency = self.metrics.histogram(
            "serve_latency_ms",
            "Answered-request latency (ms), by outcome.", ("outcome",))
        self._h_queue_wait = self.metrics.histogram(
            "serve_queue_wait_ms",
            "Submit-to-dispatch queue wait (ms) of dispatched requests.")
        self._h_occupancy = self.metrics.histogram(
            "serve_batch_occupancy", "Filled lanes per dispatch.",
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0))
        self._h_pull_frac = self.metrics.histogram(
            "serve_pull_frac",
            "Executed pull fraction per dispatch (pulls / budget).",
            buckets=PULL_FRAC_BUCKETS)
        self.metrics.gauge(
            "serve_cache_entries", "Live LRU cache entries.",
        ).set_fn(lambda: len(self.cache))
        #: plain dispatch sequence for PRNG fold keys — deliberately NOT
        #: registry-backed so metric wiring (or the NullRegistry hard-off
        #: switch) can never perturb sampling keys
        self._dispatch_seq = 0
        self._seen_refreshes = (getattr(self._store, "codebook_refreshes", 0)
                                if self._store is not None else 0)

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def outcomes(self) -> Dict[str, int]:
        """Terminal outcome counts keyed by status (registry-backed)."""
        return {s: int(self._c_outcomes.get(outcome=s))
                for s in ("ok", "degraded", "rejected", "overloaded",
                          "failed")}

    @property
    def rung_served(self) -> List[int]:
        """Requests answered per ladder rung (registry-backed)."""
        return [int(self._c_rung.get(rung=str(i)))
                for i in range(self.ladder.n_rungs)]

    @property
    def per_class(self) -> Dict[str, Dict[str, int]]:
        """Per-class event counts, classes in first-seen order
        (registry-backed)."""
        out: Dict[str, Dict[str, int]] = {}
        for labels, value in self._c_class.rows():
            cls = labels["priority_class"]
            out.setdefault(cls, {})[labels["event"]] = int(value)
        return out

    @property
    def n_requests(self) -> int:
        """Requests submitted (registry-backed)."""
        return int(self._c_requests.total())

    @property
    def n_cache_hits(self) -> int:
        """Cache-answered requests (registry-backed)."""
        return int(self._c_cache_hits.total())

    @property
    def n_dispatches(self) -> int:
        """Batch dispatches issued (registry-backed)."""
        return int(self._c_dispatches.total())

    @property
    def n_full_dispatches(self) -> int:
        """Dispatches with every lane filled (registry-backed)."""
        return int(self._c_dispatches.get(filled="full"))

    @property
    def n_retries(self) -> int:
        """Dispatch retry attempts (registry-backed)."""
        return int(self._c_retries.total())

    @property
    def n_dispatch_errors(self) -> int:
        """Dispatch attempts that raised (registry-backed)."""
        return int(self._c_dispatch_errors.total())

    @property
    def n_failed_batches(self) -> int:
        """Micro-batches failed past retries (registry-backed)."""
        return int(self._c_failed_batches.total())

    @property
    def n_slow_dispatches(self) -> int:
        """Dispatches past the timeout (registry-backed)."""
        return int(self._c_slow.total())

    @property
    def n_flush_failures(self) -> int:
        """StoreFlushError flush failures (registry-backed)."""
        return int(self._c_flush_failures.total())

    @property
    def n_update_errors(self) -> int:
        """Non-flush store update errors (registry-backed)."""
        return int(self._c_update_errors.total())

    @property
    def n_updates(self) -> int:
        """Store mutations applied (registry-backed)."""
        return int(self._c_update_rows.total())

    # ---- compat surface for simulate_stream ------------------------------

    @property
    def N(self) -> int:
        """Query dimensionality (executor-owned)."""
        return self._rung_execs[0].N

    @property
    def n(self) -> int:
        """Row capacity of the served table (executor-owned)."""
        return self._rung_execs[0].n

    @property
    def plan(self):
        """The full-quality (rung 0) executor's calibrated plan."""
        return self._rung_execs[0].plan

    @property
    def deadline_s(self) -> float:
        """Batch-assembly wait in seconds (simulate_stream drain step)."""
        return self.batch_wait_s

    @property
    def pending_count(self) -> int:
        """Requests admitted but not yet dispatched (the queue depth)."""
        return self.admission.depth

    # ---- request path -----------------------------------------------------

    def _class_counter(self, cls: str, key: str) -> None:
        # seed the full event set on a class's first touch so the
        # legacy per-class dict keeps its fixed key order
        for ev in ("requests", "answered", "degraded", "shed"):
            self._c_class.seed(priority_class=cls, event=ev)
        self._c_class.inc(priority_class=cls, event=key)

    def _finish(self, rid: int, res: ServeResult,
                t: Optional[float] = None) -> None:
        self._results[rid] = res
        self._c_outcomes.inc(outcome=res.status)
        if res.answered:
            self._class_counter(res.cls, "answered")
            if res.status == "degraded":
                self._class_counter(res.cls, "degraded")
            self._lat.append(res.latency_s)
            self._h_latency.observe(res.latency_s * 1e3,
                                    outcome=res.status)
            if len(self._lat) > 100_000:
                self._lat = self._lat[-10_000:]
        elif res.status in ("overloaded", "failed"):
            self._class_counter(res.cls, "shed")
        if self.tracer is not None and t is not None:
            self.tracer.request_end(
                rid, t, res.status,
                **({"reason": res.reason} if res.reason else {}))
        if self.flight is not None and res.status == "failed":
            self.flight.record("request_failed", t, rid=rid,
                               cls=res.cls, reason=res.reason)

    def _salted(self, base_key: bytes) -> bytes:
        """Prefix an LRU base key with the live (version, K) salt."""
        return struct.pack("<qi", self._version, self.K) + base_key

    def submit(self, q, now: Optional[float] = None,
               cls: Optional[str] = None) -> int:
        """Accept one query; always returns a request id, never raises.

        The query runs the admission pipeline (DESIGN.md §13): poison
        validation -> quarantine -> cache (full-quality hits answer
        immediately at eps_served = eps) -> bounded priority queue.
        Refused requests get their typed `ServeResult` immediately;
        admitted ones resolve at a later `poll`/`drain`.  ``cls`` names a
        configured `PriorityClass` (None = default).
        """
        now = time.perf_counter() if now is None else now
        rid = self._next_id
        self._next_id += 1
        pcls = self.admission.resolve_class(cls)
        self._c_requests.inc(priority_class=pcls.name)
        self._class_counter(pcls.name, "requests")
        if self.tracer is not None:
            self.tracer.request_begin(rid, now, priority_class=pcls.name)
        self.apply_updates(now)
        arr, reason = self.admission.validate(q)
        if arr is None:
            self.admission.count_poison()
            if self.tracer is not None:
                self.tracer.instant(rid, "rejected", now, reason=reason)
            if self.flight is not None:
                self.flight.record("rejected_poison", now, rid=rid,
                                   reason=reason)
            self._finish(rid, ServeResult(status="rejected", cls=pcls.name,
                                          reason=reason), t=now)
            return rid
        ck = self.cache.key(arr) if self.cache.capacity > 0 else None
        if ck is not None:
            hit = self.cache.get(self._salted(ck))
            if hit is not None:
                ids, scores = hit
                self._c_cache_hits.inc()
                if self.tracer is not None:
                    self.tracer.instant(rid, "cache_hit", now)
                self._finish(rid, ServeResult(
                    status="ok", ids=ids, scores=scores,
                    eps_served=self._eps, delta_served=self._delta,
                    cls=pcls.name, cached=True), t=now)
                return rid
        ticket = Ticket(rid, arr, pcls, now, now + pcls.deadline_s, ck,
                        self.admission.fingerprint(arr))
        verdict, displaced = self.admission.admit(ticket)
        for victim, vres in displaced:
            vres.latency_s = now - victim.t_submit
            if self.tracer is not None:
                self.tracer.instant(victim.req_id, "displaced", now,
                                    by=rid)
            if self.flight is not None:
                self.flight.record("displacement", now,
                                   rid=victim.req_id, by=rid,
                                   cls=victim.cls.name)
            self._finish(victim.req_id, vres, t=now)
        if verdict is not None:
            if self.tracer is not None:
                self.tracer.instant(rid, verdict.status, now,
                                    reason=verdict.reason or "")
            if self.flight is not None:
                self.flight.record("refused", now, rid=rid,
                                   status=verdict.status,
                                   reason=verdict.reason)
            self._finish(rid, verdict, t=now)
        else:
            if self.tracer is not None:
                self.tracer.instant(rid, "admitted", now,
                                    depth=self.admission.depth)
            if self.flight is not None:
                self.flight.record("admitted", now, rid=rid,
                                   cls=pcls.name,
                                   depth=self.admission.depth)
        return rid

    def result(self, req_id: int) -> Optional[ServeResult]:
        """Pop the typed `ServeResult` for a finished request, or None."""
        return self._results.pop(req_id, None)

    def warmup(self) -> float:
        """Compile every rung executor off the serving clock; returns s.

        Dispatches one all-zeros lane buffer through each ladder rung so
        jit compilation happens *before* traffic: on a virtual-clock
        driver an un-warmed runtime charges its first dispatch the whole
        compile time, which expires every queued deadline and reads as a
        (spurious) overload.  Legacy counters and stats are untouched
        (the executor-level ``cascade_*`` metrics do count warmup
        dispatches — by design, so compile cost is visible).
        """
        t0 = time.perf_counter()
        Qbuf = np.zeros((self.lanes, self.N), np.float32)
        for ex in self._rung_execs:
            ex.dispatch(Qbuf, self._key)
        return time.perf_counter() - t0

    # ---- updates ----------------------------------------------------------

    def apply_updates(self, now: Optional[float] = None) -> int:
        """Drain staged store mutations fault-tolerantly; returns applied.

        Like `MIPSServeEngine.apply_updates` (version bump invalidates +
        re-salts the LRU, the recall mirror stays live, capacity/value
        range growth recalibrates and recompiles every rung executor),
        with one robustness addition: a `StoreFlushError` from the
        store's fault hook — or any other flush exception — is *counted*
        (``stats()["faults"]["store_flush_failures"]`` /
        ``update_errors``), the staged mutations stay staged, and serving
        continues on the current table; the flush retries at the next
        poll.  ``now`` (optional virtual-clock time) only timestamps the
        flight-recorder events.  No-op without a store.
        """
        from repro.store import StoreFlushError
        store = self._store
        if store is None:
            return 0
        applied = 0
        if store.pending_updates:
            try:
                info = store.flush_updates()
                applied = info["applied"]
                self._c_update_rows.inc(applied)
            except StoreFlushError as e:
                # staged ops intact: keep serving the current table and
                # retry the flush at the next poll
                self._c_flush_failures.inc()
                if self.flight is not None:
                    self.flight.record("store_flush_error", now,
                                       error=str(e),
                                       pending=store.pending_updates)
                    self.flight.dump("store_flush_error", now)
            except Exception as e:
                # a genuinely bad mutation (unknown delete, capacity
                # exhausted): the store dropped the bad op and kept its
                # successors — count it and keep the engine alive
                self._c_update_errors.inc()
                if self.flight is not None:
                    self.flight.record("store_update_error", now,
                                       error=str(e))
        if store.version != self._version:
            self._version = store.version
            self.cache.invalidate()
        rebuilt = 0
        for ex in self._rung_execs:
            rebuilt += ex.sync_store()
        if rebuilt and self.flight is not None:
            self.flight.record("recalibration", now, rebuilds=rebuilt,
                               version=store.version)
        refreshes = getattr(store, "codebook_refreshes", 0)
        if refreshes != self._seen_refreshes:
            self._seen_refreshes = refreshes
            if self.flight is not None:
                self.flight.record("codebook_refresh", now,
                                   refreshes=refreshes,
                                   version=store.version)
        return applied

    # ---- scheduler ---------------------------------------------------------

    def poll(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Run the continuous-batching scheduler; returns (ids, busy_s).

        Dispatch triggers: ``lanes`` requests queued (full dispatch), the
        oldest queued request aged past ``batch_wait_ms``, or — the
        continuous-batching rule — the executor already ran this poll
        (work conservation: anything still queued waited through that
        dispatch, so freed lanes are refilled immediately instead of
        re-waiting the batch deadline).  Expired-deadline tickets are
        shed during batch assembly.  ``busy_s`` is virtual compute time
        (measured + injected + retry backoff) for virtual-clock drivers.
        """
        now = time.perf_counter() if now is None else now
        self.apply_updates(now)
        done: List[int] = []
        busy = 0.0
        while self.admission.depth:
            t = now + busy
            oldest = self.admission.oldest_submit()
            full = self.admission.depth >= self.lanes
            aged = (oldest is not None
                    and t - oldest >= self.batch_wait_s)
            if not (full or aged or busy > 0.0):
                break
            batch, expired = self.admission.take(t, self.lanes)
            for tk, res in expired:
                if self.flight is not None:
                    self.flight.record("deadline_expired", t,
                                       rid=tk.req_id, cls=tk.cls.name)
                self._finish(tk.req_id, res, t=t)
                done.append(tk.req_id)
            if not batch:
                continue
            served, dt = self._dispatch(batch, t)
            done.extend(served)
            busy += dt
        return done, busy

    def drain(self, now: Optional[float] = None) -> Tuple[List[int], float]:
        """Serve everything queued regardless of triggers or deadlines."""
        now = time.perf_counter() if now is None else now
        self.apply_updates(now)
        done: List[int] = []
        busy = 0.0
        while self.admission.depth:
            batch, _ = self.admission.take(now + busy, self.lanes,
                                           expire=False)
            if not batch:
                break
            served, dt = self._dispatch(batch, now + busy)
            done.extend(served)
            busy += dt
        return done, busy

    # ---- dispatch ----------------------------------------------------------

    def _fail_batch(self, batch: List[Ticket], t: float, exc: Exception,
                    retries: int, backoff: float) -> List[int]:
        """Fail ONE micro-batch (typed results + quarantine), engine lives.

        Every ticket gets a ``failed`` `ServeResult` carrying the
        exception text, and its fingerprint is quarantined so identical
        resubmissions are refused at admission instead of re-breaking
        dispatches.  The engine itself is untouched — the next poll
        dispatches the next batch normally.
        """
        self._c_failed_batches.inc()
        reason = f"dispatch failed after {retries} retries: {exc}"
        for tk in batch:
            self.admission.add_quarantine(tk.fingerprint,
                                          "dispatch failure")
            if self.flight is not None:
                self.flight.record("quarantine_add", t + backoff,
                                   rid=tk.req_id,
                                   fingerprint=repr(tk.fingerprint))
            self._finish(tk.req_id, ServeResult(
                status="failed", cls=tk.cls.name, reason=reason,
                latency_s=(t + backoff) - tk.t_submit, retries=retries),
                t=t + backoff)
        if self.flight is not None:
            # one dump per failed batch: the ring now holds the whole
            # failure context (injections, retries, quarantines)
            self.flight.dump("request_failed", t + backoff)
        return [tk.req_id for tk in batch]

    def _dispatch(self, batch: List[Ticket],
                  t: float) -> Tuple[List[int], float]:
        # rung from overload pressure at assembly, the max of two signals:
        # queue depth (the taken batch counts: it was queue content a
        # moment ago) and *urgency* — the fraction of its deadline budget
        # the most-delayed batch member has already burned.  Depth alone
        # misses overload under tight deadlines (requests expire before
        # the queue builds); urgency alone misses it when deadlines are
        # infinite.  Either saturating climbs the ladder.
        load = (self.admission.depth + len(batch)) \
            / self.admission.queue_capacity
        urgency = 0.0
        for tk in batch:
            budget = tk.t_deadline - tk.t_submit
            if np.isfinite(budget) and budget > 0:
                urgency = max(urgency, (t - tk.t_submit) / budget)
        rung = self.ladder.rung(max(load, urgency))
        ex = self._rung_execs[rung]
        Qbuf = np.zeros((self.lanes, self.N), np.float32)
        for i, tk in enumerate(batch):
            Qbuf[i] = tk.q
        # fold on the plain dispatch sequence, NOT a registry counter:
        # sampling keys must be invariant to observability wiring
        key = jax.random.fold_in(self._key, self._dispatch_seq)
        didx = self._dispatch_seq
        self._dispatch_seq += 1
        self._c_dispatches.inc(
            filled="full" if len(batch) == self.lanes else "partial")
        def on_error(e, attempt, injected):
            self._c_dispatch_errors.inc()
            if self.flight is not None:
                self.flight.record(
                    "fault_dispatch_error", t, didx=didx,
                    attempt=attempt, injected=injected, error=str(e))

        def on_retry(attempt, backoff):
            self._c_retries.inc()
            if self.tracer is not None:
                for tk in batch:
                    self.tracer.instant(tk.req_id, "retry",
                                        t + backoff, attempt=attempt,
                                        didx=didx)

        try:
            ids, scores, rounds, dt, attempt, backoff, spike = \
                dispatch_with_retries(
                    ex, Qbuf, key, didx=didx, injector=self.injector,
                    max_retries=self.max_retries,
                    retry_backoff_s=self.retry_backoff_s,
                    on_error=on_error, on_retry=on_retry)
        except DispatchFailed as df:
            return self._fail_batch(batch, t, df.cause, df.retries,
                                    df.backoff), df.backoff
        if spike > 0.0 and self.flight is not None:
            self.flight.record("fault_latency", t, didx=didx,
                               spike_ms=spike * 1e3)
        if (self.dispatch_timeout_s is not None
                and dt > self.dispatch_timeout_s):
            self._c_slow.inc()
        ids = ids[:len(batch)]
        scores = scores[:len(batch)]
        self._occupancy.append(len(batch))
        self._h_occupancy.observe(len(batch))
        from repro.distributed.sharding import dispatch_lane_stats
        lane = dispatch_lane_stats(
            None if rounds is None else rounds[:len(batch)],
            schedule=ex.plan.schedule, lanes=self.lanes,
            filled=len(batch))
        self._pull_fracs.append(lane["executed_pull_frac"])
        self._h_pull_frac.observe(lane["executed_pull_frac"])
        eps_r = self.ladder.eps_values[rung]
        self._c_rung.inc(len(batch), rung=str(rung))
        if self.tracer is not None:
            args = {"didx": didx, "rung": rung, "eps_served": eps_r,
                    "occupancy": len(batch), "retries": attempt,
                    "pull_frac": lane["executed_pull_frac"]}
            if spike > 0.0:
                args["injected_ms"] = spike * 1e3
            if rounds is not None:
                args["rounds_used"] = float(
                    np.mean(rounds[:len(batch)]))
            self.tracer.global_span(f"dispatch {didx}", t, t + dt, **args)
        done = []
        for i, tk in enumerate(batch):
            out_ids = ex.external_ids(ids[i])
            self._h_queue_wait.observe((t - tk.t_submit) * 1e3)
            if self.tracer is not None:
                self.tracer.span(tk.req_id, "queued", tk.t_submit, t,
                                 didx=didx)
                self.tracer.span(tk.req_id, "serve", t, t + dt,
                                 rung=rung, eps_served=eps_r,
                                 retries=attempt, didx=didx)
            res = ServeResult(
                status="ok" if rung == 0 else "degraded",
                ids=out_ids, scores=scores[i].copy(),
                eps_served=eps_r, delta_served=self._delta,
                cls=tk.cls.name, latency_s=(t + dt) - tk.t_submit,
                retries=attempt)
            self._finish(tk.req_id, res, t=t + dt)
            # only full-quality answers are cacheable: a degraded
            # (eps_served > eps) result must never be replayed to a
            # later query as if it met the contract eps
            if rung == 0 and tk.cache_key is not None:
                self.cache.put(self._salted(tk.cache_key),
                               (out_ids, scores[i].copy()))
            if (self._recall_rate > 0.0
                    and self._recall_rng.random() < self._recall_rate):
                self._recalls.append(ex.recall_of(tk.q, ids[i]))
            done.append(tk.req_id)
        for buf_name in ("_occupancy", "_pull_fracs", "_recalls"):
            buf = getattr(self, buf_name)
            if len(buf) > 100_000:
                setattr(self, buf_name, buf[-10_000:])
        return done, dt

    # ---- observability -----------------------------------------------------

    def stats(self) -> dict:
        """Runtime telemetry: tail latency, queue, outcomes, faults.

        ``latency_ms`` (p50/p95/p99) covers *answered* requests (cache
        hits at 0); shed/rejected/failed requests are visible in
        ``outcomes`` and ``admission`` instead.  ``degradation`` reports
        the eps ladder and how many responses each rung served
        (``eps_served`` histogram); ``lanes`` aggregates per-dispatch
        lane accounting (occupancy + executed pull fraction);
        ``faults`` reconciles retries / failed batches / store flush
        failures (+ the injector's own schedule when attached).
        """
        occ = np.asarray(self._occupancy, np.float64)
        answered = self.outcomes["ok"] + self.outcomes["degraded"]
        out = {
            "requests": self.n_requests,
            "completed": self.n_requests - self.admission.depth,
            "pending": self.admission.depth,
            "answered": answered,
            "availability": answered / max(1, self.n_requests),
            "dispatches": self.n_dispatches,
            "full_dispatches": self.n_full_dispatches,
            "cache": {"hits": self.cache.hits,
                      "misses": self.cache.misses,
                      "entries": len(self.cache),
                      "hit_rate": (self.cache.hits
                                   / max(1, self.cache.hits
                                         + self.cache.misses))},
            "latency_ms": summarize_latencies(self._lat),
            "queue": self.admission.stats(),
            "outcomes": dict(self.outcomes),
            "classes": {k: dict(v) for k, v in self.per_class.items()},
            "degradation": {
                "eps": self._eps,
                "eps_floor": self.ladder.eps_floor,
                "rungs": list(self.ladder.eps_values),
                "served_per_rung": list(self.rung_served),
                "degraded": self.outcomes["degraded"],
            },
            "lanes": {
                "lanes": self.lanes,
                "mean_occupancy": float(occ.mean()) if occ.size else 0.0,
                "mean_lane_util": (float(occ.mean()) / self.lanes
                                   if occ.size else 0.0),
                "mean_executed_pull_frac": (
                    float(np.mean(self._pull_fracs))
                    if self._pull_fracs else 1.0),
            },
            "faults": {
                "retries": self.n_retries,
                "dispatch_errors": self.n_dispatch_errors,
                "failed_batches": self.n_failed_batches,
                "slow_dispatches": self.n_slow_dispatches,
                "store_flush_failures": self.n_flush_failures,
                "update_errors": self.n_update_errors,
            },
            "recall": {"samples": len(self._recalls),
                       "mean": (float(np.mean(self._recalls))
                                if self._recalls else float("nan"))},
            "plan": {"rounds": len(self.plan.schedule.rounds),
                     "pull_speedup": self.plan.schedule.speedup},
            "updates": {"applied": self.n_updates,
                        "version": self._version,
                        "recalibrations": sum(
                            ex.n_recalibrations
                            for ex in self._rung_execs)},
        }
        if self.injector is not None:
            out["faults"]["injected"] = self.injector.stats()
        if self._store is not None:
            out["store"] = self._store.stats()
        return out
