"""Deterministic fault injection for the serving runtime (DESIGN.md §13).

Robustness claims are worthless untested, and flaky fault tests are worse
than none — so every fault here is drawn from a *seeded, stateless
schedule*: the decision for dispatch ``i`` (or flush ``j``) is a pure
function of ``(seed, fault kind, index, attempt)``, independent of call
order, wall clock, or how many other fault kinds are enabled.  Two runs
with the same seed inject byte-identical fault sequences; CI can assert
exact counters.

Three fault surfaces, matching the runtime's three failure domains:

  * **latency spikes** — heavy-tailed extra seconds added to a
    dispatch's virtual compute time (the virtual clock makes the spike
    exact, not a sleep): exercises deadline expiry, queue growth and the
    degradation ladder;
  * **dispatch exceptions** — :class:`InjectedDispatchError` raised from
    inside the executor call: exercises retry-with-backoff and, past the
    retry budget, the fail-only-this-micro-batch path + quarantine;
  * **store-flush failures** — :class:`repro.store.StoreFlushError`
    raised from the store's ``fault_hook`` before any staged mutation is
    applied: exercises the engine's keep-serving-stale-table path (the
    staged ops stay staged and retry at the next poll).

Attach with ``FaultInjector(...).attach(store)`` for the flush surface
and pass the injector to `repro.launch.engine.ServeRuntime` for the
dispatch surfaces.  `stats()` exports exactly what was injected so tests
can reconcile observed behaviour against the schedule.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

__all__ = ["InjectedDispatchError", "FaultInjector"]

# stable per-kind stream ids: entropy never collides across fault kinds
_KIND_LATENCY = 1
_KIND_ERROR = 2
_KIND_FLUSH = 3
_ROOT = 0x5EED_FA17  # namespace tag so injector streams never alias
                     # other default_rng(seed) users in the process


class InjectedDispatchError(RuntimeError):
    """A dispatch exception injected by `FaultInjector` (never raised by
    real executor code; tests match on this type to distinguish injected
    faults from genuine regressions)."""


class FaultInjector:
    """Seeded, stateless fault schedule over dispatch/flush indices.

    Args:
      seed: the schedule seed — the *only* source of randomness.
      latency_rate: probability a dispatch gets a latency spike.
      latency_ms: spike scale; actual spikes are ``latency_ms * (1 + P)``
        with P ~ Pareto(``latency_tail``) — heavy-tailed, like real
        stragglers.
      latency_tail: Pareto tail index of the spike distribution (smaller
        = heavier tail).
      error_rate: probability a dispatch raises
        `InjectedDispatchError`.  When it fires, the first
        ``fail_attempts(i)`` attempts fail — usually 1 (a transient the
        retry absorbs); with probability ``persistent_rate`` the fault is
        persistent (fails every attempt, forcing the micro-batch-failure
        path).
      persistent_rate: fraction of injected dispatch errors that never
        stop failing (conditional on an error firing at all).
      flush_failure_rate: probability a store `flush_updates` call is
        failed (via the hook installed by `attach`).

    Every decision method is pure in its index arguments; counters track
    what was actually *queried and fired* so `stats()` reconciles with
    runtime counters.
    """

    def __init__(self, seed: int = 0, *, latency_rate: float = 0.0,
                 latency_ms: float = 25.0, latency_tail: float = 1.5,
                 error_rate: float = 0.0, persistent_rate: float = 0.25,
                 flush_failure_rate: float = 0.0):
        for name, rate in (("latency_rate", latency_rate),
                           ("error_rate", error_rate),
                           ("persistent_rate", persistent_rate),
                           ("flush_failure_rate", flush_failure_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        self.seed = int(seed)
        self.latency_rate = float(latency_rate)
        self.latency_ms = float(latency_ms)
        self.latency_tail = float(latency_tail)
        self.error_rate = float(error_rate)
        self.persistent_rate = float(persistent_rate)
        self.flush_failure_rate = float(flush_failure_rate)
        self._flush_idx = 0
        self.n_latency_injected = 0
        self.injected_latency_s = 0.0
        self.n_errors_injected = 0
        self.n_persistent_errors = 0
        self.n_flush_failures = 0

    def _rng(self, kind: int, index: int) -> np.random.Generator:
        """The stateless per-(kind, index) generator of the schedule."""
        return np.random.default_rng(
            np.random.SeedSequence([_ROOT, self.seed, kind, int(index)]))

    # ---- dispatch surfaces ----------------------------------------------

    def latency_s(self, dispatch_idx: int) -> float:
        """Extra virtual seconds injected into dispatch ``dispatch_idx``
        (0.0 when the schedule doesn't spike it)."""
        if self.latency_rate <= 0.0:
            return 0.0
        rng = self._rng(_KIND_LATENCY, dispatch_idx)
        if rng.random() >= self.latency_rate:
            return 0.0
        spike = self.latency_ms * 1e-3 * (1.0 + rng.pareto(
            self.latency_tail))
        self.n_latency_injected += 1
        self.injected_latency_s += spike
        return float(spike)

    def fail_attempts(self, dispatch_idx: int) -> int:
        """How many leading attempts of dispatch ``dispatch_idx`` fail.

        0 = no injected error; 1..2 = transient (a retry will clear it);
        a large value (persistent fault) outlasts any retry budget.
        Pure in ``dispatch_idx`` — querying it twice is free.
        """
        if self.error_rate <= 0.0:
            return 0
        rng = self._rng(_KIND_ERROR, dispatch_idx)
        if rng.random() >= self.error_rate:
            return 0
        if rng.random() < self.persistent_rate:
            return 1_000_000           # outlasts any sane retry budget
        return int(rng.integers(1, 3))  # transient: 1-2 failing attempts

    def dispatch_error(self, dispatch_idx: int,
                       attempt: int = 0) -> Optional[InjectedDispatchError]:
        """The error to raise for (dispatch, attempt), or None.

        Counts each fired (dispatch, attempt) injection once; the
        persistent counter increments on the first attempt only.
        """
        fails = self.fail_attempts(dispatch_idx)
        if attempt >= fails:
            return None
        self.n_errors_injected += 1
        if fails > 2 and attempt == 0:
            self.n_persistent_errors += 1
        kind = "persistent" if fails > 2 else "transient"
        return InjectedDispatchError(
            f"injected {kind} dispatch fault "
            f"(dispatch={dispatch_idx}, attempt={attempt})")

    # ---- store-flush surface --------------------------------------------

    def attach(self, store) -> None:
        """Install this injector as ``store.fault_hook``.

        The store calls the hook at the top of every `flush_updates`,
        *before* taking staged mutations — a failed flush leaves the
        staged queue intact (the store's torn-flush contract), so the
        engine retries it at its next poll.
        """
        store.fault_hook = self._flush_hook

    def _flush_hook(self) -> None:
        from repro.store import StoreFlushError
        idx, self._flush_idx = self._flush_idx, self._flush_idx + 1
        if self.flush_failure_rate <= 0.0:
            return
        rng = self._rng(_KIND_FLUSH, idx)
        if rng.random() < self.flush_failure_rate:
            self.n_flush_failures += 1
            raise StoreFlushError(
                f"injected store flush failure (flush={idx})")

    # ---- observability ---------------------------------------------------

    def stats(self) -> dict:
        """What the schedule actually injected, as a plain dict."""
        return {
            "seed": self.seed,
            "latency_spikes": self.n_latency_injected,
            "injected_latency_ms": self.injected_latency_s * 1e3,
            "dispatch_errors": self.n_errors_injected,
            "persistent_errors": self.n_persistent_errors,
            "flush_failures": self.n_flush_failures,
        }
