"""Deterministic fault injection for the serving runtime (DESIGN.md §13).

Robustness claims are worthless untested, and flaky fault tests are worse
than none — so every fault here is drawn from a *seeded, stateless
schedule*: the decision for dispatch ``i`` (or flush ``j``) is a pure
function of ``(seed, fault kind, index, attempt)``, independent of call
order, wall clock, or how many other fault kinds are enabled.  Two runs
with the same seed inject byte-identical fault sequences; CI can assert
exact counters.

Three fault surfaces, matching the runtime's three failure domains:

  * **latency spikes** — heavy-tailed extra seconds added to a
    dispatch's virtual compute time (the virtual clock makes the spike
    exact, not a sleep): exercises deadline expiry, queue growth and the
    degradation ladder;
  * **dispatch exceptions** — :class:`InjectedDispatchError` raised from
    inside the executor call: exercises retry-with-backoff and, past the
    retry budget, the fail-only-this-micro-batch path + quarantine;
  * **store-flush failures** — :class:`repro.store.StoreFlushError`
    raised from the store's ``fault_hook`` before any staged mutation is
    applied: exercises the engine's keep-serving-stale-table path (the
    staged ops stay staged and retry at the next poll).

Attach with ``FaultInjector(...).attach(store)`` for the flush surface
and pass the injector to `repro.launch.engine.ServeRuntime` for the
dispatch surfaces.  `stats()` exports exactly what was injected — plus,
per kind, how many decision points the schedule *saw* and the resulting
injection rates (``injected / seen``), so tests can reconcile observed
behaviour against the configured rates.  The same counters live on the
injector's `repro.obs.metrics` registry (``faults_*``), which
`ServeRuntime` adopts into its own registry when the injector is
attached (docs/OBSERVABILITY.md).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry

__all__ = ["InjectedDispatchError", "FaultInjector"]

# stable per-kind stream ids: entropy never collides across fault kinds
_KIND_LATENCY = 1
_KIND_ERROR = 2
_KIND_FLUSH = 3
_ROOT = 0x5EED_FA17  # namespace tag so injector streams never alias
                     # other default_rng(seed) users in the process


class InjectedDispatchError(RuntimeError):
    """A dispatch exception injected by `FaultInjector` (never raised by
    real executor code; tests match on this type to distinguish injected
    faults from genuine regressions)."""


class FaultInjector:
    """Seeded, stateless fault schedule over dispatch/flush indices.

    Args:
      seed: the schedule seed — the *only* source of randomness.
      latency_rate: probability a dispatch gets a latency spike.
      latency_ms: spike scale; actual spikes are ``latency_ms * (1 + P)``
        with P ~ Pareto(``latency_tail``) — heavy-tailed, like real
        stragglers.
      latency_tail: Pareto tail index of the spike distribution (smaller
        = heavier tail).
      error_rate: probability a dispatch raises
        `InjectedDispatchError`.  When it fires, the first
        ``fail_attempts(i)`` attempts fail — usually 1 (a transient the
        retry absorbs); with probability ``persistent_rate`` the fault is
        persistent (fails every attempt, forcing the micro-batch-failure
        path).
      persistent_rate: fraction of injected dispatch errors that never
        stop failing (conditional on an error firing at all).
      flush_failure_rate: probability a store `flush_updates` call is
        failed (via the hook installed by `attach`).
      metrics: an existing `repro.obs.metrics.MetricsRegistry` to file
        the ``faults_*`` metrics under (default: a private registry on
        ``self.metrics``, adopted by the runtime).

    Every decision method is pure in its index arguments; counters track
    what was actually *queried and fired* so `stats()` reconciles with
    runtime counters.
    """

    def __init__(self, seed: int = 0, *, latency_rate: float = 0.0,
                 latency_ms: float = 25.0, latency_tail: float = 1.5,
                 error_rate: float = 0.0, persistent_rate: float = 0.25,
                 flush_failure_rate: float = 0.0,
                 metrics: Optional[MetricsRegistry] = None):
        for name, rate in (("latency_rate", latency_rate),
                           ("error_rate", error_rate),
                           ("persistent_rate", persistent_rate),
                           ("flush_failure_rate", flush_failure_rate)):
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {rate}")
        self.seed = int(seed)
        self.latency_rate = float(latency_rate)
        self.latency_ms = float(latency_ms)
        self.latency_tail = float(latency_tail)
        self.error_rate = float(error_rate)
        self.persistent_rate = float(persistent_rate)
        self.flush_failure_rate = float(flush_failure_rate)
        self._flush_idx = 0
        # exact seconds accumulator for the legacy latency stats — the
        # histogram buckets the same spikes in ms, but the stat contract
        # is the exact schedule sum in the schedule's own unit
        self._injected_latency_s = 0.0
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c_injected = self.metrics.counter(
            "faults_injected_total", "Faults actually fired, by kind.",
            ("kind",))
        self._c_seen = self.metrics.counter(
            "faults_seen_total",
            "Injection decision points evaluated, by kind.", ("kind",))
        for k in ("latency", "error", "flush"):
            self._c_injected.seed(kind=k)
            self._c_seen.seed(kind=k)
        self._c_persistent = self.metrics.counter(
            "faults_persistent_errors_total",
            "Injected dispatch errors that outlast any retry budget.")
        self._c_error_dispatches = self.metrics.counter(
            "faults_error_dispatches_total",
            "Dispatches with at least one injected error attempt.")
        self._h_latency = self.metrics.histogram(
            "faults_injected_latency_ms",
            "Injected latency spike sizes (ms).")

    # ---- legacy counter surface (registry-backed) ------------------------

    @property
    def n_latency_injected(self) -> int:
        """Latency spikes fired by the schedule."""
        return int(self._c_injected.get(kind="latency"))

    @property
    def injected_latency_s(self) -> float:
        """Total injected spike seconds (exact schedule sum)."""
        return self._injected_latency_s

    @property
    def n_errors_injected(self) -> int:
        """Fired (dispatch, attempt) error injections."""
        return int(self._c_injected.get(kind="error"))

    @property
    def n_persistent_errors(self) -> int:
        """Dispatches given a persistent (retry-proof) error."""
        return int(self._c_persistent.total())

    @property
    def n_flush_failures(self) -> int:
        """Store flush_updates calls failed by the hook."""
        return int(self._c_injected.get(kind="flush"))

    def _rng(self, kind: int, index: int) -> np.random.Generator:
        """The stateless per-(kind, index) generator of the schedule."""
        return np.random.default_rng(
            np.random.SeedSequence([_ROOT, self.seed, kind, int(index)]))

    # ---- dispatch surfaces ----------------------------------------------

    def latency_s(self, dispatch_idx: int) -> float:
        """Extra virtual seconds injected into dispatch ``dispatch_idx``
        (0.0 when the schedule doesn't spike it)."""
        self._c_seen.inc(kind="latency")
        if self.latency_rate <= 0.0:
            return 0.0
        rng = self._rng(_KIND_LATENCY, dispatch_idx)
        if rng.random() >= self.latency_rate:
            return 0.0
        spike = self.latency_ms * 1e-3 * (1.0 + rng.pareto(
            self.latency_tail))
        self._c_injected.inc(kind="latency")
        self._injected_latency_s += spike
        self._h_latency.observe(spike * 1e3)
        return float(spike)

    def fail_attempts(self, dispatch_idx: int) -> int:
        """How many leading attempts of dispatch ``dispatch_idx`` fail.

        0 = no injected error; 1..2 = transient (a retry will clear it);
        a large value (persistent fault) outlasts any retry budget.
        Pure in ``dispatch_idx`` — querying it twice is free.
        """
        if self.error_rate <= 0.0:
            return 0
        rng = self._rng(_KIND_ERROR, dispatch_idx)
        if rng.random() >= self.error_rate:
            return 0
        if rng.random() < self.persistent_rate:
            return 1_000_000           # outlasts any sane retry budget
        return int(rng.integers(1, 3))  # transient: 1-2 failing attempts

    def dispatch_error(self, dispatch_idx: int,
                       attempt: int = 0) -> Optional[InjectedDispatchError]:
        """The error to raise for (dispatch, attempt), or None.

        Counts each fired (dispatch, attempt) injection once; the
        persistent counter increments on the first attempt only, and the
        per-kind ``seen`` counter counts each *dispatch* once (attempt 0).
        """
        if attempt == 0:
            self._c_seen.inc(kind="error")
        fails = self.fail_attempts(dispatch_idx)
        if attempt == 0 and fails > 0:
            self._c_error_dispatches.inc()
        if attempt >= fails:
            return None
        self._c_injected.inc(kind="error")
        if fails > 2 and attempt == 0:
            self._c_persistent.inc()
        kind = "persistent" if fails > 2 else "transient"
        return InjectedDispatchError(
            f"injected {kind} dispatch fault "
            f"(dispatch={dispatch_idx}, attempt={attempt})")

    # ---- store-flush surface --------------------------------------------

    def attach(self, store) -> None:
        """Install this injector as ``store.fault_hook``.

        The store calls the hook at the top of every `flush_updates`,
        *before* taking staged mutations — a failed flush leaves the
        staged queue intact (the store's torn-flush contract), so the
        engine retries it at its next poll.
        """
        store.fault_hook = self._flush_hook

    def _flush_hook(self) -> None:
        from repro.store import StoreFlushError
        idx, self._flush_idx = self._flush_idx, self._flush_idx + 1
        self._c_seen.inc(kind="flush")
        if self.flush_failure_rate <= 0.0:
            return
        rng = self._rng(_KIND_FLUSH, idx)
        if rng.random() < self.flush_failure_rate:
            self._c_injected.inc(kind="flush")
            raise StoreFlushError(
                f"injected store flush failure (flush={idx})")

    # ---- observability ---------------------------------------------------

    def stats(self) -> dict:
        """What the schedule injected, saw, and the realized rates.

        The legacy keys are unchanged (``injected_latency_ms`` is
        milliseconds — the same unit as the
        `repro.obs.metrics.LATENCY_BUCKETS_MS` histogram buckets);
        ``seen`` counts decision points per kind (dispatches for
        latency/error, flush calls for flush) and ``rates`` is
        ``injected / seen`` — the *realized* per-kind injection rate to
        reconcile against the configured probabilities.
        """
        seen = {k: int(self._c_seen.get(kind=k))
                for k in ("latency", "error", "flush")}
        fired = {"latency": self.n_latency_injected,
                 # rate denominators are dispatches/flushes, so the error
                 # numerator counts dispatches with >= 1 injected attempt
                 # (n_errors_injected counts per-attempt firings)
                 "error": int(self._c_error_dispatches.total()),
                 "flush": self.n_flush_failures}
        return {
            "seed": self.seed,
            "latency_spikes": self.n_latency_injected,
            "injected_latency_ms": self._injected_latency_s * 1e3,
            "dispatch_errors": self.n_errors_injected,
            "persistent_errors": self.n_persistent_errors,
            "flush_failures": self.n_flush_failures,
            "seen": seen,
            "rates": {k: (fired[k] / seen[k] if seen[k] else 0.0)
                      for k in ("latency", "error", "flush")},
        }
