"""Pallas TPU kernel: exact blocked logit matvec (the exhaustive baseline).

out = W @ q for W (n, d): grid (n/TN, d/TD), f32 VMEM accumulation.  Used by
the exact decode path and as the roofline's memory-bound comparator for the
bandit kernel (same tiles, no early stopping).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["blocked_matvec_pallas"]


def _kernel(W_ref, q_ref, out_ref):
    j = pl.program_id(1)
    part = jnp.dot(W_ref[...], q_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_d", "interpret"))
def blocked_matvec_pallas(W: jnp.ndarray, q: jnp.ndarray, *,
                          tile_n: int = 256, tile_d: int = 512,
                          interpret: bool = False) -> jnp.ndarray:
    n, d = W.shape
    tile_n = min(tile_n, n)
    tile_d = min(tile_d, d)
    if n % tile_n or d % tile_d:
        raise ValueError(f"(n={n}, d={d}) not divisible by tiles "
                         f"({tile_n}, {tile_d}); pad upstream")
    grid = (n // tile_n, d // tile_d)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, tile_d), lambda i, j: (i, j)),
            pl.BlockSpec((tile_d,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(W, q)
