"""Pallas TPU kernel: the BoundedME pull hot loop.

Computes partial inner products for the surviving arm tiles over this
round's coordinate blocks:

    out[t, :] = sum_b  V4[idx[t], cols[b]] @ qsel[b]        (T, R) float32

The gather is done by the *grid*, not by data movement: ``idx`` and ``cols``
are scalar-prefetched (SMEM) and the BlockSpec index_map dereferences them,
so each grid step DMAs exactly one (R, C) tile of V from HBM into VMEM —
only the bytes the bandit actually pulls ever cross the memory bus.  This is
the TPU-native analogue of the paper's "pull one coordinate" primitive
(DESIGN.md §3): one pull = one (R, C) MXU tile-dot.

Grid: (T, dt) with the block axis innermost; the output block for a fixed
tile is revisited across the inner axis and accumulated in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gather_block_dot_pallas"]


def _kernel(idx_ref, cols_ref, V_ref, q_ref, out_ref):
    # V_ref: (1, 1, R, C) VMEM tile; q_ref: (1, C); out_ref: (1, R) f32
    j = pl.program_id(1)
    v = V_ref[0, 0]                      # (R, C)
    q = q_ref[0]                         # (C,)
    part = jnp.dot(v, q, preferred_element_type=jnp.float32)  # (R,)

    @pl.when(j == 0)
    def _init():
        out_ref[0] = part

    @pl.when(j != 0)
    def _acc():
        out_ref[0] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_block_dot_pallas(V4: jnp.ndarray, idx: jnp.ndarray,
                            cols: jnp.ndarray, qsel: jnp.ndarray,
                            *, interpret: bool = False) -> jnp.ndarray:
    n_tiles, n_blocks, R, C = V4.shape
    T, dt = idx.shape[0], cols.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # idx, cols land in SMEM before the grid runs
        grid=(T, dt),
        in_specs=[
            pl.BlockSpec((1, 1, R, C),
                         lambda i, j, idx_ref, cols_ref:
                         (idx_ref[i], cols_ref[j], 0, 0)),
            pl.BlockSpec((1, C), lambda i, j, idx_ref, cols_ref: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, R), lambda i, j, idx_ref, cols_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, R), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), cols.astype(jnp.int32), V4, qsel)
