"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this CPU container) the kernels run in interpret mode,
which executes the kernel body in Python for correctness validation; on TPU
they lower to Mosaic.  The pure-jnp oracles live in ``repro.kernels.ref``.

Every wrapper is generic in the feature-tile width ``C``: the same entry
points serve 'row'-mode plans (wide blocks) and 'coord'-mode plans (narrow
coordinate tiles, DESIGN.md §14) — callers select the pull mode purely
through the plan geometry baked into the operands and flat schedule.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather_dot import gather_block_dot_pallas
from repro.kernels.blocked_matvec import blocked_matvec_pallas
from repro.kernels.fused_cascade import (fused_cascade_pallas,
                                         fused_cascade_batched_pallas)
from repro.kernels import ref

__all__ = ["gather_block_dot", "blocked_matvec", "fused_cascade",
           "fused_cascade_batched", "on_tpu", "count_pallas_calls"]


def on_tpu() -> bool:
    """True when the default backend compiles Pallas to Mosaic (TPU)."""
    return jax.default_backend() == "tpu"


def count_pallas_calls(jaxpr) -> int:
    """Kernel dispatches reachable from ``jaxpr`` (through jit/scan/etc.).

    The PR-1 acceptance metric: the fused path must show exactly one
    `pallas_call` regardless of round count.  Shared by the test suite and
    `benchmarks/bench_fused.py` so the two can't drift apart.
    """
    from jax.core import ClosedJaxpr, Jaxpr

    def sub(params):
        for v in params.values():
            stack = [v]
            while stack:
                x = stack.pop()
                if isinstance(x, ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, Jaxpr):
                    yield x
                elif isinstance(x, (list, tuple)):
                    stack.extend(x)

    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += 1
        for j in sub(eqn.params):
            total += count_pallas_calls(j)
    return total


def gather_block_dot(V4, idx, cols, qsel):
    """Per-round BoundedME pull step: see `repro.kernels.gather_dot`."""
    return gather_block_dot_pallas(V4, idx, cols, qsel,
                                   interpret=not on_tpu())


def fused_cascade(V4, qb, slotcode, rounds_meta, cols, *, n_arms, K,
                  t_final, n_final, k_out=None, n_valid=None,
                  vscale=None, qscale=None, codebook=None,
                  packed_int4=False, cert=None, k_cert=1,
                  track_var=False):
    """Whole-cascade single dispatch: see `repro.kernels.fused_cascade`.

    Beyond the schedule operands: ``k_out`` (default K) widens the
    in-kernel final extraction so shard-local callers get extra threshold
    candidates (it never changes the elimination schedule; must satisfy
    ``K <= k_out <= n_final * tile``); ``n_valid`` (default ``n_arms``,
    may be a traced scalar) masks rows >= n_valid out of every tile-max
    and extraction so caller padding can never win (DESIGN.md §7);
    ``vscale``/``qscale`` are the int8/int4 dequantization scales of the
    quantized sampling path (DESIGN.md §10, `repro.core.quantize`) —
    ``packed_int4=True`` marks the table nibble-packed (last dim C/2) —
    and ``codebook`` selects the product-quantized tier instead (uint8
    code table, f32 queries, LUT tile-dots);
    ``cert``/``k_cert``/``track_var`` (per-round radius coefficients from
    `repro.core.schedule.cert_coeffs`, the certified top-K, and the
    M2-accumulator switch) enable adaptive early exit and append a
    ``rounds_used`` output (DESIGN.md §12).
    """
    return fused_cascade_pallas(V4, qb, slotcode, rounds_meta, cols,
                                n_arms=n_arms, K=K, t_final=t_final,
                                n_final=n_final, k_out=k_out,
                                n_valid=n_valid, vscale=vscale,
                                qscale=qscale, codebook=codebook,
                                packed_int4=packed_int4, cert=cert,
                                k_cert=k_cert, track_var=track_var,
                                interpret=not on_tpu())


def fused_cascade_batched(V4, Qb, slotcode, rounds_meta, cols, *, n_arms, K,
                          t_final, n_final, k_out=None, n_valid=None,
                          vscale=None, qscale=None, codebook=None,
                          packed_int4=False, cert=None, k_cert=1,
                          track_var=False):
    """Batched whole-cascade dispatch: query axis in the kernel grid.

    ``k_out``/``n_valid``/``vscale``/``qscale``/``codebook``/
    ``packed_int4``/``cert`` behave exactly as in :func:`fused_cascade`
    (``qscale`` is per query here, (B, n_blocks), and the adaptive
    ``rounds_used`` output is per query, (B,)).
    """
    return fused_cascade_batched_pallas(V4, Qb, slotcode, rounds_meta, cols,
                                        n_arms=n_arms, K=K, t_final=t_final,
                                        n_final=n_final, k_out=k_out,
                                        n_valid=n_valid, vscale=vscale,
                                        qscale=qscale, codebook=codebook,
                                        packed_int4=packed_int4, cert=cert,
                                        k_cert=k_cert, track_var=track_var,
                                        interpret=not on_tpu())


def blocked_matvec(W, q, *, tile_n: int = 256, tile_d: int = 512):
    """Exact blocked logit matvec: see `repro.kernels.blocked_matvec`."""
    return blocked_matvec_pallas(W, q, tile_n=tile_n, tile_d=tile_d,
                                 interpret=not on_tpu())
