"""jit'd public wrappers for the Pallas kernels.

On non-TPU backends (this CPU container) the kernels run in interpret mode,
which executes the kernel body in Python for correctness validation; on TPU
they lower to Mosaic.  The pure-jnp oracles live in ``repro.kernels.ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gather_dot import gather_block_dot_pallas
from repro.kernels.blocked_matvec import blocked_matvec_pallas
from repro.kernels import ref

__all__ = ["gather_block_dot", "blocked_matvec", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gather_block_dot(V4, idx, cols, qsel):
    """BoundedME pull step: see `repro.kernels.gather_dot`."""
    return gather_block_dot_pallas(V4, idx, cols, qsel,
                                   interpret=not on_tpu())


def blocked_matvec(W, q, *, tile_n: int = 256, tile_d: int = 512):
    """Exact blocked logit matvec: see `repro.kernels.blocked_matvec`."""
    return blocked_matvec_pallas(W, q, tile_n=tile_n, tile_d=tile_d,
                                 interpret=not on_tpu())
