"""Pallas TPU kernel: the whole BoundedME cascade in ONE dispatch.

The per-round `gather_block_dot` kernel still pays one launch + an XLA-level
top-k + survivor reshuffle per elimination round; at decode batch sizes that
dispatch overhead eats the sample-complexity savings the schedule buys.
Because the round structure is data-independent (`repro.core.schedule`), the
*entire* multi-round pull program can be flattened host-side
(`flatten_schedule`) and executed as a single grid (DESIGN.md §3):

  * the (n_tiles, R) f32 accumulator and the survivor index set stay
    VMEM/SMEM-resident across all rounds — they never round-trip to HBM;
  * each grid step manually DMAs exactly one surviving (R, C) tile of V
    from HBM (double-buffered: the next step's tile is prefetched while the
    current MXU tile-dot runs).  Only the bytes the bandit pulls ever cross
    the memory bus, and the survivor indices live in SMEM, so the
    "gather" costs no HBM traffic at all;
  * at round boundaries the tile elimination (masked tile-max + iterative
    top-k extraction, lowest-index tie-break — exactly `lax.top_k`
    semantics) runs *inside* the kernel, updating the SMEM survivor list;
  * the final top-K arms are extracted in-kernel and returned as (ids,
    scores) — dispatch count per query drops from O(rounds) to 1.

The batched variant puts the query axis in the grid: one launch serves a
(B, N) decode batch, with per-query accumulator/survivor state re-initialized
at each query's first step.

Scalar-prefetch operands (SMEM):
  slotcode (S,)           packed slot | PULL_BIT | END_BIT per step
  rounds_meta (rounds+1,3) (t_cum, n_surv, n_keep) consumed at end steps
  cert (rounds+1, 2)      adaptive only: per-round certification-radius
                          coefficients (a_l, b_l) from
                          `repro.core.schedule.cert_coeffs` (DESIGN.md §12)
  cols (S,) / (B, S)      column-block id pulled per step (perm[bpos])
  nvalid (1,)             rows >= nvalid are masked out of every ranking
                          (tile padding AND caller padding, e.g. a padded
                          vocab or a ragged shard — DESIGN.md §7); may be
                          a traced value (per-shard under shard_map)

Quantized sampling (DESIGN.md §10): when ``V4``/``qb`` are int8 the caller
passes the per-tile table scales ``vscale (n_tiles, n_blocks) f32`` and the
per-block query scales ``qscale (1|B, n_blocks) f32`` (VMEM-resident,
`repro.core.quantize`).  Each pull's tile-dot then runs int8 x int8 -> int32
on the MXU — half the HBM bytes per pulled tile — and is dequantized with
the scalar ``vscale[tile, col] * qscale[col]`` before entering the same f32
accumulator; elimination, survivor bookkeeping and extraction are unchanged.
The widened confidence radii that absorb the quantization bias live in the
schedule, not here (`make_schedule(quant_err=...)`).

Two further tiers ride the same pull pipeline (DESIGN.md §10):

  * ``packed_int4=True`` — ``V4``'s last dim holds nibble-packed int4
    codes (C/2 bytes per row per pull, half the int8 traffic); the pull
    step sign-extends with the shared `repro.core.quantize.unpack_int4`
    (pure shifts, no gather) and then runs the SAME int8-style exact
    integer dot + scalar dequantize.  Queries stay int8 (W4A8), so
    ``vscale``/``qscale`` are required exactly as for int8.
  * ``codebook`` given — product-quantized tiles: ``V4`` holds uint8
    codes (n_tiles, n_blocks, R, S) with S = C / subdims bytes per row
    per pull, the f32 ``codebook (n_blocks, S, n_codes, subdims)`` sits
    fully VMEM-resident, queries stay f32, and each pull is the shared
    `repro.core.quantize.pq_tile_dot`: a per-(pull, block) LUT of
    query-vs-codeword products plus a one-hot compare-and-reduce per row
    (gather-free, so it lowers on TPU and stays bit-exact with the jnp
    fallbacks that call the same function).

In every tier the *stored* last dim of ``V4`` (C, C/2 or S) is what the
DMA moves — the bytes-per-pull reduction is physical, not notational.

Adaptive early exit (DESIGN.md §12): with ``cert`` the kernel keeps a
per-query ``active`` lane in SMEM next to the existing ``n_valid``
plumbing.  After every round-end step it evaluates the certification
predicate over the post-elimination survivors' rows — each row's radius is
``a_l sqrt(max(Vhat, 0)) + b_l`` on the block-mean scale, with ``Vhat``
from a second running-M2 accumulator when the schedule's bound family is
'bernstein' — and if the top-``k_cert`` rows' lower bounds clear every
other survivor's upper bound, the query's remaining pull steps (tile DMA +
accumulate + prefetch) become masked no-ops.  Eliminations keep running on
the frozen accumulator (every survivor froze at the same pull count, so
scheduled-denominator means are a positive rescale of the true means and
every later ranking is unchanged), the final extraction normalizes by the
*actual* pull count, and a third output reports per-query ``rounds_used``.

Coordinate-sampling pull mode (DESIGN.md §14): the kernel is fully generic
in the feature-tile width ``C`` — a pull step DMAs one surviving ``(R, C)``
feature tile ``V_ref.at[tile, col]`` regardless of whether the plan calls
that tile a 'row'-mode block (C = min(512, d)) or a 'coord'-mode narrow
feature tile (C = coord_block, default 128 = the TPU lane width, so the
narrow tiles stay MXU/VPU-legal).  ``pull_mode='coord'`` therefore needs
ZERO kernel changes: `make_plan` re-blocks the feature axis so the flat
schedule's column ids index ``n_blocks = ceil(d / coord_block)`` narrow
tiles, and the same double-buffered DMA pipeline, survivor bookkeeping,
int8 scale grids (``vscale (n_tiles, n_blocks)``/``qscale`` follow the
plan's blocking automatically) and adaptive certification lanes serve both
reward streams.  Per-pull HBM traffic drops from ``R * 512`` to
``R * coord_block`` operand elements — the whole point at large d — while
the permutation/ordering semantics (and hence kernel == fallback bitwise
parity) are unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantize import pq_tile_dot, unpack_int4
from repro.core.schedule import END_BIT, PULL_BIT, SLOT_MASK

__all__ = ["fused_cascade_pallas", "fused_cascade_batched_pallas"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _make_kernel(*, n_arms, R, C, K, n_tiles, t_final, n_final, S, Pw, B,
                 qkind="none", adaptive=False, track_var=False,
                 k_cert=1, n_rounds=0, Pc=0):
    """Build the kernel body.  B is None for the single-query variant.

    ``qkind`` selects the pull arithmetic (DESIGN.md §10): 'none' (f32
    tile-dot), 'int8'/'int4' (the tensor-operand list grows by (vscale,
    qscale) and every pull dequantizes its exact int32 tile-dot — int4
    first sign-extends the nibble-packed tile with `unpack_int4`), or
    'pq' (the operand list grows by the f32 ``codebook`` instead and the
    pull is the `pq_tile_dot` LUT walk; queries stay f32).  ``C`` is the
    TRUE block width (denominators) — the DMA'd tile's last dim is
    whatever the stored operand carries (C, C/2 or S).
    With ``adaptive`` the scalar-prefetch list grows by the per-round
    ``cert`` coefficients, the outputs by ``rounds_used``, and the scratch
    by the active/t_stop lanes plus the certification work buffers
    (``track_var`` additionally carries the M2 accumulator for the
    variance-aware 'bernstein' radii); ``k_cert`` is the *contract* top-K
    the predicate certifies (K above is the extraction width ``k_out``).
    """
    batched = B is not None

    def kernel(code_ref, rmeta_ref, *more):
        if adaptive:
            cert_ref, cols_ref, nv_ref, V_ref, q_ref, *rest = more
        else:
            cols_ref, nv_ref, V_ref, q_ref, *rest = more
            cert_ref = None
        if qkind in ("int8", "int4"):
            vs_ref, qs_ref, *rest = rest
            cb_ref = None
        elif qkind == "pq":
            cb_ref, *rest = rest
            vs_ref = qs_ref = None
        else:
            vs_ref = qs_ref = cb_ref = None
        ids_ref, vals_ref, *rest = rest
        if adaptive:
            rused_ref, *rest = rest
        acc, *rest = rest
        if track_var:
            acc2, *rest = rest
        else:
            acc2 = None
        vbuf, surv, tmp, scorebuf, rnd, *rest = rest
        if adaptive:
            active, tstop, minlb, bufM, bufU, bufL, sem = rest
        else:
            (sem,) = rest
            active = tstop = minlb = bufM = bufU = bufL = None
        # constants must be materialized inside the traced body
        _NEG = jnp.float32(-jnp.inf)
        _NAN = jnp.float32(jnp.nan)
        denom_final = jnp.float32(max(1, t_final) * C)
        if batched:
            b, i = pl.program_id(0), pl.program_id(1)
        else:
            b, i = 0, pl.program_id(0)
        code = code_ref[i]
        slot = code & SLOT_MASK
        pull = (code & PULL_BIT) != 0
        end = (code & END_BIT) != 0
        col = cols_ref[b, i] if batched else cols_ref[i]
        dslot = jax.lax.rem(i, 2)
        colid = jax.lax.broadcasted_iota(jnp.int32, (1, Pw), 1)
        if adaptive:
            colid_c = jax.lax.broadcasted_iota(jnp.int32, (1, Pc), 1)

        @pl.when(i == 0)
        def _init():  # per-query state (re-entered at each b in the batch)
            acc[:] = jnp.zeros_like(acc)
            rnd[0] = 0
            if adaptive:
                active[0] = 1
                tstop[0] = t_final
                rused_ref[0, 0] = n_rounds
            if track_var:
                acc2[:] = jnp.zeros_like(acc2)

            def w(j, _):
                surv[j] = j
                return 0
            jax.lax.fori_loop(0, n_tiles, w, 0)

        first = jnp.logical_and(b == 0, i == 0) if batched else i == 0

        @pl.when(jnp.logical_and(first, pull))
        def _start_first():  # every later step is prefetched by the previous
            tile = surv[slot]
            pltpu.make_async_copy(V_ref.at[tile, col], vbuf.at[0],
                                  sem.at[0]).start()

        # a certified (inactive) query's remaining pulls are masked no-ops:
        # no DMA wait, no accumulate — and _warm below starts no DMA for it
        do_pull = (jnp.logical_and(pull, active[0] == 1) if adaptive
                   else pull)

        @pl.when(do_pull)
        def _pull():
            tile = surv[slot]
            pltpu.make_async_copy(V_ref.at[tile, col], vbuf.at[dslot],
                                  sem.at[dslot]).wait()
            qcol = (q_ref[0, pl.ds(col, 1), :] if batched
                    else q_ref[pl.ds(col, 1), :])          # (1, C)
            if qkind == "pq":
                # per-pull LUT of query-vs-codeword products + one-hot
                # lookups per row — the SHARED `pq_tile_dot`, so the jnp
                # fallbacks run literally the same f32 ops (bit-exact)
                cb = cb_ref[pl.ds(col, 1)][0]      # (S, n_codes, w)
                part = pq_tile_dot(vbuf[dslot], qcol[0], cb)       # (R,)
            elif qkind in ("int8", "int4"):
                # int8 x int8 -> int32 on the MXU, then dequantize with the
                # scalar tile/block scale product.  The jnp fallback does
                # the identical (exact) integer dot and the identical two
                # float ops per entry, so the paths stay bit-exact.  int4
                # tiles arrive nibble-packed and sign-extend in-register
                # with the same shared `unpack_int4` (pure shifts).
                tilebuf = vbuf[dslot]
                if qkind == "int4":
                    tilebuf = unpack_int4(tilebuf)
                raw = jnp.dot(tilebuf, qcol[0],
                              preferred_element_type=jnp.int32)    # (R,)
                s = vs_ref[tile, col] * qs_ref[0, col]
                part = raw.astype(jnp.float32) * s
            else:
                part = jnp.dot(vbuf[dslot], qcol[0],
                               preferred_element_type=jnp.float32)  # (R,)
            acc[pl.ds(tile, 1), :] = acc[pl.ds(tile, 1), :] + part[None]
            if track_var:
                acc2[pl.ds(tile, 1), :] = (acc2[pl.ds(tile, 1), :]
                                           + (part * part)[None])

        @pl.when(end)
        def _eliminate():
            r = rnd[0]
            denom = (rmeta_ref[r, 0] * C).astype(jnp.float32)
            T, keep = rmeta_ref[r, 1], rmeta_ref[r, 2]

            def score_body(s, _):  # slot-ordered masked tile-max means
                tile = surv[s]
                means = acc[pl.ds(tile, 1), :] / denom          # (1, R)
                rowids = tile * R + jax.lax.broadcasted_iota(
                    jnp.int32, (1, R), 1)
                scorebuf[0, s] = jnp.max(
                    jnp.where(rowids < nv_ref[0], means, _NEG))
                return 0
            jax.lax.fori_loop(0, T, score_body, 0)
            scorebuf[:] = jnp.where(colid < T, scorebuf[:], _NEG)

            def extract(j, _):  # descending, lowest-index tie-break
                # extracted slots become NaN: they can never tie with the
                # running max again (an -inf marker re-extracts the same
                # slot once the max itself reaches -inf, duplicating
                # survivors whenever fewer than `keep` tiles hold a valid
                # row — exactly `lax.top_k`'s distinct-index semantics)
                sc = scorebuf[:]
                m = jnp.max(jnp.where(jnp.isnan(sc), _NEG, sc))
                arg = jnp.min(jnp.where(sc == m, colid, Pw))
                tmp[j] = surv[arg]
                scorebuf[0, arg] = _NAN
                return 0
            jax.lax.fori_loop(0, keep, extract, 0)

            def writeback(j, _):
                surv[j] = tmp[j]
                return 0
            jax.lax.fori_loop(0, keep, writeback, 0)

            if adaptive:
                # certification over the post-elimination survivors' rows
                # (DESIGN.md §12): radius_i = a sqrt(max(Vhat_i, 0)) + b,
                # fire when the top-k_cert rows' lower bounds clear every
                # other valid row's upper bound
                @pl.when(active[0] == 1)
                def _certify():
                    a = cert_ref[r, 0]
                    bconst = cert_ref[r, 1]
                    denomC = denom * jnp.float32(C)
                    bufM[:] = jnp.full((1, Pc), _NEG, jnp.float32)
                    bufU[:] = jnp.full((1, Pc), _NEG, jnp.float32)
                    bufL[:] = jnp.full((1, Pc), _NEG, jnp.float32)

                    def fill(s, _):
                        tile = surv[s]
                        mu = acc[pl.ds(tile, 1), :] / denom     # (1, R)
                        if track_var:
                            v = (acc2[pl.ds(tile, 1), :] / denomC
                                 - mu * mu)
                            rad = a * jnp.sqrt(jnp.maximum(v, 0.0)) + bconst
                        else:
                            rad = jnp.full_like(mu, bconst)
                        rowids = tile * R + jax.lax.broadcasted_iota(
                            jnp.int32, (1, R), 1)
                        valid = rowids < nv_ref[0]
                        bufM[0, pl.ds(s * R, R)] = jnp.where(
                            valid, mu, _NEG)[0]
                        bufU[0, pl.ds(s * R, R)] = jnp.where(
                            valid, mu + rad, _NEG)[0]
                        bufL[0, pl.ds(s * R, R)] = jnp.where(
                            valid, mu - rad, _NEG)[0]
                        return 0
                    jax.lax.fori_loop(0, keep, fill, 0)
                    minlb[0] = jnp.float32(jnp.inf)

                    def take(j, _):  # top-k_cert rows by mean, as extract
                        sc = bufM[:]
                        m = jnp.max(jnp.where(jnp.isnan(sc), _NEG, sc))
                        arg = jnp.min(jnp.where(sc == m, colid_c, Pc))
                        minlb[0] = jnp.minimum(minlb[0], bufL[0, arg])
                        bufU[0, arg] = _NEG
                        bufM[0, arg] = _NAN     # distinct rows, as extract
                        return 0
                    jax.lax.fori_loop(0, k_cert, take, 0)

                    @pl.when(minlb[0] >= jnp.max(bufU[:]))
                    def _fire():
                        active[0] = 0
                        tstop[0] = rmeta_ref[r, 0]
                        rused_ref[0, 0] = r + 1

            rnd[0] = r + 1

        # prefetch the next step's tile (post-elimination survivor indices)
        @pl.when(i < S - 1)
        def _warm():
            ncode = code_ref[i + 1]
            npull = (ncode & PULL_BIT) != 0
            if adaptive:      # frozen queries prefetch nothing
                npull = jnp.logical_and(npull, active[0] == 1)

            @pl.when(npull)
            def _():
                ntile = surv[ncode & SLOT_MASK]
                ncol = cols_ref[b, i + 1] if batched else cols_ref[i + 1]
                pltpu.make_async_copy(V_ref.at[ntile, ncol],
                                      vbuf.at[1 - dslot],
                                      sem.at[1 - dslot]).start()

        if batched:
            @pl.when(jnp.logical_and(i == S - 1, b < B - 1))
            def _warm_next_query():  # next query restarts on identity slots
                ncode = code_ref[0]

                @pl.when((ncode & PULL_BIT) != 0)
                def _():
                    pltpu.make_async_copy(
                        V_ref.at[ncode & SLOT_MASK, cols_ref[b + 1, 0]],
                        vbuf.at[0], sem.at[0]).start()

        @pl.when(i == S - 1)
        def _finalize():
            if adaptive:   # normalize by the query's ACTUAL pull count
                denom_f = (jnp.maximum(tstop[0], 1) * C).astype(jnp.float32)
            else:
                denom_f = denom_final

            def score_body(s, _):
                tile = surv[s]
                means = acc[pl.ds(tile, 1), :] / denom_f        # (1, R)
                rowids = tile * R + jax.lax.broadcasted_iota(
                    jnp.int32, (1, R), 1)
                scorebuf[0, pl.ds(s * R, R)] = jnp.where(
                    rowids < nv_ref[0], means, _NEG)[0]
                return 0
            jax.lax.fori_loop(0, n_final, score_body, 0)
            scorebuf[:] = jnp.where(colid < n_final * R, scorebuf[:], _NEG)

            def extract(j, _):
                sc = scorebuf[:]
                m = jnp.max(jnp.where(jnp.isnan(sc), _NEG, sc))
                arg = jnp.min(jnp.where(sc == m, colid, Pw))
                s_idx = arg // R
                ids_ref[0, j] = surv[s_idx] * R + (arg - s_idx * R)
                vals_ref[0, j] = m
                scorebuf[0, arg] = _NAN     # distinct candidates, see above
                return 0
            jax.lax.fori_loop(0, K, extract, 0)

    return kernel


def _scratch(n_tiles, R, C, Pw, vdtype, *, adaptive=False, track_var=False,
             Pc=0):
    base = [
        pltpu.VMEM((n_tiles, R), jnp.float32),   # accumulator, all rounds
        pltpu.VMEM((2, R, C), vdtype),           # double-buffered tile DMA
        pltpu.SMEM((n_tiles,), jnp.int32),       # survivor tile ids
        pltpu.SMEM((n_tiles,), jnp.int32),       # elimination staging
        pltpu.VMEM((1, Pw), jnp.float32),        # score workspace
        pltpu.SMEM((1,), jnp.int32),             # round cursor
    ]
    if track_var:
        # running M2 accumulator feeding the 'bernstein' radii — inserted
        # BEFORE the adaptive lanes so the kernel's unpack order holds
        base.insert(1, pltpu.VMEM((n_tiles, R), jnp.float32))
    if adaptive:
        base += [
            pltpu.SMEM((1,), jnp.int32),         # active lane
            pltpu.SMEM((1,), jnp.int32),         # t_stop (actual pulls)
            pltpu.SMEM((1,), jnp.float32),       # min lower bound
            pltpu.VMEM((1, Pc), jnp.float32),    # cert means workspace
            pltpu.VMEM((1, Pc), jnp.float32),    # cert upper bounds
            pltpu.VMEM((1, Pc), jnp.float32),    # cert lower bounds
        ]
    return base + [pltpu.SemaphoreType.DMA((2,))]


def _resolve_qkind(Cs, vscale, qscale, codebook, packed_int4):
    """Classify the tier from the wrapper operands; returns (qkind, C).

    ``Cs`` is the stored operand's last dim; ``C`` the true block width
    the kernel's denominators use — 2*Cs for nibble-packed int4,
    S*subdims from the codebook shape for pq, Cs otherwise.
    """
    if codebook is not None:
        if vscale is not None or qscale is not None or packed_int4:
            raise ValueError("codebook (pq) excludes vscale/qscale/"
                             "packed_int4")
        return "pq", codebook.shape[1] * codebook.shape[3]
    if (vscale is not None) != (qscale is not None):
        raise ValueError("vscale and qscale must be passed together")
    if packed_int4:
        if vscale is None:
            raise ValueError("packed_int4 needs vscale/qscale (W4A8)")
        return "int4", 2 * Cs
    return ("int8" if vscale is not None else "none"), Cs


@functools.partial(jax.jit, static_argnames=("n_arms", "K", "t_final",
                                             "n_final", "k_out", "k_cert",
                                             "track_var", "packed_int4",
                                             "interpret"))
def fused_cascade_pallas(V4, qb, slotcode, rounds_meta, cols, *, n_arms: int,
                         K: int, t_final: int, n_final: int,
                         k_out: int = None, n_valid=None,
                         vscale=None, qscale=None, codebook=None,
                         packed_int4: bool = False, cert=None,
                         k_cert: int = 1, track_var: bool = False,
                         interpret: bool = False):
    """Single-query fused cascade: ONE pallas_call for all rounds.

    V4:  (n_tiles, n_blocks, R, C) tile-major data (stays in HBM);
    float for the fp32 path, int8 for the quantized path, nibble-packed
    int8 (last dim C/2) with ``packed_int4=True``, uint8 codes (last dim
    S) with ``codebook``.
    qb:  (n_blocks, C) blocked query (VMEM-resident) — f32 on the fp32
    AND pq paths, int8 on the int8/int4 (W4A8) paths.
    slotcode/rounds_meta/cols: see `FlatSchedule.packed`
    k_out: number of final candidates extracted in-kernel (default K).
    Shard-local callers ask for k_out > K so the K winners come back with a
    threshold candidate for bound-gap computation; the extra extraction
    iterations reuse the same scorebuf, so K only sizes the schedule while
    k_out sizes the output.  Must satisfy ``K <= k_out <= n_final * R``.
    n_valid: rows >= n_valid never win a ranking (default ``n_arms``);
    accepts a traced scalar, so shards can mask their own slice of a
    caller-padded table in-cascade (DESIGN.md §7).
    vscale/qscale: per-tile table scales (n_tiles, n_blocks) and per-block
    query scales (n_blocks,) for int8/int4 operands (`repro.core.quantize`,
    DESIGN.md §10); both or neither must be given.
    codebook: (n_blocks, S, n_codes, subdims) f32 pq codebook
    (`repro.core.quantize.pq_train`), fully VMEM-resident; excludes
    vscale/qscale/packed_int4.
    cert: (rounds+1, 2) f32 per-round certification coefficients
    (`repro.core.schedule.cert_coeffs`) — enables adaptive early exit
    (DESIGN.md §12); ``k_cert`` is the contract top-K the predicate
    certifies and ``track_var`` carries the running M2 accumulator the
    'bernstein' radii read.
    Returns (ids (k_out,) int32, vals (k_out,) f32) — vals are unscaled
    block means, identical to the unfused path before its padding rescale.
    With ``cert`` a third output ``rounds_used`` (int32 scalar) reports
    how many elimination rounds actually pulled before certification.
    """
    n_tiles, n_blocks, R, Cs = V4.shape
    qkind, C = _resolve_qkind(Cs, vscale, qscale, codebook, packed_int4)
    adaptive = cert is not None
    if k_out is None:
        k_out = K
    K = k_out          # K's only kernel role is the extraction/output width
    if n_valid is None:
        n_valid = n_arms
    S = slotcode.shape[0]
    n_rounds = rounds_meta.shape[0] - 1
    Pw = _round_up(max(n_tiles, n_final * R, 1), 128)
    Pc = _round_up(n_tiles * R, 128) if adaptive else 0
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),     # V4: manual tile DMA
        pl.BlockSpec(memory_space=pltpu.VMEM),    # qb: fully resident
    ]
    operands = [V4, qb]
    if qkind in ("int8", "int4"):
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.VMEM),    # vscale
            pl.BlockSpec(memory_space=pltpu.VMEM),    # qscale (1, n_blocks)
        ]
        operands += [jnp.asarray(vscale, jnp.float32),
                     jnp.asarray(qscale, jnp.float32).reshape(1, n_blocks)]
    elif qkind == "pq":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))  # codebook
        operands.append(jnp.asarray(codebook, jnp.float32))
    out_specs = [
        pl.BlockSpec((1, K), lambda i, *_: (0, 0)),
        pl.BlockSpec((1, K), lambda i, *_: (0, 0)),
    ]
    out_shape = [jax.ShapeDtypeStruct((1, K), jnp.int32),
                 jax.ShapeDtypeStruct((1, K), jnp.float32)]
    if adaptive:
        out_specs.append(pl.BlockSpec((1, 1), lambda i, *_: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
    scalars = [slotcode.astype(jnp.int32), rounds_meta.astype(jnp.int32)]
    if adaptive:
        scalars.append(jnp.asarray(cert, jnp.float32))
    scalars += [cols.astype(jnp.int32),
                jnp.asarray(n_valid, jnp.int32).reshape(1)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(S,),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        scratch_shapes=_scratch(n_tiles, R, Cs, Pw, V4.dtype,
                                adaptive=adaptive, track_var=track_var,
                                Pc=Pc),
    )
    kernel = _make_kernel(n_arms=n_arms, R=R, C=C, K=K, n_tiles=n_tiles,
                          t_final=t_final, n_final=n_final, S=S, Pw=Pw,
                          B=None, qkind=qkind, adaptive=adaptive,
                          track_var=track_var, k_cert=k_cert,
                          n_rounds=n_rounds, Pc=Pc)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*scalars, *operands)
    if adaptive:
        ids, vals, rused = out
        return ids[0], vals[0], rused[0, 0]
    ids, vals = out
    return ids[0], vals[0]


@functools.partial(jax.jit, static_argnames=("n_arms", "K", "t_final",
                                             "n_final", "k_out", "k_cert",
                                             "track_var", "packed_int4",
                                             "interpret"))
def fused_cascade_batched_pallas(V4, Qb, slotcode, rounds_meta, cols, *,
                                 n_arms: int, K: int, t_final: int,
                                 n_final: int, k_out: int = None,
                                 n_valid=None, vscale=None, qscale=None,
                                 codebook=None, packed_int4: bool = False,
                                 cert=None, k_cert: int = 1,
                                 track_var: bool = False,
                                 interpret: bool = False):
    """Batched fused cascade: the query axis rides in the grid.

    Qb: (B, n_blocks, C) blocked queries; cols: (B, S) per-query pull
    columns.  One dispatch serves the whole decode batch; per-query state is
    re-initialized at each query's first grid step.  ``k_out`` (default K)
    widens the in-kernel final extraction and ``n_valid`` (default
    ``n_arms``, may be traced) masks caller-padding rows exactly as in
    `fused_cascade_pallas`.  For int8 operands pass ``vscale`` (n_tiles,
    n_blocks) and per-query ``qscale`` (B, n_blocks) (DESIGN.md §10); for
    nibble-packed int4 tiles additionally set ``packed_int4=True``; for
    product-quantized tiles pass ``codebook`` instead (uint8 code table,
    f32 queries) — tiers resolve exactly as in `fused_cascade_pallas`.
    ``cert``/``k_cert``/``track_var`` enable per-query adaptive early exit
    exactly as in `fused_cascade_pallas` — each query carries its own
    ``active`` lane, so one certified query's no-op steps never disturb
    its batchmates.
    Returns (ids (B, k_out) int32, vals (B, k_out) f32), unscaled; with
    ``cert`` also ``rounds_used (B,) int32``.
    """
    n_tiles, n_blocks, R, Cs = V4.shape
    qkind, C = _resolve_qkind(Cs, vscale, qscale, codebook, packed_int4)
    adaptive = cert is not None
    if k_out is None:
        k_out = K
    K = k_out
    if n_valid is None:
        n_valid = n_arms
    B, S = cols.shape
    n_rounds = rounds_meta.shape[0] - 1
    Pw = _round_up(max(n_tiles, n_final * R, 1), 128)
    Pc = _round_up(n_tiles * R, 128) if adaptive else 0
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec((1, n_blocks, C), lambda b, i, *_: (b, 0, 0)),
    ]
    operands = [V4, Qb]
    if qkind in ("int8", "int4"):
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.VMEM),                # vscale
            pl.BlockSpec((1, n_blocks), lambda b, i, *_: (b, 0)),  # qscale
        ]
        operands += [jnp.asarray(vscale, jnp.float32),
                     jnp.asarray(qscale, jnp.float32)]
    elif qkind == "pq":
        in_specs.append(pl.BlockSpec(memory_space=pltpu.VMEM))  # codebook
        operands.append(jnp.asarray(codebook, jnp.float32))
    out_specs = [
        pl.BlockSpec((1, K), lambda b, i, *_: (b, 0)),
        pl.BlockSpec((1, K), lambda b, i, *_: (b, 0)),
    ]
    out_shape = [jax.ShapeDtypeStruct((B, K), jnp.int32),
                 jax.ShapeDtypeStruct((B, K), jnp.float32)]
    if adaptive:
        out_specs.append(pl.BlockSpec((1, 1), lambda b, i, *_: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.int32))
    scalars = [slotcode.astype(jnp.int32), rounds_meta.astype(jnp.int32)]
    if adaptive:
        scalars.append(jnp.asarray(cert, jnp.float32))
    scalars += [cols.astype(jnp.int32),
                jnp.asarray(n_valid, jnp.int32).reshape(1)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalars),
        grid=(B, S),
        in_specs=in_specs,
        out_specs=tuple(out_specs),
        scratch_shapes=_scratch(n_tiles, R, Cs, Pw, V4.dtype,
                                adaptive=adaptive, track_var=track_var,
                                Pc=Pc),
    )
    kernel = _make_kernel(n_arms=n_arms, R=R, C=C, K=K, n_tiles=n_tiles,
                          t_final=t_final, n_final=n_final, S=S, Pw=Pw, B=B,
                          qkind=qkind, adaptive=adaptive,
                          track_var=track_var, k_cert=k_cert,
                          n_rounds=n_rounds, Pc=Pc)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(*scalars, *operands)
    if adaptive:
        ids, vals, rused = out
        return ids, vals, rused[:, 0]
    return out
