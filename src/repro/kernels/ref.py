"""Pure oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["gather_block_dot_ref", "blocked_matvec_ref", "fused_cascade_ref"]


def gather_block_dot_ref(V4: jnp.ndarray, idx: jnp.ndarray,
                         cols: jnp.ndarray, qsel: jnp.ndarray) -> jnp.ndarray:
    """Partial inner products for surviving arm tiles over selected blocks.

    V4:   (n_tiles, n_blocks, R, C) tile-major data
    idx:  (T,)  surviving tile ids
    cols: (dt,) coordinate-block ids to pull this round
    qsel: (dt, C) the query restricted to those blocks
    out:  (T, R) float32 partial sums  sum_b  V4[idx_t, cols_b] @ qsel_b
    """
    Vsel = V4[idx[:, None], cols[None, :]]        # (T, dt, R, C)
    return jnp.einsum("tbrc,bc->tr", Vsel, qsel,
                      preferred_element_type=jnp.float32)


def blocked_matvec_ref(W: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact logit matvec oracle: (n, d) @ (d,) -> (n,) in float32."""
    return jnp.dot(W, q, preferred_element_type=jnp.float32)


def fused_cascade_ref(V4, qb, flat, cols, *, n_arms: int, K: int,
                      vscale=None, qscale=None):
    """Step-accurate numpy simulation of the fused cascade kernel.

    Walks the same FlatSchedule the kernel prefetches, one grid step at a
    time: pull -> accumulate, eliminate at round-end flags (tile-max means,
    iterative max-extraction with lowest-index tie-break), final top-K over
    the surviving arms.  Slow and deliberately naive — the point is that it
    shares no code with either the kernel or the `lax.scan` fallback.

    V4: (n_tiles, n_blocks, R, C); qb: (n_blocks, C); flat: FlatSchedule;
    cols: (S,) column-block id per step (i.e. perm[flat.bpos]).
    With ``vscale (n_tiles, n_blocks)`` / ``qscale (n_blocks,)`` the
    operands are int8 and each pull is an exact integer dot dequantized by
    the scalar scale product (the quantized path, DESIGN.md §10).
    Returns (ids (K,), vals (K,)) — vals unscaled, like the kernel.
    """
    quantized = vscale is not None
    if quantized:
        V4 = np.asarray(V4, np.int32)   # exact integer tile-dots
        qb = np.asarray(qb, np.int32)
        vscale = np.asarray(vscale, np.float32)
        qscale = np.asarray(qscale, np.float32)
    else:
        V4 = np.asarray(V4, np.float32)
        qb = np.asarray(qb, np.float32)
    cols = np.asarray(cols)
    n_tiles, n_blocks, R, C = V4.shape
    acc = np.zeros((n_tiles, R), np.float32)
    surv = np.arange(n_tiles)

    def masked_means(tile, denom):
        rowids = tile * R + np.arange(R)
        return np.where(rowids < n_arms, acc[tile] / denom, -np.inf)

    for i in range(flat.n_steps):
        if flat.is_pull[i]:
            tile = surv[flat.slot[i]]
            col = int(cols[i])
            if quantized:
                raw = V4[tile, col] @ qb[col]               # exact int32
                s = np.float32(vscale[tile, col]) * np.float32(qscale[col])
                acc[tile] = acc[tile] + raw.astype(np.float32) * s
            else:
                acc[tile] = acc[tile] + V4[tile, col] @ qb[col]
        if flat.is_end[i]:
            T, keep = int(flat.n_surv[i]), int(flat.n_keep[i])
            denom = np.float32(int(flat.t_cum[i]) * C)
            scores = np.array([masked_means(surv[s], denom).max()
                               for s in range(T)], np.float32)
            new = []
            for _ in range(keep):
                a = int(np.argmax(scores))      # first max == lowest index
                new.append(surv[a])
                scores[a] = -np.inf
            surv = np.asarray(new)

    denom = np.float32(max(1, flat.t_final) * C)
    flat_scores = np.concatenate([masked_means(surv[s], denom)
                                  for s in range(flat.n_final)])
    ids, vals = [], []
    for _ in range(K):
        a = int(np.argmax(flat_scores))
        s, r = divmod(a, R)
        ids.append(surv[s] * R + r)
        vals.append(flat_scores[a])
        flat_scores[a] = -np.inf
    return np.asarray(ids, np.int32), np.asarray(vals, np.float32)
