"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gather_block_dot_ref", "blocked_matvec_ref"]


def gather_block_dot_ref(V4: jnp.ndarray, idx: jnp.ndarray,
                         cols: jnp.ndarray, qsel: jnp.ndarray) -> jnp.ndarray:
    """Partial inner products for surviving arm tiles over selected blocks.

    V4:   (n_tiles, n_blocks, R, C) tile-major data
    idx:  (T,)  surviving tile ids
    cols: (dt,) coordinate-block ids to pull this round
    qsel: (dt, C) the query restricted to those blocks
    out:  (T, R) float32 partial sums  sum_b  V4[idx_t, cols_b] @ qsel_b
    """
    Vsel = V4[idx[:, None], cols[None, :]]        # (T, dt, R, C)
    return jnp.einsum("tbrc,bc->tr", Vsel, qsel,
                      preferred_element_type=jnp.float32)


def blocked_matvec_ref(W: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact logit matvec oracle: (n, d) @ (d,) -> (n,) in float32."""
    return jnp.dot(W, q, preferred_element_type=jnp.float32)
