"""Pure oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["gather_block_dot_ref", "blocked_matvec_ref", "fused_cascade_ref"]


def gather_block_dot_ref(V4: jnp.ndarray, idx: jnp.ndarray,
                         cols: jnp.ndarray, qsel: jnp.ndarray) -> jnp.ndarray:
    """Partial inner products for surviving arm tiles over selected blocks.

    V4:   (n_tiles, n_blocks, R, C) tile-major data
    idx:  (T,)  surviving tile ids
    cols: (dt,) coordinate-block ids to pull this round
    qsel: (dt, C) the query restricted to those blocks
    out:  (T, R) float32 partial sums  sum_b  V4[idx_t, cols_b] @ qsel_b
    """
    Vsel = V4[idx[:, None], cols[None, :]]        # (T, dt, R, C)
    return jnp.einsum("tbrc,bc->tr", Vsel, qsel,
                      preferred_element_type=jnp.float32)


def blocked_matvec_ref(W: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """Exact logit matvec oracle: (n, d) @ (d,) -> (n,) in float32."""
    return jnp.dot(W, q, preferred_element_type=jnp.float32)


def fused_cascade_ref(V4, qb, flat, cols, *, n_arms: int, K: int,
                      vscale=None, qscale=None, codebook=None,
                      packed_int4=False, n_valid=None,
                      cert=None, k_cert=1):
    """Step-accurate numpy simulation of the fused cascade kernel.

    Walks the same FlatSchedule the kernel prefetches, one grid step at a
    time: pull -> accumulate, eliminate at round-end flags (tile-max means,
    iterative max-extraction with lowest-index tie-break), final top-K over
    the surviving arms.  Slow and deliberately naive — the point is that it
    shares no code with either the kernel or the `lax.scan` fallback.

    V4: (n_tiles, n_blocks, R, C); qb: (n_blocks, C); flat: FlatSchedule;
    cols: (S,) column-block id per step (i.e. perm[flat.bpos]).
    With ``vscale (n_tiles, n_blocks)`` / ``qscale (n_blocks,)`` the
    operands are int8 and each pull is an exact integer dot dequantized by
    the scalar scale product (the quantized path, DESIGN.md §10);
    ``packed_int4=True`` marks the table nibble-packed (stored last dim
    C/2, half-split layout) and the oracle unpacks it with independent
    numpy bit arithmetic before the same exact integer dot.  ``codebook``
    ((n_blocks, S, n_codes, w) f32) selects the product-quantized tier
    instead: ``V4`` holds uint8 codes (last dim S), ``qb`` stays f32, and
    each pull is an independent numpy LUT walk.
    ``n_valid`` (default ``n_arms``) masks rows at or past it out of every
    ranking, like the kernel's scalar-prefetch bound.  With ``cert``
    (the (rounds+1, 2) coefficient array of
    `repro.core.schedule.cert_coeffs`) the adaptive early exit
    (DESIGN.md §12) is simulated too — running M2 accumulator,
    per-round-end certification of the top-``k_cert`` rows, frozen pulls,
    actual-pull-count normalization — and the return grows a third
    element ``rounds_used``.
    Returns (ids (K,), vals (K,)) — vals unscaled, like the kernel.
    """
    quantized = vscale is not None
    is_pq = codebook is not None
    adaptive = cert is not None
    if is_pq:
        V4 = np.asarray(V4, np.uint8)    # per-subspace code table
        qb = np.asarray(qb, np.float32)
        codebook = np.asarray(codebook, np.float32)
    elif quantized:
        if packed_int4:
            # Independent nibble unpack (half-split layout): byte k holds
            # column k in its low nibble and column k + C/2 in its high.
            pu = np.asarray(V4).astype(np.uint8)
            lo = (pu & 0x0F).astype(np.int32)
            lo = np.where(lo >= 8, lo - 16, lo)
            hi = (pu >> 4).astype(np.int32)
            hi = np.where(hi >= 8, hi - 16, hi)
            V4 = np.concatenate([lo, hi], axis=-1)
        else:
            V4 = np.asarray(V4, np.int32)   # exact integer tile-dots
        qb = np.asarray(qb, np.int32)
        vscale = np.asarray(vscale, np.float32)
        qscale = np.asarray(qscale, np.float32)
    else:
        V4 = np.asarray(V4, np.float32)
        qb = np.asarray(qb, np.float32)
    cols = np.asarray(cols)
    n_tiles, n_blocks, R, C = V4.shape
    if is_pq:
        S, w = codebook.shape[1], codebook.shape[3]
        C = S * w                       # true pull width (denominators)
    if n_valid is None:
        n_valid = n_arms
    acc = np.zeros((n_tiles, R), np.float32)
    acc2 = np.zeros((n_tiles, R), np.float32)
    surv = np.arange(n_tiles)
    if adaptive:
        cert = np.asarray(cert, np.float32)
        n_rounds = int(np.sum(np.asarray(flat.is_end)))
        active, t_stop, rounds_used, rnd = True, flat.t_final, n_rounds, 0

    def masked_means(tile, denom):
        rowids = tile * R + np.arange(R)
        return np.where(rowids < n_valid, acc[tile] / denom, -np.inf)

    def take_max(buf):
        """Kernel-exact extraction step: max over non-extracted entries,
        lowest-index tie-break; extracted slots are NaN so they can never
        tie again (lax.top_k's distinct-index semantics)."""
        m = np.max(np.where(np.isnan(buf), -np.inf, buf))
        a = int(np.argmax(buf == m))
        return a, np.float32(m)

    for i in range(flat.n_steps):
        if flat.is_pull[i] and (not adaptive or active):
            tile = surv[flat.slot[i]]
            col = int(cols[i])
            if is_pq:
                cb = codebook[col]                          # (S, n_codes, w)
                lut = (qb[col].reshape(S, 1, w) * cb).sum(-1)
                codes = V4[tile, col]                       # (R, S) uint8
                part = np.stack([
                    lut[np.arange(S), codes[r]].sum()
                    for r in range(R)]).astype(np.float32)
            elif quantized:
                raw = V4[tile, col] @ qb[col]               # exact int32
                s = np.float32(vscale[tile, col]) * np.float32(qscale[col])
                part = raw.astype(np.float32) * s
            else:
                part = V4[tile, col] @ qb[col]
            acc[tile] = acc[tile] + part
            acc2[tile] = acc2[tile] + part * part
        if flat.is_end[i]:
            T, keep = int(flat.n_surv[i]), int(flat.n_keep[i])
            denom = np.float32(int(flat.t_cum[i]) * C)
            scores = np.array([masked_means(surv[s], denom).max()
                               for s in range(T)], np.float32)
            new = []
            for _ in range(keep):
                a, _m = take_max(scores)        # first max == lowest index
                new.append(surv[a])
                scores[a] = np.nan
            surv = np.asarray(new)
            if adaptive and active:
                a_l, b_l = np.float32(cert[rnd, 0]), np.float32(cert[rnd, 1])
                denomC = np.float32(denom * np.float32(C))
                bufM, bufU, bufL = [], [], []
                for s in range(keep):
                    tile = surv[s]
                    mu = (acc[tile] / denom).astype(np.float32)
                    if a_l != 0.0:
                        v = (acc2[tile] / denomC - mu * mu).astype(
                            np.float32)
                        rad = a_l * np.sqrt(np.maximum(v, np.float32(0.0))
                                            ) + b_l
                    else:
                        rad = np.full_like(mu, b_l)
                    valid = tile * R + np.arange(R) < n_valid
                    bufM.append(np.where(valid, mu, -np.inf))
                    bufU.append(np.where(valid, mu + rad, -np.inf))
                    bufL.append(np.where(valid, mu - rad, -np.inf))
                bufM = np.concatenate(bufM).astype(np.float32)
                bufU = np.concatenate(bufU).astype(np.float32)
                bufL = np.concatenate(bufL).astype(np.float32)
                minlb = np.inf
                for _ in range(k_cert):
                    a, _m = take_max(bufM)      # lowest-index tie-break
                    minlb = min(minlb, bufL[a])
                    bufU[a] = -np.inf
                    bufM[a] = np.nan
                if minlb >= bufU.max():
                    active = False
                    t_stop = int(flat.t_cum[i])
                    rounds_used = rnd + 1
            if adaptive:
                rnd += 1

    t_fin = t_stop if adaptive else flat.t_final
    denom = np.float32(max(1, t_fin) * C)
    flat_scores = np.concatenate([masked_means(surv[s], denom)
                                  for s in range(flat.n_final)])
    ids, vals = [], []
    for _ in range(K):
        a, m = take_max(flat_scores)
        s, r = divmod(a, R)
        ids.append(surv[s] * R + r)
        vals.append(m)
        flat_scores[a] = np.nan
    out = (np.asarray(ids, np.int32), np.asarray(vals, np.float32))
    return (*out, rounds_used) if adaptive else out
