"""Pallas TPU kernels for the MIPS hot loops (+ jnp oracles in ref.py)."""
