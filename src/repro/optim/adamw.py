"""AdamW with global-norm clipping, schedules, and gradient compression.

Optimizer states are f32 regardless of param dtype (bf16 params get f32
first/second moments).  ``compress_grads`` implements bf16 compression with
an error-feedback accumulator for the cross-pod all-reduce (DESIGN.md §6):
the pod axis is the slow DCN link, so halving gradient bytes there is the
cheapest distributed-optimization win; error feedback keeps the update
unbiased over time.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt", "apply_updates",
           "cosine_schedule", "compress_grads", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    err: Any  # error-feedback accumulator (zeros when compression is off)


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def init_opt(params, moments_dtype=jnp.float32, with_err: bool = True
             ) -> OptState:
    """moments_dtype=bf16 halves optimizer HBM for >50B-param models."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moments_dtype), params)
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
           if with_err else None)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros,
                    err=err)


def compress_grads(grads, err, enabled: bool = True):
    """bf16 compression with error feedback.

    g_compressed = bf16(g + err);  err' = (g + err) - g_compressed.
    Call *before* the cross-pod all-reduce; the ICI-level reduce stays f32.
    """
    if not enabled:
        return grads, err

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        gc = g32.astype(jnp.bfloat16)
        return gc.astype(jnp.float32), g32 - gc.astype(jnp.float32)

    flat = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_err


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig,
                  ) -> Tuple[Any, OptState, dict]:
    """One AdamW step; returns (params, state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat, vhat = m / b1c, v / b2c
        m, v = m.astype(mdt), v.astype(mdt)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu, state.err), metrics
