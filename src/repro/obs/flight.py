"""Crash flight recorder: a ring buffer of structured serving events.

:class:`FlightRecorder` keeps the last ``capacity`` structured events —
admissions, displacements, quarantines, fault injections,
recalibrations, codebook refreshes, store flushes — and dumps them to a
JSON file when something goes wrong (a request terminates ``failed`` or
a store flush raises), so post-mortems of fault-injection runs no longer
require rerunning with prints.

Each event is ``{"seq", "t", "kind", ...fields}``: a monotone sequence
number (survives wraparound, so dumps show how much history was lost),
the virtual-clock timestamp (None for events without one, e.g.
store-internal flushes), the event kind, and kind-specific fields.  The
dump payload is ``{"reason", "t", "seq", "capacity", "n_recorded",
"n_dumps", "events"}``; see docs/OBSERVABILITY.md for the schema and the
kind catalog.
"""

from __future__ import annotations

import json
from collections import deque
from typing import List, Optional


class FlightRecorder:
    """Fixed-size ring of structured events with dump-to-JSON-on-failure.

    ``path`` is the default dump destination; each dump overwrites it
    (the *latest* failure context wins — post-mortems care about the
    most recent crash).  With no path configured, :meth:`dump` is a
    no-op returning None, so instrumentation can call it unconditionally.
    """

    def __init__(self, capacity: int = 256,
                 path: Optional[str] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.path = path
        self._buf: deque = deque(maxlen=self.capacity)
        self.n_recorded = 0      #: total events ever recorded
        self.n_dumps = 0         #: dumps actually written

    def record(self, kind: str, t: Optional[float] = None,
               **fields: object) -> None:
        """Append one event (evicting the oldest past ``capacity``)."""
        self.n_recorded += 1
        ev = {"seq": self.n_recorded,
              "t": None if t is None else float(t), "kind": str(kind)}
        ev.update(fields)
        self._buf.append(ev)

    def events(self) -> List[dict]:
        """The retained events, oldest first."""
        return list(self._buf)

    def dump(self, reason: str, t: Optional[float] = None,
             path: Optional[str] = None) -> Optional[str]:
        """Write the ring to ``path`` (or the configured default).

        Returns the path written, or None when no destination is
        configured.  The payload embeds ``reason`` (e.g.
        ``"request_failed"``, ``"store_flush_error"``) and the dump-time
        virtual clock ``t``.
        """
        dest = path or self.path
        if dest is None:
            return None
        payload = {
            "reason": str(reason),
            "t": None if t is None else float(t),
            "seq": self.n_recorded,
            "capacity": self.capacity,
            "n_recorded": self.n_recorded,
            "n_dumps": self.n_dumps + 1,
            "events": self.events(),
        }
        with open(dest, "w") as f:
            json.dump(payload, f, indent=1)
        self.n_dumps += 1
        return dest
