"""Unified observability layer for the serving stack (DESIGN.md §15).

Three pillars, all zero-dependency host-side code (numpy only — no jax,
no third-party clients), shared by every layer of the serving stack:

  * :mod:`repro.obs.metrics` — a typed metrics registry (`Counter`,
    `Gauge`, `Histogram` with fixed log-scale latency/pull buckets,
    labeled by ``precision`` / ``pull_mode`` / ``priority_class`` /
    ``outcome``), JSON snapshot export and Prometheus text-exposition
    rendering.  The engines', admission controller's, fault injector's
    and stores' counters all live here; their ``stats()`` dicts are
    computed *from* the registry and stay byte-compatible.
  * :mod:`repro.obs.trace` — per-request span tracing on the serving
    stack's virtual clock, exported as Chrome trace-event JSON loadable
    in Perfetto, with bounded memory via reservoir sampling over
    requests.
  * :mod:`repro.obs.flight` — a crash flight recorder: a fixed-size
    ring buffer of structured events dumped to a JSON file when a
    request terminates ``failed`` or a store flush raises.

See docs/OBSERVABILITY.md for the metric catalog, span taxonomy and
flight-recorder event schema.
"""

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (LATENCY_BUCKETS_MS, PULL_FRAC_BUCKETS,
                               PULL_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, null_registry,
                               summarize_latencies)
from repro.obs.trace import SpanTracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "null_registry",
    "summarize_latencies", "LATENCY_BUCKETS_MS", "PULL_FRAC_BUCKETS",
    "PULL_BUCKETS", "SpanTracer", "FlightRecorder",
]
