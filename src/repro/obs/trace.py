"""Per-request span tracing on the serving stack's virtual clock.

:class:`SpanTracer` records the life of each request through
``ServeRuntime`` — ``submit -> admission -> queued -> batch-assembly ->
dispatch(n) -> retry/backoff -> complete(status)`` — and exports Chrome
trace-event JSON (the ``{"traceEvents": [...]}`` object format) loadable
directly in Perfetto / ``chrome://tracing``.

Layout: everything lives in pid 1.  Thread 0 is the shared
executor/dispatch track (complete ``X`` spans per batch dispatch,
annotated with rung, eps_served, rounds_used, pull fraction and fault
injections); each sampled request gets its own thread ``TID_REQ_BASE +
rid`` carrying the request-scoped spans.  Timestamps are the virtual
clock in microseconds (floats — Chrome accepts fractional ``ts``), so a
trace of a simulated bursty stream reads in real units.

Memory is bounded two ways: per-request tracks go through reservoir
sampling (Algorithm R, deterministic seed) once more than
``max_requests`` requests have begun, and the shared dispatch track is a
ring of the last ``max_global_events`` spans.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Dict, List, Optional

import numpy as np

#: request tracks start here so tid 0 (dispatch track) stays reserved
TID_REQ_BASE = 16


class SpanTracer:
    """Bounded-memory collector of Chrome trace events (one per run).

    Timestamps are the serving stack's *virtual* clock (seconds,
    rendered as microsecond ``ts``); the export loads directly in
    Perfetto.  Per-request tracks are reservoir-sampled past
    ``max_requests`` so memory stays bounded on long streams.

    Typical wiring (done by ``ServeRuntime`` when constructed with
    ``tracer=``)::

        tr = SpanTracer(max_requests=256, seed=0)
        tr.request_begin(rid, t_submit, priority_class="default")
        tr.instant(rid, "admitted", t_submit)
        tr.span(rid, "queued", t_submit, t_dispatch)
        tr.span(rid, "serve", t_dispatch, t_done, rung=1, eps_served=0.6)
        tr.request_end(rid, t_done, "ok")
        tr.write("trace.json")
    """

    def __init__(self, max_requests: int = 512,
                 max_global_events: int = 4096, seed: int = 0) -> None:
        if max_requests < 1:
            raise ValueError("max_requests must be >= 1")
        self.max_requests = int(max_requests)
        self._rng = np.random.default_rng(seed)
        #: rid -> list of this request's events (sampled requests only)
        self._per_req: Dict[int, List[dict]] = {}
        #: reservoir slots, parallel to _per_req keys
        self._slots: List[int] = []
        #: rid -> (t_begin, args) for the enclosing request span
        self._open: Dict[int, tuple] = {}
        self._global: deque = deque(maxlen=int(max_global_events))
        self.n_seen = 0          #: requests offered to the reservoir
        self.n_dropped = 0       #: requests evicted or never sampled

    # ---- sampling -------------------------------------------------------

    def sampled(self, rid: int) -> bool:
        """True if ``rid`` currently holds a reservoir slot."""
        return rid in self._per_req

    def request_begin(self, rid: int, t: float, **args: object) -> bool:
        """Offer request ``rid`` (beginning at virtual time ``t``) to the
        reservoir.  Returns True if it was sampled; all later per-request
        calls for an unsampled rid are no-ops."""
        self.n_seen += 1
        if len(self._slots) < self.max_requests:
            self._slots.append(rid)
        else:
            j = int(self._rng.integers(0, self.n_seen))
            if j >= self.max_requests:
                self.n_dropped += 1
                return False
            evicted = self._slots[j]
            self._slots[j] = rid
            self._per_req.pop(evicted, None)
            self._open.pop(evicted, None)
            self.n_dropped += 1
        self._per_req[rid] = []
        self._open[rid] = (float(t), dict(args))
        return True

    # ---- event emission -------------------------------------------------

    def span(self, rid: int, name: str, t0: float, t1: float,
             cat: str = "request", **args: object) -> None:
        """Complete span ``[t0, t1]`` on request ``rid``'s track."""
        evs = self._per_req.get(rid)
        if evs is None:
            return
        evs.append(_complete(name, cat, TID_REQ_BASE + rid, t0, t1, args))

    def instant(self, rid: int, name: str, t: float,
                cat: str = "request", **args: object) -> None:
        """Zero-duration marker on request ``rid``'s track."""
        evs = self._per_req.get(rid)
        if evs is None:
            return
        evs.append({"ph": "i", "name": name, "cat": cat, "pid": 1,
                    "tid": TID_REQ_BASE + rid, "ts": _us(t), "s": "t",
                    "args": dict(args)})

    def request_end(self, rid: int, t: float, status: str,
                    **args: object) -> None:
        """Close request ``rid``: emits the enclosing ``request`` span
        from its begin time to ``t``, annotated with the outcome."""
        opened = self._open.pop(rid, None)
        evs = self._per_req.get(rid)
        if opened is None or evs is None:
            return
        t0, a = opened
        a.update(args, status=status)
        evs.append(_complete(f"request rid={rid}", "request",
                             TID_REQ_BASE + rid, t0, max(float(t), t0), a))

    def global_span(self, name: str, t0: float, t1: float, tid: int = 0,
                    cat: str = "dispatch", **args: object) -> None:
        """Complete span on a shared track (tid 0 = dispatch/executor)."""
        self._global.append(_complete(name, cat, tid, t0, t1, args))

    # ---- export ---------------------------------------------------------

    def export(self) -> dict:
        """The Chrome trace-event object: metadata + all retained events.

        Unclosed requests get a zero-length ``request`` span at their
        begin time so every sampled rid has an enclosing span.
        """
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
             "args": {"name": "mips-serve"}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
             "args": {"name": "dispatch"}},
        ]
        events.extend(self._global)
        for rid in sorted(self._per_req):
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": TID_REQ_BASE + rid,
                           "args": {"name": f"request {rid}"}})
            events.extend(self._per_req[rid])
            if rid in self._open:
                t0, a = self._open[rid]
                a = dict(a, status="unterminated")
                events.append(_complete(f"request rid={rid}", "request",
                                        TID_REQ_BASE + rid, t0, t0, a))
        return {
            "displayTimeUnit": "ms",
            "otherData": {"n_requests_seen": self.n_seen,
                          "n_requests_sampled": len(self._per_req),
                          "n_requests_dropped": self.n_dropped,
                          "clock": "virtual"},
            "traceEvents": events,
        }

    def write(self, path: str) -> None:
        """Serialize :meth:`export` to ``path`` as JSON."""
        with open(path, "w") as f:
            json.dump(self.export(), f, indent=1)


def _us(t: float) -> float:
    return float(t) * 1e6


def _complete(name: str, cat: str, tid: int, t0: float, t1: float,
              args: dict) -> dict:
    return {"ph": "X", "name": name, "cat": cat, "pid": 1, "tid": tid,
            "ts": _us(t0), "dur": max(_us(t1) - _us(t0), 0.0),
            "args": dict(args)}
