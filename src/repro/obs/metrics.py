"""Typed metrics registry for the serving stack (DESIGN.md §15).

Three metric kinds — :class:`Counter` (monotone), :class:`Gauge`
(last-write or callback-backed), :class:`Histogram` (fixed buckets,
cumulative counts + sum) — held in a :class:`MetricsRegistry` keyed by
metric name.  Metrics are *labeled*: one metric object fans out into
label rows (e.g. ``serve_outcomes_total{outcome="degraded"}``), with the
serving stack's canonical label keys being ``precision`` / ``pull_mode``
/ ``priority_class`` / ``outcome`` / ``rung`` / ``trigger`` / ``kind``.

Design constraints, in order:

1. **stats() stays byte-compatible.**  Every legacy counter attribute on
   the engines, admission controller, fault injector and stores is a
   property reading a registry metric; the legacy ``stats()`` dicts are
   computed *from* the registry and pinned by
   ``tests/test_obs_regression.py`` against a pre-migration golden.
2. **Hot-path cost is a dict lookup + float add.**  Callers hold the
   metric object and pass labels as kwargs; rows are materialized once
   and then hit a tuple-keyed dict.  ``benchmarks/bench_obs.py`` pins
   the end-to-end overhead at <= 3%.
3. **Zero dependencies.**  Exports are JSON (:meth:`MetricsRegistry.snapshot`)
   and Prometheus text exposition format
   (:meth:`MetricsRegistry.render_prometheus`) — no client libraries.

Bucket layouts are fixed so runs are comparable across PRs:
``LATENCY_BUCKETS_MS`` is log-scale 0.1 ms .. 2.5 s, ``PULL_BUCKETS``
log4 64 .. 1M pulls, ``PULL_FRAC_BUCKETS`` linear-in-eighths pull
fractions (pulls / budget) used by TUNING.md to pick ``adaptive`` vs
``bound``.
"""

from __future__ import annotations

import bisect
import json
import math
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

#: log-scale latency buckets in milliseconds (upper bounds; +Inf implied).
#: 1-2.5-5 decades from 100us to 2.5s — spans a cache hit (~0.1ms) to a
#: blown 200ms deadline with a Pareto latency spike on top.
LATENCY_BUCKETS_MS: Tuple[float, ...] = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
    100.0, 250.0, 500.0, 1000.0, 2500.0)

#: log4-scale pull-count buckets (upper bounds; +Inf implied) for
#: per-query sample-complexity histograms.
PULL_BUCKETS: Tuple[float, ...] = (
    64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0, 262144.0, 1048576.0)

#: linear pull-fraction buckets (pulls used / full-scan budget).  A mass
#: near 1.0 means the cascade degenerates to brute force — see TUNING.md.
PULL_FRAC_BUCKETS: Tuple[float, ...] = (
    0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(f"invalid metric name {name!r}")
    return name


def _fmt(v: float) -> str:
    """Render a sample value the way Prometheus text format expects."""
    if v != v:                                     # NaN
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n").replace('"', '\\"')


class Metric:
    """Base class: a named, labeled family of sample rows.

    Subclasses define ``kind`` and the per-row cell shape.  Rows are
    keyed by the tuple of label *values* in declared label-key order and
    materialize on first touch, preserving insertion order (the legacy
    ``stats()`` dicts depend on first-seen ordering).
    """

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()) -> None:
        self.name = _check_name(name)
        self.help = help
        self.labels = tuple(labels)
        for lab in self.labels:
            if not _LABEL_RE.match(lab):
                raise ValueError(f"invalid label name {lab!r}")
        self._rows: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        if len(labels) != len(self.labels):
            raise ValueError(
                f"{self.name}: expected labels {self.labels}, "
                f"got {tuple(labels)}")
        try:
            return tuple(str(labels[k]) for k in self.labels)
        except KeyError as e:
            raise ValueError(
                f"{self.name}: expected labels {self.labels}, "
                f"got {tuple(labels)}") from e

    def rows(self) -> List[Tuple[Dict[str, str], object]]:
        """All materialized rows as ``(label_dict, cell)`` in first-seen
        order; gauge callbacks are resolved at call time."""
        out = []
        for key, cell in self._rows.items():
            out.append((dict(zip(self.labels, key)), self._resolve(cell)))
        return out

    def _resolve(self, cell: object) -> object:
        return cell


class Counter(Metric):
    """Monotonically increasing sum; negative increments are rejected."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        """Add ``amount`` (>= 0) to the row selected by ``labels``."""
        if amount < 0:
            raise ValueError(f"{self.name}: counter increment < 0")
        key = self._key(labels)
        self._rows[key] = self._rows.get(key, 0.0) + amount

    def seed(self, **labels: object) -> None:
        """Materialize a row at 0 without incrementing (pins row order
        and makes never-hit outcomes render explicitly as 0)."""
        self._rows.setdefault(self._key(labels), 0.0)

    def get(self, **labels: object) -> float:
        """Current value of one row (0 if the row was never touched)."""
        return float(self._rows.get(self._key(labels), 0.0))

    def total(self) -> float:
        """Sum over all label rows."""
        return float(sum(self._rows.values()))


class Gauge(Metric):
    """Last-written value, or a zero-argument callback sampled on read.

    Callback gauges (:meth:`set_fn`) let live quantities — queue depth,
    store utilization, table version — export without the owner pushing
    updates on every mutation.
    """

    kind = "gauge"

    def set(self, value: float, **labels: object) -> None:
        """Write ``value`` to the row selected by ``labels``."""
        self._rows[self._key(labels)] = float(value)

    def set_fn(self, fn: Callable[[], float], **labels: object) -> None:
        """Back the row with ``fn``, called at snapshot/render time."""
        self._rows[self._key(labels)] = fn

    def get(self, **labels: object) -> float:
        """Current value of one row (callbacks are invoked)."""
        return float(self._resolve(self._rows.get(self._key(labels), 0.0)))

    def _resolve(self, cell: object) -> float:
        return float(cell()) if callable(cell) else float(cell)


class Histogram(Metric):
    """Fixed-bucket histogram: cumulative bucket counts, sum and count.

    Buckets are upper bounds; an implicit +Inf bucket catches the tail.
    The default layout is :data:`LATENCY_BUCKETS_MS`.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS_MS) -> None:
        super().__init__(name, help, labels)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(set(bs)) or not math.isfinite(bs[-1]):
            raise ValueError(f"{name}: buckets must be finite, sorted, "
                             f"unique: {buckets!r}")
        self.buckets = bs

    def _cell(self, key: Tuple[str, ...]) -> dict:
        cell = self._rows.get(key)
        if cell is None:
            cell = {"counts": [0] * (len(self.buckets) + 1),
                    "sum": 0.0, "count": 0}
            self._rows[key] = cell
        return cell

    def observe(self, value: float, **labels: object) -> None:
        """Record one observation into the row selected by ``labels``."""
        cell = self._cell(self._key(labels))
        cell["counts"][bisect.bisect_left(self.buckets, float(value))] += 1
        cell["sum"] += float(value)
        cell["count"] += 1

    def get(self, **labels: object) -> dict:
        """One row's cell: ``{"counts", "sum", "count"}`` (counts are
        per-bucket, not cumulative; +Inf bucket last)."""
        cell = self._cell(self._key(labels))
        return {"counts": list(cell["counts"]),
                "sum": float(cell["sum"]), "count": int(cell["count"])}

    def sum(self) -> float:
        """Sum of observed values over all label rows."""
        return float(sum(c["sum"] for c in self._rows.values()))

    def count(self) -> int:
        """Number of observations over all label rows."""
        return int(sum(c["count"] for c in self._rows.values()))


class MetricsRegistry:
    """Name-keyed collection of metrics with get-or-create semantics.

    Components deep in the stack (stores, the fault injector) create
    their own private registry; composite owners (``MIPSServeEngine``,
    ``ServeRuntime``) :meth:`adopt` those so one :meth:`snapshot` /
    :meth:`render_prometheus` call exports the whole stack.  Get-or-create
    (:meth:`counter` / :meth:`gauge` / :meth:`histogram`) lets the four
    degradation-ladder executors share one labeled metric family instead
    of colliding on registration.
    """

    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterable[Metric]:
        return iter(self._metrics.values())

    def get(self, name: str) -> Optional[Metric]:
        """The metric registered under ``name``, or None."""
        return self._metrics.get(name)

    def _get_or_create(self, cls: type, name: str, help: str,
                       labels: Sequence[str], **kw: object) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, labels, **kw)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls) or m.labels != tuple(labels):
            raise ValueError(
                f"metric {name!r} re-registered with kind/labels "
                f"({cls.__name__}, {tuple(labels)}) != "
                f"({type(m).__name__}, {m.labels})")
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        """Get or create a :class:`Counter`."""
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        """Get or create a :class:`Gauge`."""
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_MS
                  ) -> Histogram:
        """Get or create a :class:`Histogram` (bucket layout must match
        on reuse)."""
        h = self._get_or_create(Histogram, name, help, labels,
                                buckets=buckets)
        if h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"metric {name!r} re-registered with "
                             f"different buckets")
        return h

    def adopt(self, other: "MetricsRegistry") -> None:
        """Merge ``other``'s metrics into this registry by reference.

        Name collisions must agree on kind and labels; the colliding
        family is then shared (both owners increment the same rows).
        Adopting a registry twice is a no-op.
        """
        if other is self:
            return
        for name, m in other._metrics.items():
            mine = self._metrics.get(name)
            if mine is None:
                self._metrics[name] = m
            elif mine is not m:
                raise ValueError(
                    f"adopt(): metric {name!r} exists in both registries "
                    f"as distinct objects")

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values a label key has taken on one metric family.

        First-seen row order, empty when the metric is absent or the
        family has no such label key.  The tenancy layer uses this to
        assert per-tenant coverage of its labeled families (e.g. every
        registered tenant appears in ``tenancy_serve_requests_total``)
        without parsing an exposition dump.
        """
        m = self._metrics.get(name)
        if m is None or label not in m.labels:
            return []
        seen: List[str] = []
        for labels, _ in m.rows():
            v = labels[label]
            if v not in seen:
                seen.append(v)
        return seen

    # ---- export ---------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-serializable snapshot of every metric and row.

        Shape: ``{"metrics": [{"name", "kind", "help", "labels",
        "buckets"?, "values": [{"labels": {...}, "value" | "counts"/
        "sum"/"count"}]}]}`` in registration/row insertion order.
        """
        out = []
        for m in self._metrics.values():
            entry: dict = {"name": m.name, "kind": m.kind, "help": m.help,
                           "labels": list(m.labels)}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
            vals = []
            for labels, cell in m.rows():
                row: dict = {"labels": labels}
                if isinstance(m, Histogram):
                    row.update(counts=list(cell["counts"]),
                               sum=cell["sum"], count=cell["count"])
                else:
                    row["value"] = cell
                vals.append(row)
            entry["values"] = vals
            out.append(entry)
        return {"metrics": out}

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4) for every metric.

        Histograms render cumulative ``_bucket{le=...}`` rows plus
        ``_sum`` / ``_count``; rows appear in insertion order.
        """
        lines: List[str] = []
        for m in self._metrics.values():
            lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for labels, cell in m.rows():
                if isinstance(m, Histogram):
                    cum = 0
                    for ub, c in zip(list(m.buckets) + [float("inf")],
                                     cell["counts"]):
                        cum += c
                        lab = dict(labels)
                        lab["le"] = _fmt(ub)
                        lines.append(f"{m.name}_bucket{_labelstr(lab)} "
                                     f"{cum}")
                    lines.append(f"{m.name}_sum{_labelstr(labels)} "
                                 f"{_fmt(cell['sum'])}")
                    lines.append(f"{m.name}_count{_labelstr(labels)} "
                                 f"{cell['count']}")
                else:
                    lines.append(
                        f"{m.name}{_labelstr(labels)} {_fmt(cell)}")
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        """Write the snapshot to ``path``: Prometheus text if the path
        ends in ``.prom`` / ``.txt``, JSON otherwise."""
        if path.endswith((".prom", ".txt")):
            payload = self.render_prometheus()
        else:
            payload = json.dumps(self.snapshot(), indent=1)
        with open(path, "w") as f:
            f.write(payload)


def _labelstr(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in labels.items())
    return "{" + inner + "}"


class _NullMetric:
    """Accepts the full Counter/Gauge/Histogram API and drops everything.

    ``get``/``total``/``sum``/``count`` read back zeros, so legacy
    property-backed counters report 0 instead of raising — the hard-off
    switch used by ``benchmarks/bench_obs.py`` to measure the
    observability-off baseline.
    """

    kind = "null"
    name = "null"
    help = ""
    labels: Tuple[str, ...] = ()
    buckets: Tuple[float, ...] = ()

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        """No-op."""

    def seed(self, **labels: object) -> None:
        """No-op."""

    def set(self, value: float, **labels: object) -> None:
        """No-op."""

    def set_fn(self, fn: Callable[[], float], **labels: object) -> None:
        """No-op (the callback is never invoked)."""

    def observe(self, value: float, **labels: object) -> None:
        """No-op."""

    def get(self, **labels: object) -> float:
        """Always 0 (histogram rows read as an empty cell via sum/count)."""
        return 0.0

    def total(self) -> float:
        """Always 0."""
        return 0.0

    def sum(self) -> float:
        """Always 0."""
        return 0.0

    def count(self) -> int:
        """Always 0."""
        return 0

    def rows(self) -> list:
        """Always empty."""
        return []


class NullRegistry(MetricsRegistry):
    """A registry whose metrics are all shared no-op stubs.

    Pass ``metrics=null_registry()`` to an engine/runtime to disable
    metric collection entirely (legacy counter properties read 0, legacy
    list-backed latency stats still work).  Used to measure the
    observability-off baseline in ``benchmarks/bench_obs.py``.
    """

    _NULL = _NullMetric()

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        """The shared no-op stub."""
        return self._NULL  # type: ignore[return-value]

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        """The shared no-op stub."""
        return self._NULL  # type: ignore[return-value]

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = LATENCY_BUCKETS_MS
                  ) -> Histogram:
        """The shared no-op stub."""
        return self._NULL  # type: ignore[return-value]

    def adopt(self, other: MetricsRegistry) -> None:
        """No-op: adopted components keep their own registries."""

    def snapshot(self) -> dict:
        """Always empty."""
        return {"metrics": []}


def null_registry() -> NullRegistry:
    """A fresh no-op registry (the observability hard-off switch)."""
    return NullRegistry()


def summarize_latencies(lat_s: Sequence[float],
                        keys: Sequence[str] = ("mean", "p50", "p95",
                                               "p99", "max")) -> dict:
    """Latency summary in milliseconds from per-request seconds.

    The single percentile helper for the whole repo (deduplicates the
    engine/runtime/benchmark copies).  Semantics pinned by
    ``tests/test_obs.py``: percentiles are ``np.percentile`` with linear
    interpolation over ``lat_s * 1e3``; an empty input yields all-zero
    entries.  ``keys`` selects and orders the output (the micro-batching
    engine's legacy surface is ``("mean", "p50", "p95", "max")``).
    """
    known = ("mean", "p50", "p95", "p99", "max")
    bad = [k for k in keys if k not in known]
    if bad:
        raise ValueError(f"unknown latency summary keys {bad!r}")
    if len(lat_s) == 0:
        full = {k: 0.0 for k in known}
    else:
        lat = np.asarray(lat_s, dtype=np.float64) * 1e3
        full = {
            "mean": float(lat.mean()),
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "p99": float(np.percentile(lat, 99)),
            "max": float(lat.max()),
        }
    return {k: full[k] for k in keys}
