"""Model building blocks: norms, RoPE, GQA attention, MLP, MoE, Mamba2 SSD.

Pure functional JAX.  Params are plain dicts of arrays (stackable over the
layer axis for lax.scan).  Sharding is expressed through logical-axis
annotations (`repro.distributed.sharding.shard`) which are no-ops when no
mesh is bound — the same code runs on 1 CPU device and on the 512-chip mesh.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard

Params = Dict[str, jnp.ndarray]


# ----------------------------------------------------------------- norms ---

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layer_norm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


def norm(x: jnp.ndarray, p: Params, cfg: ArchConfig, name: str) -> jnp.ndarray:
    if cfg.norm == "ln":
        return layer_norm(x, p[f"{name}_w"], p[f"{name}_b"])
    return rms_norm(x, p[f"{name}_w"])


# ------------------------------------------------------------------ rope ---

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention ---

def _qkv(x: jnp.ndarray, p: Params, cfg: ArchConfig, prefix: str = ""
         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wv"])
    if cfg.qkv_bias:
        q, k, v = (q + p[f"{prefix}bq"], k + p[f"{prefix}bk"],
                   v + p[f"{prefix}bv"])
    q = shard(q.reshape(B, S, H, D), "batch", "seq", "heads", None)
    k = shard(k.reshape(B, S, KV, D), "batch", "seq", "kv_heads", None)
    v = shard(v.reshape(B, S, KV, D), "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool, q_offset: int = 0,
                  chunk: int = 512) -> jnp.ndarray:
    """Chunked softmax attention: full rows per q-chunk (bounded memory).

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D) with H = G*KV.  Each q-chunk
    computes complete softmax rows over all Sk keys, so no running-max
    rescaling is needed; peak memory is (B, H, chunk, Sk) per step.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    kq = k.reshape(B, -1, KV, 1, D)
    vq = v.reshape(B, -1, KV, 1, D)
    Sk = k.shape[1]

    def one_chunk(qc: jnp.ndarray, start) -> jnp.ndarray:
        # qc: (B, c, H, D) -> (B, c, KV, G, D)
        c = qc.shape[1]
        qg = qc.reshape(B, c, KV, G, D)
        s = jnp.einsum("bckgd,bskzd->bckgs", qg, kq,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = start + jnp.arange(c)[:, None]
            kpos = jnp.arange(Sk)[None, :]
            mask = (kpos <= qpos + q_offset)[None, :, None, None, :]
            s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bckgs,bskzd->bckgd", w.astype(v.dtype), vq,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, c, H, D).astype(q.dtype)

    if Sq <= chunk or Sq % chunk:
        # ragged query lengths (e.g. whisper's 1500-frame encoder): one chunk
        return one_chunk(q, 0)
    n_chunks = Sq // chunk

    if causal and q_offset == 0 and Sq == Sk:
        # static causal chunking: q-chunk i attends to k[: (i+1)*chunk] —
        # all slice bounds are python ints, so no masked upper-triangle MACs
        # and no S^2 `where`; only the diagonal block needs a mask.
        # Halves attention FLOPs vs full-row chunking (§Perf iteration 3).
        outs = []
        diag = jnp.tril(jnp.ones((chunk, chunk), bool))
        for i in range(n_chunks):
            qg = q[:, i * chunk:(i + 1) * chunk].reshape(B, chunk, KV, G, D)
            ctx = (i + 1) * chunk
            s = jnp.einsum("bckgd,bskzd->bckgs", qg, kq[:, :ctx],
                           preferred_element_type=jnp.float32) * scale
            s = s.at[..., i * chunk:].set(
                jnp.where(diag[None, :, None, None, :],
                          s[..., i * chunk:], -1e30))
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bckgs,bskzd->bckgd", w.astype(v.dtype),
                           vq[:, :ctx], preferred_element_type=jnp.float32)
            outs.append(o.reshape(B, chunk, H, D).astype(q.dtype))
        return jnp.concatenate(outs, axis=1)

    qs = q.reshape(B, n_chunks, chunk, H, D)
    outs = jax.lax.map(
        lambda args: one_chunk(args[0], args[1]),
        (qs.transpose(1, 0, 2, 3, 4),
         jnp.arange(n_chunks) * chunk))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


def attention(x: jnp.ndarray, p: Params, cfg: ArchConfig, *,
              positions: jnp.ndarray, causal: bool = True,
              cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_len: Optional[int] = None, pos=None,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              prefix: str = "", rope_on: bool = True,
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """GQA attention for train / prefill / decode.

    * train:    cache=None, cache_len=None           -> (y, None)
    * prefill:  cache_len=S_max                      -> (y, new cache)
    * decode:   cache={'k','v'} + pos (scalar)       -> (y, updated cache)
    * cross-attention: kv_override=(k, v) from the encoder (no cache).
    """
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    if kv_override is not None:
        q = jnp.einsum("bsd,dh->bsh", x, p[f"{prefix}wq"]).reshape(B, S, H, D)
        k, v = kv_override
        o = _sdpa_chunked(q, k, v, causal=False)
        y = jnp.einsum("bshd,hdf->bsf", o, p[f"{prefix}wo"].reshape(H, D, -1))
        return y.astype(x.dtype), None

    q, k, v = _qkv(x, p, cfg, prefix)
    if rope_on:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None and cache_len is None:            # train
        o = _sdpa_chunked(q, k, v, causal=causal)
    elif cache_len is not None:                        # prefill
        kf = jnp.zeros((B, cache_len, KV, D), k.dtype).at[:, :S].set(k)
        vf = jnp.zeros((B, cache_len, KV, D), v.dtype).at[:, :S].set(v)
        kf = shard(kf, "batch", "kvseq", "kv_heads", None)
        vf = shard(vf, "batch", "kvseq", "kv_heads", None)
        new_cache = {"k": kf, "v": vf}
        o = _sdpa_chunked(q, k, v, causal=causal)
    else:                                              # decode
        pos = jnp.asarray(pos, jnp.int32)
        kf = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        vf = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        kf = shard(kf, "batch", "kvseq", "kv_heads", None)
        vf = shard(vf, "batch", "kvseq", "kv_heads", None)
        new_cache = {"k": kf, "v": vf}
        # causal mask with offset also masks the empty tail of the cache
        o = _sdpa_chunked(q, kf, vf, causal=True, q_offset=pos)
    y = jnp.einsum("bshd,hdf->bsf", o,
                   p[f"{prefix}wo"].reshape(H, D, -1))
    y = shard(y, "batch", "seq", None)
    return y.astype(x.dtype), new_cache


# ------------------------------------------------------------------- mlp ---

def mlp(x: jnp.ndarray, p: Params, cfg: ArchConfig,
        prefix: str = "") -> jnp.ndarray:
    """SwiGLU (rms-norm archs) / GELU (ln archs, whisper-style)."""
    if cfg.norm == "ln":
        h = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}w_up"])
        h = jax.nn.gelu(h + p[f"{prefix}b_up"])
        h = shard(h, "batch", "seq", "ff")
        y = jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}w_down"])
        return (y + p[f"{prefix}b_down"]).astype(x.dtype)
    g = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}w_up"])
    h = shard(jax.nn.silu(g) * u, "batch", "seq", "ff")
    y = jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}w_down"])
    return y.astype(x.dtype)


# ------------------------------------------------------------------- moe ---

def moe_layer(x: jnp.ndarray, p: Params, cfg: ArchConfig) -> jnp.ndarray:
    """Top-k routed MoE: expert-parallel shard_map path or GSPMD fallback.

    With a bound mesh and E divisible by the model axis, uses the
    redundant-routing EP kernel (`_moe_ep_shardmap`): every model rank
    routes all of its batch shard's tokens, keeps only the assignments to
    its local E/m experts, and the partial outputs are merged with ONE bf16
    psum per layer.  This avoids the involuntary f32 dispatch-buffer
    all-reduce GSPMD emits for scatter-into-expert-sharded buffers
    (EXPERIMENTS.md §Perf iteration 2).  Otherwise falls back to the
    vmapped sort-based dispatch with sharding constraints.
    """
    from repro.distributed.sharding import current_mesh

    mesh = current_mesh()
    E = cfg.n_experts
    if (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1 and E % mesh.shape["model"] == 0):
        return _moe_ep_shardmap(x, p, cfg, mesh)
    return _moe_gspmd(x, p, cfg)


def _moe_ep_shardmap(x: jnp.ndarray, p: Params, cfg: ArchConfig,
                     mesh) -> jnp.ndarray:
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import spec_of

    E, k = cfg.n_experts, cfg.experts_per_token
    msize = mesh.shape["model"]
    E_loc = E // msize
    B, S, d = x.shape
    baxes = spec_of("batch")[0]

    def local(x_l, router, wg, wu, wd):
        B_l, S_l, _ = x_l.shape
        T = B_l * S_l
        cap = max(8, int(-(-T * k * cfg.capacity_factor // E)))
        cap = min(cap, T * k)
        xf = x_l.reshape(T, d)
        logits = (xf @ router).astype(jnp.float32)        # (T, E) tiny
        gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        m = jax.lax.axis_index("model")
        off = eidx - m * E_loc                             # (T, k)
        is_local = (off >= 0) & (off < E_loc)
        flat_e = jnp.where(is_local, off, E_loc).reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        rank = jnp.arange(T * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                    side="left")
        token = order // k
        dest = jnp.where((rank < cap) & (sorted_e < E_loc),
                         sorted_e * cap + rank, E_loc * cap)
        buf = jnp.zeros((E_loc * cap + 1, d), xf.dtype).at[dest].set(
            xf[token], mode="drop")
        be = buf[: E_loc * cap].reshape(E_loc, cap, d)
        g = jnp.einsum("ecd,edf->ecf", be, wg)
        u = jnp.einsum("ecd,edf->ecf", be, wu)
        h = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", h, wd).astype(xf.dtype)
        flat = jnp.concatenate([out.reshape(E_loc * cap, d),
                                jnp.zeros((1, d), out.dtype)])
        vals = flat[dest]                                  # (T*k, d)
        w = gates.reshape(-1)[order].astype(out.dtype)
        y_l = jnp.zeros((T, d), out.dtype).at[token].add(vals * w[:, None])
        y_l = jax.lax.psum(y_l, "model")                   # ONE bf16 psum
        return y_l.reshape(B_l, S_l, d)

    from repro.distributed.sharding import shard_map_compat
    fn = shard_map_compat(
        local, mesh=mesh,
        in_specs=(P(baxes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(baxes, None, None))
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_gspmd(x: jnp.ndarray, p: Params, cfg: ArchConfig) -> jnp.ndarray:
    """Fallback: per-batch-row sort-based capacity dispatch under GSPMD.

    Dispatch is computed independently per batch row (vmapped sort /
    searchsorted / scatter), so with batch sharded on (pod, data) the whole
    routing stage is collective-free; the buffer re-shard for the
    expert-sharded FFN einsum is left to the compiler.  Overflow beyond
    capacity is dropped (standard dropping MoE).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    cap = max(8, int(-(-S * k * cfg.capacity_factor // E)))
    cap = min(cap, S * k)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                    # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(xr, er):  # xr: (S, d); er: (S, k)
        flat_e = er.reshape(-1)                              # (S*k,)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        rank = jnp.arange(S * k) - jnp.searchsorted(sorted_e, sorted_e,
                                                    side="left")
        token = order // k
        dest = jnp.where(rank < cap, sorted_e * cap + rank, E * cap)
        buf = jnp.zeros((E * cap + 1, d), xr.dtype).at[dest].set(
            xr[token], mode="drop")
        return buf[: E * cap].reshape(E, cap, d), dest, token, order

    buf, dest, token, order = jax.vmap(dispatch_row)(
        x, eidx)                                             # (B, E, cap, d)
    buf = shard(buf, "batch", "experts", "expert_cap", None)

    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = shard(jax.nn.silu(g) * u, "batch", "experts", "expert_cap", "ff")
    out = jnp.einsum("becf,efd->becd", h, p["w_down"]).astype(x.dtype)
    out = shard(out, "batch", "experts", "expert_cap", None)

    def combine_row(out_r, dest_r, token_r, order_r, gates_r):
        flat = jnp.concatenate(
            [out_r.reshape(E * cap, d), jnp.zeros((1, d), out_r.dtype)])
        vals = flat[dest_r]                                  # (S*k, d) sorted
        w = gates_r.reshape(-1)[order_r]                     # (S*k,)
        y = jnp.zeros((S, d), out_r.dtype).at[token_r].add(
            vals * w[:, None].astype(out_r.dtype))
        return y

    y = jax.vmap(combine_row)(out, dest, token, order, gates)
    return shard(y, "batch", "seq", None)


# ---------------------------------------------------------- mamba2 (SSD) ---

def _ssd_chunk_scan(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan (Dao & Gu 2024), per-head scalar decay.

    xh: (B, S, H, P)   dt: (B, S, H)    A: (H,) (negative)
    Bm/Cm: (B, S, Sdim)                 returns (B, S, H, P)
    """
    Bsz, S, H, P = xh.shape
    Sdim = Bm.shape[-1]
    S0 = S
    if S % chunk:  # ragged tail: zero-pad (la=0, xs=0 leaves state untouched)
        padlen = chunk - S % chunk
        pad = lambda t: jnp.pad(t, ((0, 0), (0, padlen)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, Bm, Cm = pad(xh), pad(dt), pad(Bm), pad(Cm)
        S = S + padlen
    nc = S // chunk
    la = (dt * A[None, None, :]).astype(jnp.float32)         # log-decay <= 0
    xs = (xh * dt[..., None]).astype(jnp.float32)            # dt-scaled input

    def reshape_c(t):
        return t.reshape((Bsz, nc, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    la_c, xs_c = reshape_c(la), reshape_c(xs)
    B_c, C_c = reshape_c(Bm.astype(jnp.float32)), reshape_c(Cm.astype(jnp.float32))

    def chunk_step(h, inp):
        la_i, xs_i, B_i, C_i = inp          # (B,c,H) (B,c,H,P) (B,c,Sd) (B,c,Sd)
        cum = jnp.cumsum(la_i, axis=1)                        # (B,c,H)
        # intra-chunk: y[t] = sum_{s<=t} C_t.B_s x_s exp(cum_t - cum_s)
        gsb = jnp.einsum("bts,bcs->btc", C_i, B_i)            # (B,c,c)
        decay = cum[:, :, None, :] - cum[:, None, :, :]       # (B,t,s,H)
        tmask = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tmask[None, :, :, None], decay, -jnp.inf)
        w = gsb[..., None] * jnp.exp(decay)                   # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xs_i)
        # inter-chunk: y[t] += C_t . h_in * exp(cum_t)
        y_inter = jnp.einsum("bts,bhsp,bth->bthp",
                             C_i, h, jnp.exp(cum))
        # state update: h_out = exp(cum_T) h_in + sum_s exp(cum_T-cum_s) B_s x_s
        tail = jnp.exp(cum[:, -1:, :] - cum)                  # (B,c,H)
        dh = jnp.einsum("bcs,bchp,bch->bhsp", B_i, xs_i, tail)
        h = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + dh
        return h, y_intra + y_inter

    h0 = jnp.zeros((Bsz, H, Sdim, P), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_step, h0, (la_c, xs_c, B_c, C_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)[:, :S0]
    return y.astype(xh.dtype), h_fin


def mamba2_layer(x: jnp.ndarray, p: Params, cfg: ArchConfig, *,
                 cache: Optional[Dict[str, jnp.ndarray]] = None,
                 mode: str = "train",
                 ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Mamba2 SSD mixer.

    mode='train'  : chunked scan, no state returned
    mode='prefill': chunked scan, returns final state {'h': (B,H,Sd,P)}
    mode='decode' : sequential step(s) from cache['h']
    """
    B, S, d = x.shape
    di, H, P, Sd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = shard(jnp.einsum("bsd,de->bse", x, p["wx"]), "batch", "seq", "dinner")
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
        + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,) negative
    xh = xin.reshape(B, S, H, P)

    new_cache = None
    if mode in ("train", "prefill"):
        y, h_fin = _ssd_chunk_scan(xh, dt, A, Bm, Cm,
                                   min(cfg.ssm_chunk, S))
        if mode == "prefill":
            new_cache = {"h": h_fin}
    else:
        h = (cache["h"] if cache is not None and "h" in cache
             else jnp.zeros((B, H, Sd, P), jnp.float32))
        def step(h, inp):
            x_t, dt_t, B_t, C_t = inp
            decay = jnp.exp(dt_t * A)                         # (B,H)
            dx = jnp.einsum("bn,bhp,bh->bhnp", B_t, x_t, dt_t)
            h = h * decay[..., None, None] + dx
            y_t = jnp.einsum("bn,bhnp->bhp", C_t, h)
            return h, y_t
        seq = (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
               dt.transpose(1, 0, 2), Bm.transpose(1, 0, 2).astype(jnp.float32),
               Cm.transpose(1, 0, 2).astype(jnp.float32))
        h, ys = jax.lax.scan(step, h, seq)
        y = ys.transpose(1, 0, 2, 3).astype(x.dtype)
        new_cache = {"h": h}
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm_w"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return shard(out, "batch", "seq", None).astype(x.dtype), new_cache
