"""Train / prefill / decode step functions (the pjit entry points).

`decode_step` is where the paper lands in the serving stack: with
``cfg.mips_mode='boundedme'`` the greedy next-token argmax over the (large,
vocab-sharded) unembedding runs as a BoundedME bandit instead of a full
matvec + argmax — zero preprocessing, per-query (eps, delta) knob.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import current_mesh, shard
from repro.models.model import forward, logits_from_hidden
from repro.optim.adamw import (AdamWConfig, OptState, apply_updates,
                               compress_grads)

__all__ = ["loss_fn", "train_step", "prefill_step", "decode_step",
           "make_mips_plan"]


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray]):
    """Mean next-token NLL over the batch; returns (loss, metrics dict)."""
    h, _ = forward(params, cfg, batch["tokens"],
                   patch_embeds=batch.get("patch_embeds"),
                   enc_frames=batch.get("enc_frames"))
    logits = logits_from_hidden(params, cfg, h)          # (B,S,Vp) f32
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    loss = jnp.mean(nll)
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"loss": loss, "acc": acc}


def train_step(params, opt_state: OptState, batch, cfg: ArchConfig,
               opt_cfg: AdamWConfig, compress: bool = False):
    """One AdamW step (optionally int8-compressed grads); returns
    (params, opt_state, metrics)."""
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    err = opt_state.err
    if compress and err is not None:
        grads, err = compress_grads(grads, err, enabled=True)
    params, opt_state, opt_metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
    opt_state = opt_state._replace(err=err)
    metrics.update(opt_metrics)
    return params, opt_state, metrics


def prefill_step(params, cfg: ArchConfig, tokens, cache_len: int,
                 patch_embeds=None, enc_frames=None):
    """Process the prompt, return (last-position hidden, caches)."""
    h, caches = forward(params, cfg, tokens, cache_len=cache_len,
                        patch_embeds=patch_embeds, enc_frames=enc_frames)
    return h[:, -1], caches


def make_mips_plan(cfg: ArchConfig, K: int = 1):
    """Static BoundedME plan for the unembedding MIPS (trace-time).

    ``cfg.mips_precision`` selects the sampling arithmetic: 'int8' or
    'int4' run the cascade's pull rounds on quantized tiles under
    quantization-widened worst-case bounds (DESIGN.md §10), with final
    scores rescored in fp32.  'pq' is not servable from this trace-time
    helper — its measured error bound needs a table to calibrate on
    (use the serving engines or `make_measured_plan`).
    """
    return make_plan(cfg.padded_vocab, cfg.d_model, K=K, eps=cfg.mips_eps,
                     delta=cfg.mips_delta, value_range=4.0,
                     tile=8, block=min(512, cfg.d_model),
                     precision=cfg.mips_precision)


def decode_step(params, cfg: ArchConfig, caches, tokens, pos,
                key: Optional[jax.Array] = None):
    """One greedy decode step: returns (next_token (B,), new caches).

    mips_mode='exact'     -> full (d x Vp) matvec + argmax (the baseline)
    mips_mode='boundedme' -> the paper's bandit over the unembedding rows
    """
    h, new_caches = forward(params, cfg, tokens, caches=caches, pos=pos)
    hid = h[:, -1]                                        # (B, d)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if cfg.mips_mode == "boundedme":
        if key is None:
            key = jax.random.PRNGKey(0)
        # one key for the whole batch: the decode paths share a single
        # block permutation across queries (DESIGN.md §3)
        mips_key = jax.random.fold_in(key, 1)
        mesh = current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and mesh.shape["model"] > 1):
            # distributed MIPS: shard-local fused cascades + exact K-merge
            # (the GSPMD fallback involuntarily replicates the gathered
            # working set — see EXPERIMENTS.md §Perf iteration 1).  Ragged
            # vocab shards are handled by the engine (DESIGN.md §7).
            from repro.distributed.sharding import (
                sharded_bounded_me_decode, spec_of)
            baxes = spec_of("batch")[0]
            ids, _, _ = sharded_bounded_me_decode(
                table, hid.astype(table.dtype), mips_key, K=1, mesh=mesh,
                batch_axes=baxes, n_valid=cfg.vocab,
                eps=cfg.mips_eps, delta=cfg.mips_delta,
                value_range=4.0, block=min(512, cfg.d_model),
                final_exact=True, precision=cfg.mips_precision)
        else:
            # batched decode path: the whole (B,) batch is served by one
            # dispatch (one fused pallas_call on TPU; one dense-round scan
            # program otherwise) instead of a vmapped per-query cascade;
            # vocab-padding rows are masked inside the cascade
            plan = make_mips_plan(cfg, K=1)
            ids, _ = bounded_me_decode(table, hid, mips_key, plan=plan,
                                       final_exact=True, n_valid=cfg.vocab)
        next_tok = ids[:, 0]
    else:
        logits = jnp.einsum("bd,vd->bv", hid, table,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", "vocab")
        if cfg.padded_vocab != cfg.vocab:
            mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
            logits = jnp.where(mask[None], logits, -1e30)
        next_tok = jnp.argmax(logits, axis=-1)
    return next_tok.astype(jnp.int32), new_caches
