"""Model assembly: init + forward for all assigned architecture families.

Families: dense, moe, ssm (mamba2), hybrid (jamba), encdec (whisper),
vlm (internvl backbone + stubbed patch embeddings).  Homogeneous stacks are
scanned (stacked layer params) with optional remat; the hybrid family scans
over its repeating period.  The same code path serves train (no cache),
prefill (builds cache) and decode (updates cache).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.models import layers as L

Params = Dict[str, Any]


# ------------------------------------------------------------------ init ---

def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(jnp.float32(max(1, fan_in)))).astype(dtype)


def _attn_params(key, cfg: ArchConfig, n: int, prefix: str = "",
                 kv_heads: Optional[int] = None) -> Params:
    """n stacked attention layers (n==0 -> unstacked single layer)."""
    H, D = cfg.n_heads, cfg.head_dim
    KV = cfg.n_kv_heads if kv_heads is None else kv_heads
    d = cfg.d_model
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    lead = (n,) if n else ()
    p = {
        f"{prefix}wq": _dense(ks[0], lead + (d, H * D), d, dt),
        f"{prefix}wk": _dense(ks[1], lead + (d, KV * D), d, dt),
        f"{prefix}wv": _dense(ks[2], lead + (d, KV * D), d, dt),
        f"{prefix}wo": _dense(ks[3], lead + (H * D, d), H * D, dt),
    }
    if cfg.qkv_bias and not prefix:
        p[f"{prefix}bq"] = jnp.zeros(lead + (H * D,), dt)
        p[f"{prefix}bk"] = jnp.zeros(lead + (KV * D,), dt)
        p[f"{prefix}bv"] = jnp.zeros(lead + (KV * D,), dt)
    return p


def _mlp_params(key, cfg: ArchConfig, n: int, prefix: str = "") -> Params:
    d, f, dt = cfg.d_model, cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    lead = (n,) if n else ()
    if cfg.norm == "ln":
        return {
            f"{prefix}w_up": _dense(ks[0], lead + (d, f), d, dt),
            f"{prefix}b_up": jnp.zeros(lead + (f,), dt),
            f"{prefix}w_down": _dense(ks[1], lead + (f, d), f, dt),
            f"{prefix}b_down": jnp.zeros(lead + (d,), dt),
        }
    return {
        f"{prefix}w_gate": _dense(ks[0], lead + (d, f), d, dt),
        f"{prefix}w_up": _dense(ks[1], lead + (d, f), d, dt),
        f"{prefix}w_down": _dense(ks[2], lead + (f, d), f, dt),
    }


def _moe_params(key, cfg: ArchConfig, n: int) -> Params:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, _dtype(cfg)
    ks = jax.random.split(key, 4)
    lead = (n,) if n else ()
    return {
        "router": _dense(ks[0], lead + (d, E), d, jnp.float32),
        "w_gate": _dense(ks[1], lead + (E, d, f), d, dt),
        "w_up": _dense(ks[2], lead + (E, d, f), d, dt),
        "w_down": _dense(ks[3], lead + (E, f, d), f, dt),
    }


def _mamba_params(key, cfg: ArchConfig, n: int) -> Params:
    d, di, H, Sd, dt = (cfg.d_model, cfg.d_inner, cfg.ssm_heads,
                        cfg.ssm_state, _dtype(cfg))
    ks = jax.random.split(key, 7)
    lead = (n,) if n else ()
    return {
        "wz": _dense(ks[0], lead + (d, di), d, dt),
        "wx": _dense(ks[1], lead + (d, di), d, dt),
        "wB": _dense(ks[2], lead + (d, Sd), d, dt),
        "wC": _dense(ks[3], lead + (d, Sd), d, dt),
        "wdt": _dense(ks[4], lead + (d, H), d, dt),
        "dt_bias": jnp.zeros(lead + (H,), jnp.float32),
        "A_log": jnp.zeros(lead + (H,), jnp.float32),
        "D": jnp.ones(lead + (H,), jnp.float32),
        "out_proj": _dense(ks[5], lead + (di, d), di, dt),
        "norm_w": jnp.ones(lead + (di,), jnp.float32),
    }


def _norm_params(cfg: ArchConfig, n: int, names=("ln1", "ln2")) -> Params:
    d = cfg.d_model
    lead = (n,) if n else ()
    p = {}
    for nm in names:
        p[f"{nm}_w"] = jnp.ones(lead + (d,), jnp.float32)
        if cfg.norm == "ln":
            p[f"{nm}_b"] = jnp.zeros(lead + (d,), jnp.float32)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    d, Vp, dt = cfg.d_model, cfg.padded_vocab, _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": (jax.random.normal(keys[0], (Vp, d), jnp.float32) * 0.02
                  ).astype(dt),
    }
    params.update({k: v for k, v in _norm_params(cfg, 0, ("final",)).items()})
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(keys[1], (Vp, d), jnp.float32) * 0.02).astype(dt)

    Lk = keys[2]
    n = cfg.n_layers
    if cfg.family in ("dense", "vlm"):
        lp = {}
        lp.update(_attn_params(jax.random.fold_in(Lk, 0), cfg, n))
        lp.update(_mlp_params(jax.random.fold_in(Lk, 1), cfg, n))
        lp.update(_norm_params(cfg, n))
        params["layers"] = lp
    elif cfg.family == "moe":
        lp = {}
        lp.update(_attn_params(jax.random.fold_in(Lk, 0), cfg, n))
        lp.update(_moe_params(jax.random.fold_in(Lk, 1), cfg, n))
        lp.update(_norm_params(cfg, n))
        params["layers"] = lp
    elif cfg.family == "ssm":
        lp = {}
        lp.update(_mamba_params(jax.random.fold_in(Lk, 0), cfg, n))
        lp.update(_norm_params(cfg, n, ("ln1",)))
        params["layers"] = lp
    elif cfg.family == "hybrid":
        period = cfg.attn_period
        n_periods = n // period
        n_mamba = period - 1
        n_moe = period // 2
        n_mlp = period - n_moe
        pp = {
            "mamba": _stack_over(
                lambda k: _mamba_params(k, cfg, n_mamba),
                jax.random.fold_in(Lk, 0), n_periods),
            "attn": _stack_over(
                lambda k: _attn_params(k, cfg, 0),
                jax.random.fold_in(Lk, 1), n_periods),
            "moe": _stack_over(
                lambda k: _moe_params(k, cfg, n_moe),
                jax.random.fold_in(Lk, 2), n_periods),
            "mlp": _stack_over(
                lambda k: _mlp_params(k, cfg, n_mlp),
                jax.random.fold_in(Lk, 3), n_periods),
            "norms": _stack_over(
                lambda k: _norm_params(cfg, period),
                jax.random.fold_in(Lk, 4), n_periods),
        }
        params["periods"] = pp
    elif cfg.family == "encdec":
        enc = {}
        enc.update(_attn_params(jax.random.fold_in(Lk, 0), cfg,
                                cfg.encoder_layers, kv_heads=cfg.n_heads))
        enc.update(_mlp_params(jax.random.fold_in(Lk, 1), cfg,
                               cfg.encoder_layers))
        enc.update(_norm_params(cfg, cfg.encoder_layers))
        params["enc_layers"] = enc
        params["enc_pos"] = (jax.random.normal(
            keys[3], (cfg.encoder_seq, d), jnp.float32) * 0.02).astype(dt)
        dec = {}
        dec.update(_attn_params(jax.random.fold_in(Lk, 2), cfg, n))
        dec.update(_attn_params(jax.random.fold_in(Lk, 3), cfg, n,
                                prefix="c", kv_heads=cfg.n_heads))
        dec.update(_mlp_params(jax.random.fold_in(Lk, 4), cfg, n))
        dec.update(_norm_params(cfg, n, ("ln1", "ln2", "ln3")))
        params["layers"] = dec
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return params


def _stack_over(fn, key, n):
    trees = [fn(jax.random.fold_in(key, i)) for i in range(n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# --------------------------------------------------------------- forward ---

def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _unroll(cfg: ArchConfig, n: int) -> int:
    # 0 = full unroll (dry-run exact-FLOPs mode; cost_analysis counts scan
    # bodies once, so rooflines lower with unrolled stacks)
    return n if cfg.scan_unroll == 0 else min(cfg.scan_unroll, n)


def _dense_block(x, lp, cfg, positions, cache, cache_len, pos, moe: bool):
    h, new_kv = L.attention(L.norm(x, lp, cfg, "ln1"), lp, cfg,
                            positions=positions, cache=cache,
                            cache_len=cache_len, pos=pos)
    x = x + h
    h2 = L.norm(x, lp, cfg, "ln2")
    x = x + (L.moe_layer(h2, lp, cfg) if moe else L.mlp(h2, lp, cfg))
    return x, new_kv


def _stack_apply(x, stacked, cfg, positions, caches, cache_len, pos,
                 block_fn):
    """lax.scan over stacked layer params (+ per-layer caches)."""
    def body(carry, per):
        lp, lcache = per
        y, new_cache = block_fn(carry, lp, cfg, positions, lcache,
                                cache_len, pos)
        return y, new_cache
    body = _maybe_remat(body, cfg)
    n = jax.tree.leaves(stacked)[0].shape[0]
    x, new_caches = jax.lax.scan(body, x, (stacked, caches),
                                 unroll=_unroll(cfg, n))
    return x, new_caches


def _empty_caches(cfg, n, like):
    return None if like is None else jax.tree.map(
        lambda t: jnp.broadcast_to(t, (n,) + t.shape), like)


def forward(params: Params, cfg: ArchConfig, tokens: jnp.ndarray, *,
            caches=None, cache_len: Optional[int] = None,
            pos=None, patch_embeds=None, enc_frames=None,
            ) -> Tuple[jnp.ndarray, Any]:
    """Run the backbone; returns (final hidden states (B,S,d), new caches).

    * train:   caches=None, cache_len=None, pos=None
    * prefill: cache_len=S_max  -> caches returned
    * decode:  caches=..., pos=scalar position
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(_dtype(cfg))
    if cfg.family == "vlm" and patch_embeds is not None and pos is None:
        # stubbed vision frontend: prepend patch embeddings, keep length S
        # (patches only enter at train/prefill; decode steps are text-only)
        npatch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, npatch:]], 1)
    x = shard(x, "batch", "seq", None)
    if pos is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    else:
        positions = pos + jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    if cfg.family in ("dense", "vlm", "moe"):
        moe = cfg.family == "moe"
        block = functools.partial(_dense_block, moe=moe)
        x, new_caches = _stack_apply(x, params["layers"], cfg, positions,
                                     caches, cache_len, pos, block)
    elif cfg.family == "ssm":
        mode = ("train" if cache_len is None and pos is None
                else "prefill" if cache_len is not None else "decode")

        def block(x, lp, cfg_, positions_, lcache, cache_len_, pos_):
            h, nc = L.mamba2_layer(L.norm(x, lp, cfg_, "ln1"), lp, cfg_,
                                   cache=lcache, mode=mode)
            return x + h, nc
        if mode == "decode" and caches is None:
            caches = {"h": jnp.zeros(
                (cfg.n_layers, B, cfg.ssm_heads, cfg.ssm_state,
                 cfg.ssm_head_dim), jnp.float32)}
        x, new_caches = _stack_apply(x, params["layers"], cfg, positions,
                                     caches, cache_len, pos, block)
    elif cfg.family == "hybrid":
        x, new_caches = _hybrid_forward(params, cfg, x, positions,
                                        caches, cache_len, pos)
    elif cfg.family == "encdec":
        x, new_caches = _encdec_forward(params, cfg, x, positions,
                                        caches, cache_len, pos, enc_frames)
    else:
        raise ValueError(cfg.family)

    x = L.norm(x, params, cfg, "final")
    return x, new_caches


def _hybrid_forward(params, cfg, x, positions, caches, cache_len, pos):
    period = cfg.attn_period
    n_moe = period // 2

    def period_block(x, pp, cfg_, positions_, pcache, cache_len_, pos_):
        m_i, d_i = 0, 0
        new_cache = {"h": [], "k": None, "v": None}
        for i in range(period):
            nm = {k: v[i] for k, v in pp["norms"].items()}
            h_in = L.norm(x, {**nm}, cfg_, "ln1")
            mode = ("train" if cache_len_ is None and pos_ is None
                    else "prefill" if cache_len_ is not None else "decode")
            if i == period - 1:  # attention layer
                kv = (None if pcache is None or "k" not in pcache
                      else {"k": pcache["k"], "v": pcache["v"]})
                h, kv_new = L.attention(h_in, pp["attn"], cfg_,
                                        positions=positions_, cache=kv,
                                        cache_len=cache_len_, pos=pos_)
                if kv_new is not None:
                    new_cache["k"], new_cache["v"] = kv_new["k"], kv_new["v"]
            else:
                mp = {k: v[m_i] for k, v in pp["mamba"].items()}
                hc = (None if pcache is None or "h" not in pcache
                      else {"h": pcache["h"][m_i]})
                h, hc_new = L.mamba2_layer(h_in, mp, cfg_, cache=hc,
                                           mode=mode)
                if hc_new is not None:
                    new_cache["h"].append(hc_new["h"])
                m_i += 1
            x = x + h
            h2 = L.norm(x, {**nm}, cfg_, "ln2")
            if i % 2 == 1:  # MoE every other layer
                k_moe = (i // 2) % max(1, n_moe)
                ep = {k: v[k_moe] for k, v in pp["moe"].items()}
                x = x + L.moe_layer(h2, ep, cfg_)
            else:
                k_mlp = (i // 2) % max(1, period - n_moe)
                fp = {k: v[k_mlp] for k, v in pp["mlp"].items()}
                x = x + L.mlp(h2, fp, cfg_)
        out_cache = None
        if new_cache["h"] or new_cache["k"] is not None:
            out_cache = {}
            if new_cache["h"]:
                out_cache["h"] = jnp.stack(new_cache["h"])
            if new_cache["k"] is not None:
                out_cache["k"], out_cache["v"] = new_cache["k"], new_cache["v"]
        return x, out_cache

    def body(carry, per):
        pp, pcache = per
        return period_block(carry, pp, cfg, positions, pcache,
                            cache_len, pos)
    body = _maybe_remat(body, cfg)
    n = jax.tree.leaves(params["periods"])[0].shape[0]
    x, new_caches = jax.lax.scan(body, x, (params["periods"], caches),
                                 unroll=_unroll(cfg, n))
    return x, new_caches


def _encdec_forward(params, cfg, x, positions, caches, cache_len, pos,
                    enc_frames):
    B = x.shape[0]
    # ---- encoder (runs at train + prefill; cached as cross-kv at decode)
    if caches is None or "ck" not in caches:
        assert enc_frames is not None, "encdec needs enc_frames"
        e = enc_frames.astype(x.dtype) + params["enc_pos"][None]
        e = shard(e, "batch", "seq", None)
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None],
                                (B, e.shape[1]))

        def enc_block(carry, lp):
            h, _ = L.attention(L.norm(carry, lp, cfg, "ln1"), lp, cfg,
                               positions=epos, causal=False)
            carry = carry + h
            carry = carry + L.mlp(L.norm(carry, lp, cfg, "ln2"), lp, cfg)
            return carry, None
        enc_out, _ = jax.lax.scan(
            _maybe_remat(enc_block, cfg), e, params["enc_layers"],
            unroll=_unroll(cfg, cfg.encoder_layers))
        # per-decoder-layer cross kv
        H, D = cfg.n_heads, cfg.head_dim

        def cross_kv(lp):
            ck = jnp.einsum("bsd,dh->bsh", enc_out, lp["cwk"])
            cv = jnp.einsum("bsd,dh->bsh", enc_out, lp["cwv"])
            S_e = enc_out.shape[1]
            return (ck.reshape(B, S_e, H, D), cv.reshape(B, S_e, H, D))
        cks, cvs = jax.vmap(cross_kv)(params["layers"])  # stacked over L
    else:
        cks, cvs = caches["ck"], caches["cv"]

    self_caches = None
    if caches is not None and "k" in caches:
        self_caches = {"k": caches["k"], "v": caches["v"]}

    def dec_block(carry, per):
        lp, lc, ck, cv = per
        h, kv_new = L.attention(L.norm(carry, lp, cfg, "ln1"), lp, cfg,
                                positions=positions, cache=lc,
                                cache_len=cache_len, pos=pos)
        carry = carry + h
        h2, _ = L.attention(L.norm(carry, lp, cfg, "ln2"), lp, cfg,
                            positions=positions, kv_override=(ck, cv),
                            prefix="c")
        carry = carry + h2
        carry = carry + L.mlp(L.norm(carry, lp, cfg, "ln3"), lp, cfg)
        return carry, kv_new

    x, new_kv = jax.lax.scan(_maybe_remat(dec_block, cfg), x,
                             (params["layers"], self_caches, cks, cvs),
                             unroll=_unroll(cfg, cfg.n_layers))
    new_caches = None
    if new_kv is not None and (cache_len is not None or pos is not None):
        new_caches = {"k": new_kv["k"], "v": new_kv["v"],
                      "ck": cks, "cv": cvs}
    return x, new_caches


# ---------------------------------------------------------------- logits ---

def logits_from_hidden(params: Params, cfg: ArchConfig,
                       hidden: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) -> (B, S, Vp) with padded-vocab masking."""
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", hidden, table,
                        preferred_element_type=jnp.float32)
    logits = shard(logits, "batch", "seq", "vocab")
    if cfg.padded_vocab != cfg.vocab:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(mask[None, None, :], logits, -1e30)
    return logits
