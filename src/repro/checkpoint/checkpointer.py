"""Fault-tolerant checkpointing: atomic npz shards + manifest, keep-last-k.

Design (DESIGN.md §6): checkpoints are written to a temp dir and atomically
renamed, so a node failure mid-write never corrupts the latest restore
point.  Shardings are *not* baked into the checkpoint — arrays are saved
device-agnostic and re-sharded on restore from the logical rules — which is
what makes elastic re-meshing (restore on a different device count) work.
On a real multi-host pod each host writes only its addressable shards; this
container has one host, so there is one shard file.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_steps"]


def jnp_astype(arr: np.ndarray, dtype) -> np.ndarray:
    """astype that understands ml_dtypes (bfloat16 etc.)."""
    import ml_dtypes  # shipped with jax

    return arr.astype(np.dtype(dtype))

_MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no bf16: widen to f32 (lossless) and narrow on restore
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
    meta = {"step": step, "time": time.time(), "n_arrays": len(flat),
            "bytes": int(sum(v.nbytes for v in flat.values()))}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _update_manifest(ckpt_dir, keep_last)
    return final


def _update_manifest(ckpt_dir: str, keep_last: int) -> None:
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    steps = list_steps(ckpt_dir)
    with open(os.path.join(ckpt_dir, _MANIFEST), "w") as f:
        json.dump({"steps": steps}, f)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp") \
                and os.path.exists(os.path.join(ckpt_dir, d, "meta.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shapes must match)."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "shard_0.npz")
    data = np.load(path)
    flat_like = _flatten(tree_like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint at step {step} missing keys: "
                       f"{sorted(missing)[:5]}...")
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs model {leaf.shape}")
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = jnp_astype(arr, leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
