"""Whisper-medium enc-dec backbone; conv frontend stubbed [arXiv:2212.04356; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096,
    vocab=51_865,
    norm="ln", qkv_bias=True,
    encoder_layers=24, encoder_seq=1500,
    tie_embeddings=True,
)
