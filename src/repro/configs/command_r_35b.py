"""Command-R 35B dense, GQA, no bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22_528,
    vocab=256_000,                  # largest vocab: best case for BoundedME
    rope_theta=8_000_000.0,
    mips_mode="boundedme",
)
