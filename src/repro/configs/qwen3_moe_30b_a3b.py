"""Qwen3-30B-A3B MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768,                       # per-expert intermediate size
    vocab=151_936,
    n_experts=128, experts_per_token=8,
    rope_theta=1_000_000.0,
    mips_mode="boundedme",          # 151k-row unembedding: prime MIPS target
)
