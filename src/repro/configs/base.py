"""Architecture + run-shape configuration.

One frozen dataclass describes every assigned architecture; the per-arch
modules in this package instantiate it with the exact published numbers.
``smoke()`` derives the reduced config used by CPU smoke tests; the full
config is only ever lowered via ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ArchConfig", "RunShape", "SHAPES", "pad_to"]


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class RunShape:
    """One input-shape cell (assigned per arch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Tuple[RunShape, ...] = (
    RunShape("train_4k", 4096, 256, "train"),
    RunShape("prefill_32k", 32768, 32, "prefill"),
    RunShape("decode_32k", 32768, 128, "decode"),
    RunShape("long_500k", 524288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rms"            # rms | ln
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (jamba): one attention layer every `attn_period` layers
    attn_period: int = 0
    # enc-dec (whisper): encoder depth; frontend provides embeddings (stub)
    encoder_layers: int = 0
    encoder_seq: int = 1500      # whisper 30s @ 50Hz after conv stub
    # vlm: prepended patch embeddings from the stubbed vision frontend
    n_patches: int = 0
    # serving
    mips_mode: str = "exact"     # exact | boundedme
    mips_eps: float = 0.3
    mips_delta: float = 0.1
    mips_precision: str = "fp32"  # fp32 | int8 sampling (DESIGN.md §10)
    # numerics / memory
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1         # 0 = fully unroll layer scans (dry-run FLOPs)
    vocab_pad: int = 2048        # pad vocab to this multiple for sharding
    # which run-shape cells apply (long_500k only for sub-quadratic mixers)
    supports_long: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, self.vocab_pad)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:  # mamba
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Rough parameter count (embedding + layers), for roofline MODEL_FLOPS."""
        d, L = self.d_model, self.n_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        if self.family in ("dense", "vlm", "encdec"):
            per = attn + 3 * d * self.d_ff
        elif self.family == "moe":
            per = attn + self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        elif self.family == "ssm":
            di, H, S = self.d_inner, self.ssm_heads, self.ssm_state
            per = d * (2 * di + 2 * S + H) + di * d + di  # in/out proj + B,C,dt
        elif self.family == "hybrid":
            n_attn = L // self.attn_period
            n_mamba = L - n_attn
            di, S = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * S + self.ssm_heads) + di * d
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            dense_ffn = 3 * d * self.d_ff
            # MoE on every other layer, dense MLP on the rest
            per = (attn * n_attn + mamba * n_mamba) / L + (moe + dense_ffn) / 2
        total = emb + int(per) * L
        if self.family == "encdec":
            total += self.encoder_layers * int(attn + 3 * d * self.d_ff)
            total += L * int(attn)  # cross-attention in the decoder
        return int(total)

    def active_params(self) -> int:
        """Active (per-token) params — MoE uses experts_per_token of n_experts."""
        if self.n_experts and self.experts_per_token:
            d, L = self.d_model, self.n_layers
            dead = (self.n_experts - self.experts_per_token) * 3 * d * self.d_ff
            if self.family == "hybrid":
                return self.n_params() - int(L // 2 * dead)
            return self.n_params() - L * dead
        return self.n_params()

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=4 if self.attn_period else max(2, min(4, self.n_layers)),
            attn_period=min(self.attn_period, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=32,
            d_ff=256,
            vocab=512,
            vocab_pad=128,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=32,
            ssm_chunk=16,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=24,
            n_patches=min(self.n_patches, 16),
            dtype="float32",
            remat=False,
        )
