"""Jamba v0.1 52B hybrid: Mamba+attention 1:7 interleave, MoE 16e top-2 [arXiv:2403.19887; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14_336,
    vocab=65_536,
    n_experts=16, experts_per_token=2,
    attn_period=8,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    supports_long=True,             # mamba-dominated: runs long_500k
)
