"""Assigned-architecture registry: --arch <id> resolves here."""

from repro.configs.base import ArchConfig, RunShape, SHAPES

from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.grok_1_314b import CONFIG as _grok1
from repro.configs.qwen2_5_3b import CONFIG as _qwen25_3b
from repro.configs.qwen1_5_0_5b import CONFIG as _qwen15_05b
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.internvl2_26b import CONFIG as _internvl2
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba

REGISTRY = {c.name: c for c in (
    _qwen3_moe, _grok1, _qwen25_3b, _qwen15_05b, _command_r,
    _tinyllama, _mamba2, _whisper, _internvl2, _jamba,
)}

SHAPE_REGISTRY = {s.name: s for s in SHAPES}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> RunShape:
    if name not in SHAPE_REGISTRY:
        raise KeyError(f"unknown shape {name!r}; have {sorted(SHAPE_REGISTRY)}")
    return SHAPE_REGISTRY[name]


def cells():
    """All 40 (arch x shape) dry-run cells, with skip reasons where N/A."""
    out = []
    for cfg in REGISTRY.values():
        for shp in SHAPES:
            skip = None
            if shp.name == "long_500k" and not cfg.supports_long:
                skip = "full quadratic attention at 512k context (DESIGN.md §5)"
            out.append((cfg, shp, skip))
    return out
