"""Grok-1 314B MoE [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=32_768,
    vocab=131_072,
    n_experts=8, experts_per_token=2,
)
