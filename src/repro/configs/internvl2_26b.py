"""InternVL2-26B backbone (InternLM2-20B); InternViT frontend stubbed [arXiv:2404.16821; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16_384,
    vocab=92_553,
    n_patches=256,                  # pixel-shuffled ViT tokens per image
)
