"""Mamba2-130M SSD, attention-free [arXiv:2405.21060; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
    supports_long=True,             # O(1)-state decode: runs long_500k
)
