"""Fault-injection harness tests (DESIGN.md §13).

The injector's whole value is determinism: the same seed must produce the
same fault schedule regardless of query order or which surfaces are
enabled, so CI can assert exact counters.  These tests pin that contract
plus the store flush-failure surface (staged mutations survive a failed
flush).
"""

import numpy as np
import pytest

from repro.launch.faults import FaultInjector, InjectedDispatchError
from repro.store import DynamicTableStore, StoreFlushError


# ---- determinism ---------------------------------------------------------

def test_schedule_is_pure_in_index():
    a = FaultInjector(3, latency_rate=0.3, error_rate=0.3)
    b = FaultInjector(3, latency_rate=0.3, error_rate=0.3)
    # query b in a different order: identical per-index decisions
    fa = [a.fail_attempts(i) for i in range(50)]
    fb = [b.fail_attempts(i) for i in reversed(range(50))][::-1]
    assert fa == fb
    la = [a.latency_s(i) for i in range(50)]
    lb = [b.latency_s(i) for i in range(50)]
    assert la == lb
    # and querying twice changes nothing
    assert [a.fail_attempts(i) for i in range(50)] == fa


def test_different_seeds_differ():
    a = [FaultInjector(s, error_rate=0.5).fail_attempts(i)
         for s in (0, 1) for i in range(40)]
    assert a[:40] != a[40:]


def test_kinds_are_independent_streams():
    # enabling latency must not shift the error schedule
    only_err = FaultInjector(9, error_rate=0.4)
    both = FaultInjector(9, error_rate=0.4, latency_rate=0.9)
    assert ([only_err.fail_attempts(i) for i in range(60)]
            == [both.fail_attempts(i) for i in range(60)])


# ---- rates / validation --------------------------------------------------

def test_zero_rates_inject_nothing():
    inj = FaultInjector(0)
    assert all(inj.latency_s(i) == 0.0 for i in range(20))
    assert all(inj.dispatch_error(i) is None for i in range(20))
    s = inj.stats()
    assert s["latency_spikes"] == 0 and s["dispatch_errors"] == 0


@pytest.mark.parametrize("kw", [{"latency_rate": 1.5},
                                {"error_rate": -0.1},
                                {"flush_failure_rate": 2.0}])
def test_invalid_rates_raise(kw):
    with pytest.raises(ValueError, match=r"\[0, 1\]"):
        FaultInjector(0, **kw)


# ---- dispatch error semantics -------------------------------------------

def test_transient_errors_clear_within_two_attempts():
    inj = FaultInjector(5, error_rate=1.0, persistent_rate=0.0)
    for i in range(30):
        fails = inj.fail_attempts(i)
        assert fails in (1, 2)
        assert isinstance(inj.dispatch_error(i, 0), InjectedDispatchError)
        assert inj.dispatch_error(i, fails) is None   # retry clears it


def test_persistent_errors_outlast_any_retry_budget():
    inj = FaultInjector(5, error_rate=1.0, persistent_rate=1.0)
    err = inj.dispatch_error(0, 0)
    assert "persistent" in str(err)
    assert inj.dispatch_error(0, 100) is not None
    assert inj.stats()["persistent_errors"] == 1


def test_latency_spikes_heavy_tailed_and_counted():
    inj = FaultInjector(2, latency_rate=1.0, latency_ms=10.0)
    spikes = [inj.latency_s(i) for i in range(200)]
    assert all(s >= 10e-3 for s in spikes)        # at least the scale
    assert max(spikes) > 3 * np.median(spikes)    # a real tail
    st = inj.stats()
    assert st["latency_spikes"] == 200
    assert st["injected_latency_ms"] == pytest.approx(sum(spikes) * 1e3)


# ---- store flush surface -------------------------------------------------

def test_flush_hook_fails_flush_with_staged_intact():
    store = DynamicTableStore(np.eye(4, 6, dtype=np.float32))
    inj = FaultInjector(0, flush_failure_rate=1.0)
    inj.attach(store)
    store.upsert(0, np.full(6, 2.0, np.float32))
    v0 = store.version
    with pytest.raises(StoreFlushError, match="injected"):
        store.flush_updates()
    # the torn-flush contract: nothing applied, everything still staged
    assert store.pending_updates == 1
    assert store.version == v0
    assert store.n_flush_failures == 1
    assert inj.stats()["flush_failures"] == 1
    # disable the schedule: the retried flush applies the survivor
    inj.flush_failure_rate = 0.0
    info = store.flush_updates()
    assert info["applied"] == 1
    assert store.host_table()[0, 0] == 2.0


def test_flush_schedule_deterministic_per_flush_index():
    def run():
        store = DynamicTableStore(np.eye(4, 6, dtype=np.float32))
        inj = FaultInjector(11, flush_failure_rate=0.5)
        inj.attach(store)
        outcomes = []
        for i in range(20):
            store.upsert(0, np.full(6, float(i), np.float32))
            try:
                store.flush_updates()
                outcomes.append(True)
            except StoreFlushError:
                outcomes.append(False)
                store._staged.clear()   # drop so indices stay aligned
        return outcomes

    a, b = run(), run()
    assert a == b
    assert True in a and False in a
