"""The micro-batching serve loop: triggers, cache, stats, correctness.

Single-device, in-process (the sharded engine runs in
tests/test_sharded_serve.py under fake devices).  The engine is driven on a
virtual clock throughout — no sleeps, no wall-clock flakiness.
"""

import jax
import numpy as np
import pytest

from repro.launch.serve import MIPSServeEngine, QuantizedLRU, simulate_stream


def _engine(**kw):
    rng = np.random.default_rng(0)
    table = rng.normal(size=(256, 128)).astype(np.float32)
    kw.setdefault("K", 3)
    kw.setdefault("eps", 1e-4)
    kw.setdefault("delta", 0.05)
    kw.setdefault("value_range", 8.0)
    kw.setdefault("block", 64)
    kw.setdefault("batch_size", 4)
    kw.setdefault("deadline_ms", 5.0)
    return MIPSServeEngine(table, **kw), table


class TestMicroBatching:
    def test_full_batch_flushes_without_deadline(self):
        eng, _ = _engine(batch_size=4)
        rng = np.random.default_rng(1)
        for i in range(4):
            eng.submit(rng.normal(size=128).astype(np.float32), now=0.0)
        done, _ = eng.poll(now=0.0)      # full trigger, deadline not reached
        assert len(done) == 4
        assert eng.n_full_flushes == 1 and eng.n_deadline_flushes == 0

    def test_partial_batch_waits_for_deadline(self):
        eng, _ = _engine(batch_size=4, deadline_ms=5.0)
        rng = np.random.default_rng(2)
        eng.submit(rng.normal(size=128).astype(np.float32), now=0.0)
        eng.submit(rng.normal(size=128).astype(np.float32), now=0.001)
        done, _ = eng.poll(now=0.004)            # younger than the deadline
        assert done == [] and eng.pending_count == 2
        done, _ = eng.poll(now=0.0051)           # oldest is now over it
        assert len(done) == 2
        assert eng.n_deadline_flushes == 1 and eng.n_full_flushes == 0
        assert eng.stats()["mean_batch_occupancy"] == 2.0

    def test_results_match_exact_topk(self):
        eng, table = _engine()
        rng = np.random.default_rng(3)
        qs = rng.normal(size=(10, 128)).astype(np.float32)
        rids = [eng.submit(q, now=0.0) for q in qs]
        eng.drain(now=0.0)
        for rid, q in zip(rids, qs):
            ids, scores = eng.result(rid)
            truth = np.argsort(-(table @ q))[:3]
            np.testing.assert_array_equal(np.sort(ids), np.sort(truth))
            for i, s in zip(ids, scores):
                assert abs(s - float(table[i] @ q) / 128.0) < 1e-5

    def test_query_shape_rejected(self):
        eng, _ = _engine()
        with pytest.raises(ValueError, match="query shape"):
            eng.submit(np.zeros(64, np.float32))


class TestCache:
    def test_repeat_query_hits_lru(self):
        eng, _ = _engine(cache_entries=16)
        rng = np.random.default_rng(4)
        q = rng.normal(size=128).astype(np.float32)
        r1 = eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        ids1, sc1 = eng.result(r1)
        r2 = eng.submit(q.copy(), now=1.0)       # same query, new buffer
        assert eng.pending_count == 0            # answered from cache
        ids2, sc2 = eng.result(r2)
        np.testing.assert_array_equal(ids1, ids2)
        np.testing.assert_array_equal(sc1, sc2)
        assert eng.n_cache_hits == 1 and eng.cache.hits == 1

    def test_quantization_shares_nearby_queries(self):
        eng, _ = _engine(cache_entries=16, cache_resolution=1e-2)
        rng = np.random.default_rng(5)
        # keep every coordinate well inside its quantization bucket so the
        # perturbation below cannot cross a rounding boundary
        q = (rng.integers(-50, 50, 128) * 1e-2 + 3e-3).astype(np.float32)
        eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        eng.submit(q + 1e-4, now=1.0)            # same bucket everywhere
        assert eng.n_cache_hits == 1

    def test_lru_eviction(self):
        lru = QuantizedLRU(capacity=2)
        for i, v in enumerate(("a", "b", "c")):
            lru.put(bytes([i]), v)
        assert len(lru) == 2
        assert lru.get(bytes([0])) is None       # evicted, counts a miss
        assert lru.get(bytes([2])) == "c"

    def test_capacity_zero_disables(self):
        eng, _ = _engine(cache_entries=0)
        rng = np.random.default_rng(6)
        q = rng.normal(size=128).astype(np.float32)
        eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        eng.submit(q, now=1.0)
        assert eng.pending_count == 1 and eng.n_cache_hits == 0


class TestStats:
    def test_stats_schema_and_recall(self):
        eng, _ = _engine(recall_sample_rate=1.0)
        rng = np.random.default_rng(7)
        stats = simulate_stream(
            eng, rng.normal(size=(12, 128)).astype(np.float32),
            interarrival_ms=0.01)
        for k in ("requests", "completed", "pending", "batches",
                  "full_flushes", "deadline_flushes",
                  "mean_batch_occupancy", "cache", "latency_ms", "recall",
                  "plan", "virtual_s", "throughput_rps"):
            assert k in stats, k
        assert stats["requests"] == stats["completed"] == 12
        assert stats["pending"] == 0
        assert stats["recall"]["samples"] == 12
        assert stats["recall"]["mean"] == 1.0    # eps=1e-4 => exact top-K
        assert stats["latency_ms"]["p95"] >= stats["latency_ms"]["p50"] >= 0

    def test_latency_includes_queue_wait(self):
        eng, _ = _engine(batch_size=8, deadline_ms=50.0)
        rng = np.random.default_rng(8)
        eng.submit(rng.normal(size=128).astype(np.float32), now=0.0)
        eng.poll(now=0.0512)                     # deadline flush at 51.2 ms
        lat = eng.stats()["latency_ms"]
        assert lat["max"] >= 51.0                # waited out the deadline


class TestNValidMasking:
    def test_adversarial_padding_rows_cannot_win(self):
        """Caller-padding rows with huge scores must be masked INSIDE the
        cascade: masking after the fact cannot recover a true winner the
        elimination already dropped for a padding arm."""
        from repro.core.boundedme_jax import bounded_me_decode, make_plan
        rng = np.random.default_rng(10)
        n, n_valid, N, K = 256, 200, 512, 3
        V = rng.normal(size=(n, N)).astype(np.float32)
        V[n_valid:] = 100.0                  # padding rows dominate any q>0
        Q = np.abs(rng.normal(size=(2, N))).astype(np.float32)
        plan = make_plan(n, N, K=K, eps=1e-4, delta=0.05, value_range=8.0,
                         block=128)
        truth = np.argsort(-(V[:n_valid] @ Q.T), axis=0)[:K].T
        for use_pallas in (False, True):
            ids, scores = bounded_me_decode(
                V, Q, jax.random.PRNGKey(0), plan=plan, final_exact=True,
                use_pallas=use_pallas, n_valid=n_valid)
            assert int(np.asarray(ids).max()) < n_valid, use_pallas
            for b in range(2):
                assert (set(np.asarray(ids)[b].tolist())
                        == set(truth[b].tolist())), (use_pallas, b)

    def test_engine_masks_padded_table(self):
        rng = np.random.default_rng(11)
        table = rng.normal(size=(256, 128)).astype(np.float32)
        table[200:] = 100.0
        eng = MIPSServeEngine(table, K=3, eps=1e-4, delta=0.05,
                              value_range=8.0, block=64, batch_size=2,
                              deadline_ms=1.0, n_valid=200,
                              recall_sample_rate=1.0)
        q = np.abs(rng.normal(size=(4, 128))).astype(np.float32)
        rids = [eng.submit(x, now=0.0) for x in q]
        eng.drain(now=0.0)
        for rid in rids:
            ids, _ = eng.result(rid)
            assert int(ids.max()) < 200
        assert eng.stats()["recall"]["mean"] == 1.0


class TestDynamicStoreRegressions:
    """ISSUE 4 satellites: cache/recall staleness under live updates."""

    def _store_engine(self, **kw):
        from repro.store import DynamicTableStore
        rng = np.random.default_rng(20)
        table = rng.normal(size=(256, 128)).astype(np.float32)
        st = DynamicTableStore(table, block=64, capacity_slack=1.5)
        kw.setdefault("K", 3)
        kw.setdefault("eps", 1e-4)
        kw.setdefault("delta", 0.05)
        kw.setdefault("value_range", 16.0)
        kw.setdefault("batch_size", 2)
        kw.setdefault("deadline_ms", 1.0)
        return MIPSServeEngine(st, **kw), st

    def test_post_upsert_query_never_returns_stale_cache(self):
        """Regression: the LRU key used to ignore table identity — a
        repeat query after an upsert was answered from the pre-upsert
        cache line.  Version-salted keys + invalidate-on-bump fix it."""
        eng, st = self._store_engine(cache_entries=64)
        rng = np.random.default_rng(21)
        q = rng.normal(size=128).astype(np.float32)
        r1 = eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        ids1, _ = eng.result(r1)
        nid = st.append((9.0 * q / np.linalg.norm(q)).astype(np.float32))
        r2 = eng.submit(q.copy(), now=1.0)   # would hit the stale line
        eng.drain(now=1.0)
        ids2, _ = eng.result(r2)
        assert nid in ids2.tolist(), "pre-upsert cached answer returned"
        assert nid not in ids1.tolist()
        assert eng.cache.invalidations >= 1
        # and the same query now re-caches under the new version
        r3 = eng.submit(q.copy(), now=2.0)
        assert eng.pending_count == 0        # served from the fresh line
        np.testing.assert_array_equal(eng.result(r3)[0], ids2)

    def test_result_cached_under_live_version_when_update_queued(self):
        """A result computed after a mid-queue version bump must be
        cached under the live version, not the submit-time one — the
        post-update repeat should hit, not recompute."""
        eng, st = self._store_engine(cache_entries=64, batch_size=4,
                                     deadline_ms=50.0)
        rng = np.random.default_rng(24)
        q = rng.normal(size=128).astype(np.float32)
        eng.submit(q, now=0.0)               # queued: batch not full
        st.upsert(0, rng.normal(size=128).astype(np.float32))  # staged
        eng.poll(now=0.06)                   # drains update, then flushes
        eng.submit(q.copy(), now=0.1)        # repeat at the live version
        assert eng.pending_count == 0 and eng.n_cache_hits == 1

    def test_recall_mirror_refreshes_after_updates(self):
        """Regression: the recall estimator's host table copy was
        materialized once and never refreshed — after an upsert its
        'exact truth' was stale and the live recall stat lied."""
        eng, st = self._store_engine(cache_entries=0,
                                     recall_sample_rate=1.0)
        rng = np.random.default_rng(22)
        q = rng.normal(size=128).astype(np.float32)
        r = eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        eng.result(r)
        # mutate winners: overwrite the current argmax and add a new one
        ids, _ = np.asarray(st.host_table() @ q), None
        st.upsert(int(np.argmax(st.host_table() @ q)),
                  rng.normal(size=128).astype(np.float32))
        st.append((9.0 * q / np.linalg.norm(q)).astype(np.float32))
        r = eng.submit(q, now=1.0)
        eng.drain(now=1.0)
        eng.result(r)
        # a stale mirror would score the engine's (correct, fresh) answer
        # against pre-update truth and report recall < 1
        assert eng.stats()["recall"]["mean"] == 1.0

    def test_static_engine_behavior_unchanged(self):
        """A plain-array engine still works with apply_updates a no-op."""
        eng, table = _engine()
        assert eng.apply_updates() == 0
        rng = np.random.default_rng(23)
        q = rng.normal(size=128).astype(np.float32)
        r = eng.submit(q, now=0.0)
        eng.drain(now=0.0)
        ids, _ = eng.result(r)
        truth = np.argsort(-(table @ q))[:3]
        np.testing.assert_array_equal(np.sort(ids), np.sort(truth))
        assert eng.stats()["updates"]["applied"] == 0


class TestKOutPlumbing:
    def test_decode_k_out_returns_sorted_superset(self):
        from repro.core.boundedme_jax import bounded_me_decode, make_plan
        rng = np.random.default_rng(9)
        V = rng.normal(size=(128, 256)).astype(np.float32)
        Q = rng.normal(size=(2, 256)).astype(np.float32)
        plan = make_plan(128, 256, K=2, eps=1e-4, delta=0.05,
                         value_range=8.0, block=64)
        key = jax.random.PRNGKey(0)
        i2, s2 = bounded_me_decode(V, Q, key, plan=plan, use_pallas=False)
        i3, s3 = bounded_me_decode(V, Q, key, plan=plan, use_pallas=False,
                                   k_out=3)
        np.testing.assert_array_equal(np.asarray(i3)[:, :2], np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(s3)[:, :2], np.asarray(s2))
        assert np.all(np.diff(np.asarray(s3), axis=1) <= 0)   # sorted desc

    def test_k_out_out_of_range_raises(self):
        from repro.core.boundedme_jax import bounded_me_decode, make_plan
        V = np.zeros((64, 128), np.float32)
        Q = np.zeros((1, 128), np.float32)
        plan = make_plan(64, 128, K=2, eps=0.1, delta=0.1, value_range=1.0,
                         block=64)
        with pytest.raises(ValueError, match="k_out"):
            bounded_me_decode(V, Q, jax.random.PRNGKey(0), plan=plan,
                              use_pallas=False, k_out=1)
        with pytest.raises(ValueError, match="k_out"):
            bounded_me_decode(V, Q, jax.random.PRNGKey(0), plan=plan,
                              use_pallas=False, k_out=plan.k_out_cap + 1)


class TestQuantizedLRUEdgeCases:
    """PR-6 satellite: LRU corner cases the serving stack leans on."""

    def test_capacity_zero_disables_cache_with_version_salting(self):
        # a capacity-0 cache must be a true no-op even through the
        # engine's version-salted key path (store updates bump versions)
        from repro.store import DynamicTableStore
        rng = np.random.default_rng(0)
        store = DynamicTableStore(
            rng.normal(size=(64, 16)).astype(np.float32))
        eng = MIPSServeEngine(store, K=2, eps=0.3, delta=0.2,
                              batch_size=2, cache_entries=0)
        q = rng.normal(size=16).astype(np.float32)
        for rep in range(3):
            if rep == 1:     # version bump mid-stream
                store.upsert(0, rng.normal(size=16).astype(np.float32))
            rid = eng.submit(q, now=float(rep))
            eng.drain(now=float(rep))
            assert eng.result(rid) is not None
        assert eng.n_cache_hits == 0
        assert len(eng.cache) == 0
        assert eng.cache.put(b"k", ("v",)) is None and len(eng.cache) == 0

    def test_eviction_order_after_invalidation(self):
        # invalidate() must fully reset recency: entries inserted after
        # it evict in their OWN insertion order, not a stale pre-clear one
        lru = QuantizedLRU(2, resolution=0.0)
        lru.put(b"a", 1)
        lru.put(b"b", 2)
        lru.invalidate()
        assert len(lru) == 0 and lru.invalidations == 1
        lru.put(b"c", 3)
        lru.put(b"d", 4)
        assert lru.get(b"c") == 3          # refresh c: d is now LRU
        lru.put(b"e", 5)                   # evicts d, not c
        assert lru.get(b"d") is None
        assert lru.get(b"c") == 3 and lru.get(b"e") == 5
        # pre-invalidation keys stayed dead through it all
        assert lru.get(b"a") is None and lru.get(b"b") is None

    def test_quantization_shares_lines_at_resolution(self):
        lru = QuantizedLRU(8, resolution=1e-2)
        q1 = np.zeros(4, np.float32)
        q2 = q1 + 1e-3                     # within resolution: same line
        q3 = q1 + 1.0                      # far away: distinct line
        assert lru.key(q1) == lru.key(q2)
        assert lru.key(q1) != lru.key(q3)
