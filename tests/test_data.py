"""Data pipeline: determinism, resumability, MIPS dataset shapes."""

import numpy as np

from repro.data.synthetic import (LMStream, adversarial_dataset,
                                  gaussian_dataset, mf_dataset,
                                  uniform_dataset)


def test_stream_deterministic_and_indexable():
    s1 = LMStream(vocab=1000, batch=4, seq=16, seed=42)
    s2 = LMStream(vocab=1000, batch=4, seq=16, seed=42)
    b_iter = next(iter(s1))
    b_idx = s2.batch_at(0)
    np.testing.assert_array_equal(b_iter["tokens"], b_idx["tokens"])
    # resume-at-step semantics: step k is identical regardless of history
    np.testing.assert_array_equal(s1.batch_at(7)["labels"],
                                  s2.batch_at(7)["labels"])
    assert not np.array_equal(s1.batch_at(7)["tokens"],
                              s1.batch_at(8)["tokens"])


def test_stream_labels_shifted():
    b = LMStream(vocab=50, batch=2, seq=8, seed=0).batch_at(3)
    assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50


def test_adversarial_rows_sorted_and_mean_matches():
    R = adversarial_dataset(50, 1000, seed=1)
    assert ((np.diff(R, axis=1) <= 0).all())  # 1s strictly before 0s
    means = R.mean(axis=1)
    assert 0 <= means.min() and means.max() <= 1


def test_generators_shapes():
    for gen in (gaussian_dataset, uniform_dataset):
        V, q = gen(100, 64, seed=3)
        assert V.shape == (100, 64) and q.shape == (64,)
    V, q = mf_dataset(100, 64, rank=8, seed=3)
    assert V.shape == (100, 64) and q.shape == (64,)
    # low-rank structure: top singular value dominates
    s = np.linalg.svd(V, compute_uv=False)
    assert s[0] / s[40] > 3
