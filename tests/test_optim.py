"""Optimizer + gradient-compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import (AdamWConfig, apply_updates, compress_grads,
                               cosine_schedule, global_norm, init_opt)


def test_adamw_minimizes_quadratic():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16,)),
                         jnp.float32)
    params = {"w": jnp.zeros(16)}
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                      total_steps=400)
    opt = init_opt(params)
    loss0 = None
    for i in range(300):
        g = {"w": params["w"] - target}
        params, opt, m = apply_updates(params, g, opt, cfg)
        if loss0 is None:
            loss0 = float(jnp.sum((params["w"] - target) ** 2))
    lossT = float(jnp.sum((params["w"] - target) ** 2))
    assert lossT < loss0 * 1e-3


def test_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0)
    opt = init_opt(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, opt, m = apply_updates(params, g, opt, cfg)
    assert float(m["grad_norm"]) > 1e5  # raw norm reported
    assert float(jnp.abs(p2["w"]).max()) < 10.0  # but update clipped


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6
    assert lrs[-1] < 0.2
    assert min(lrs[10:]) >= 0.1 * 1.0 - 1e-6


def test_compress_error_feedback_unbiased():
    """Accumulated compressed grads converge to accumulated true grads."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(size=(64,)) * 1e-3, jnp.float32)}
    err = {"w": jnp.zeros(64)}
    acc = jnp.zeros(64)
    for _ in range(200):
        gc, err = compress_grads(g_true, err)
        acc = acc + gc["w"]
    expected = g_true["w"] * 200
    rel = float(jnp.abs(acc - expected).max() / jnp.abs(expected).max())
    assert rel < 0.01  # error feedback keeps the long-run sum faithful


def test_bf16_moments_supported():
    params = {"w": jnp.zeros((8, 8), jnp.bfloat16)}
    opt = init_opt(params, moments_dtype=jnp.bfloat16, with_err=False)
    assert opt.mu["w"].dtype == jnp.bfloat16
    assert opt.err is None
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0)
    g = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    p2, opt2, _ = apply_updates(params, g, opt, cfg)
    assert opt2.mu["w"].dtype == jnp.bfloat16
    assert p2["w"].dtype == jnp.bfloat16
    assert not np.allclose(np.asarray(p2["w"], np.float32), 0.0)
