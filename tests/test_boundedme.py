"""Reference BoundedME: Theorem 1 validation + sample-complexity wins."""

import numpy as np
import pytest

from repro.core import (bounded_me, median_elimination, reward_matrix,
                        successive_elimination)
from repro.data.synthetic import adversarial_dataset


def test_guarantee_adversarial():
    """Paper Fig. 1 in miniature: suboptimality < eps at >= 1-delta rate."""
    n, N = 400, 4000
    eps, delta = 0.15, 0.2
    fails = 0
    trials = 25
    for t in range(trials):
        R = adversarial_dataset(n, N, seed=t)
        means = R.mean(axis=1)
        res = bounded_me(R, K=1, eps=eps, delta=delta, value_range=1.0)
        subopt = means.max() - means[res.topk[0]]
        if subopt >= eps:
            fails += 1
    assert fails / trials <= delta + 0.12  # generous slack at 25 trials


def test_topk_guarantee_adversarial():
    n, N, K = 300, 3000, 5
    R = adversarial_dataset(n, N, seed=7)
    means = R.mean(axis=1)
    res = bounded_me(R, K=K, eps=0.2, delta=0.1)
    kth_true = np.sort(means)[-K]
    kth_ret = np.sort(means[res.topk])[0]
    assert kth_true - kth_ret < 0.2


def test_exact_when_eps_tiny():
    rng = np.random.default_rng(3)
    V = rng.normal(size=(200, 512)).astype(np.float32)
    q = rng.normal(size=512).astype(np.float32)
    R = reward_matrix(V, q, rng)
    res = bounded_me(R, K=1, eps=1e-6, delta=0.01,
                     value_range=float(np.abs(R).max() * 2))
    assert res.topk[0] == np.argmax(V @ q)
    # at eps -> 0 every pull count saturates at N: exactly exhaustive
    assert res.total_pulls <= 200 * 512


def test_never_more_than_naive():
    R = adversarial_dataset(100, 1000, seed=1)
    for eps in (0.01, 0.1, 0.5):
        res = bounded_me(R, eps=eps, delta=0.1)
        assert res.total_pulls <= R.size


def test_beats_median_elimination():
    """BoundedME sample complexity < classical ME (the MAB-BP payoff)."""
    R = adversarial_dataset(500, 5000, seed=2)
    bme = bounded_me(R, K=1, eps=0.2, delta=0.1)
    me = median_elimination(R, K=1, eps=0.2, delta=0.1)
    assert bme.total_pulls < me.total_pulls


def test_beats_successive_elimination_small_eps():
    """At small eps the iid Hoeffding radius needs >> N samples to certify;
    BoundedME's without-replacement bound saturates at N and wins.  (At
    large eps instance-adaptive SE can win on easy instances — that is
    expected and not what the paper claims.)"""
    R = adversarial_dataset(500, 5000, seed=4)
    bme = bounded_me(R, K=1, eps=0.008, delta=0.1)
    se = successive_elimination(R, K=1, eps=0.008, delta=0.1)
    # BME saturates at n*N; SE's iid accounting keeps growing as 1/eps^2
    assert bme.total_pulls <= R.size
    assert bme.total_pulls <= se.total_pulls


def test_sample_complexity_scaling():
    """Corollary 3: pulls ~ n sqrt(N) / eps (up to logs)."""
    n = 200
    pulls = []
    for N in (1000, 4000):
        R = adversarial_dataset(n, N, seed=5)
        pulls.append(bounded_me(R, eps=0.3, delta=0.1).total_pulls)
    # quadrupling N should grow pulls by ~2x (sqrt), not 4x (linear)
    assert pulls[1] / pulls[0] < 3.0
