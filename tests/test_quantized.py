"""The int8 quantized sampling cascade (ISSUE 3 tentpole).

Covers the acceptance criteria that must hold from a clean checkout:

  * bit-exactness of the quantized fused kernel vs the jnp fallback in
    interpret mode (single query, batched decode), and vs the
    step-accurate numpy oracle;
  * the (eps, delta) guarantee survives quantization — empirical recall
    regression at int8 incl. exact top-K recovery at tiny eps;
  * adversarial extreme-scale tiles (one huge-magnitude row per tile):
    per-tile scales keep ranking intact;
  * the widened confidence bounds: pull counts grow monotonically with
    quant_err and `eps_effective` degrades gracefully;
  * fp32-exact final rescore on the int8 path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bounds
from repro.core.boundedme_jax import (bounded_me_blocked, bounded_me_decode,
                                      make_plan)
from repro.core.quantize import (INT4_LEVELS, INT8_LEVELS, pack_int4,
                                 pq_decode, pq_encode, pq_train,
                                 quantize_blocks, quantize_tiles,
                                 quantize_tiles_int4, unpack_int4)
from repro.core.schedule import make_schedule


def _data(n, N, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, N)).astype(np.float32),
            rng.normal(size=N).astype(np.float32))


class TestQuantizers:
    def test_tile_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        V4 = jnp.asarray(rng.normal(size=(4, 3, 8, 64)), jnp.float32)
        V8, vscale = quantize_tiles(V4)
        assert V8.dtype == jnp.int8 and vscale.shape == (4, 3)
        recon = np.asarray(V8, np.float32) * np.asarray(vscale)[:, :, None,
                                                               None]
        err = np.abs(recon - np.asarray(V4))
        # round-to-nearest: per-entry error <= scale / 2
        assert np.all(err <= np.asarray(vscale)[:, :, None, None] / 2 + 1e-7)

    def test_zero_tile_gets_scale_one(self):
        V4 = jnp.zeros((2, 2, 8, 64), jnp.float32)
        V8, vscale = quantize_tiles(V4)
        np.testing.assert_array_equal(np.asarray(vscale), 1.0)
        np.testing.assert_array_equal(np.asarray(V8), 0)

    def test_query_blocks_batched_scales(self):
        rng = np.random.default_rng(1)
        Qb = jnp.asarray(rng.normal(size=(3, 5, 64)), jnp.float32)
        q8, qscale = quantize_blocks(Qb)
        assert q8.dtype == jnp.int8 and qscale.shape == (3, 5)
        assert int(np.abs(np.asarray(q8)).max()) <= INT8_LEVELS


class TestQuantizationErrorBound:
    def test_formula_and_monotonicity(self):
        e8 = bounds.quantization_error(8.0, bits=8)
        assert e8 == pytest.approx(4.0 * (1 / 127 + 1 / (4 * 127 ** 2)))
        assert bounds.quantization_error(8.0, bits=16) < e8  # more bits
        assert bounds.quantization_error(16.0) > e8          # wider range
        with pytest.raises(ValueError):
            bounds.quantization_error(0.0)

    def test_schedule_widens_with_quant_err(self):
        base = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                             value_range=0.5)
        wide = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                             value_range=0.5, quant_err=0.01)
        assert wide.quant_err == 0.01
        for rb, rw in zip(base.rounds, wide.rounds):
            assert rw.t_cum >= rb.t_cum     # never fewer pulls
        assert wide.total_pulls > base.total_pulls

    def test_unabsorbable_bias_saturates_to_full_coverage(self):
        # quant_err >= eps_1/2 on every round: all pulls go to N
        sched = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                              value_range=0.5, quant_err=1.0)
        assert all(r.t_cum == 128 for r in sched.rounds)

    def test_eps_effective(self):
        base = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                             value_range=0.5)
        assert base.eps_effective == base.eps
        wide = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                             value_range=0.5, quant_err=1e-4)
        # tiny bias: every round absorbs it, no penalty
        assert wide.eps_effective == pytest.approx(wide.eps)
        bad = make_schedule(64, 128, K=2, eps=0.2, delta=0.1,
                            value_range=0.5, quant_err=0.05)
        assert bad.eps_effective > bad.eps

    def test_plan_precision_validation(self):
        with pytest.raises(ValueError):
            make_plan(64, 256, precision="int2")
        plan = make_plan(64, 256, K=1, eps=0.2, value_range=8.0, block=64,
                         precision="int8")
        assert plan.precision == "int8" and plan.quant_err > 0
        assert plan.eps_effective >= plan.schedule.eps
        fp = make_plan(64, 256, K=1, eps=0.2, value_range=8.0, block=64)
        assert fp.quant_err == 0.0 and fp.eps_effective == fp.schedule.eps


class TestCodecs:
    """Property tests for the PR-8 int4/pq codecs (ISSUE 8 satellite)."""

    def test_int4_pack_unpack_roundtrip_identity(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-INT4_LEVELS, INT4_LEVELS + 1,
                                     size=(3, 4, 8, 64)), jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(x))),
                                      np.asarray(x))
        # packed layout: half the stored width, one byte per value pair
        assert pack_int4(x).shape == (3, 4, 8, 32)

    def test_int4_quantize_tiles_bounds_and_reconstruction(self):
        rng = np.random.default_rng(1)
        V4 = jnp.asarray(rng.normal(size=(4, 3, 8, 64)), jnp.float32)
        P4, vscale = quantize_tiles_int4(V4)
        assert P4.shape == (4, 3, 8, 32) and vscale.shape == (4, 3)
        codes = np.asarray(unpack_int4(P4))
        assert np.abs(codes).max() <= INT4_LEVELS
        recon = codes.astype(np.float32) * np.asarray(vscale)[:, :, None,
                                                              None]
        err = np.abs(recon - np.asarray(V4))
        # round-to-nearest on the 15-level grid: error <= scale / 2
        assert np.all(err <= np.asarray(vscale)[:, :, None, None] / 2 + 1e-6)

    def test_pq_assignment_is_argmin_distance(self):
        rng = np.random.default_rng(2)
        V4 = jnp.asarray(rng.normal(size=(2, 3, 8, 32)), jnp.float32)
        cb = pq_train(V4, n_codes=8, subdims=8)
        codes = np.asarray(pq_encode(V4, cb))
        X = np.asarray(V4).reshape(2, 3, 8, 4, 8)        # (T, Bn, R, S, w)
        C = np.asarray(cb)                                # (Bn, S, K, w)
        for t in range(2):
            for b in range(3):
                for r in range(8):
                    for s in range(4):
                        d = ((X[t, b, r, s][None] - C[b, s]) ** 2).sum(-1)
                        assert d[codes[t, b, r, s]] <= d.min() + 1e-5

    def test_pq_codebook_determinism(self):
        rng = np.random.default_rng(3)
        V4 = jnp.asarray(rng.normal(size=(2, 2, 8, 64)), jnp.float32)
        cb1 = pq_train(V4, n_codes=16, subdims=8)
        cb2 = pq_train(V4, n_codes=16, subdims=8)
        np.testing.assert_array_equal(np.asarray(cb1), np.asarray(cb2))
        np.testing.assert_array_equal(np.asarray(pq_encode(V4, cb1)),
                                      np.asarray(pq_encode(V4, cb2)))

    def test_pq_reconstruction_error_monotone_in_subdims(self):
        """Wider subspaces = fewer codebook cells per coordinate = coarser
        reconstruction; mean-squared error must not improve as subdims
        grows (same code budget spread over more dimensions)."""
        rng = np.random.default_rng(4)
        V4 = jnp.asarray(rng.normal(size=(3, 2, 8, 64)), jnp.float32)
        errs = []
        for w in (4, 8, 16):
            cb = pq_train(V4, n_codes=16, subdims=w)
            recon = pq_decode(pq_encode(V4, cb), cb)
            errs.append(float(jnp.mean((recon - V4) ** 2)))
        assert errs[0] <= errs[1] <= errs[2], errs

    def test_pq_shape_validation(self):
        V4 = jnp.zeros((1, 1, 8, 30), jnp.float32)
        with pytest.raises(ValueError):
            pq_train(V4, n_codes=8, subdims=8)    # 30 % 8 != 0
        with pytest.raises(ValueError):
            pq_train(jnp.zeros((1, 1, 8, 32), jnp.float32), n_codes=300,
                     subdims=8)                   # codes don't fit uint8


class TestBitExactness:
    """Kernel (interpret mode) vs jnp fallback vs numpy oracle, int8."""

    @pytest.mark.parametrize("n,N,tile,block,K", [
        (512, 2048, 8, 128, 3),
        (517, 2100, 8, 256, 12),     # ragged + K > tile
        (123, 300, 8, 64, 5),
    ])
    def test_fused_matches_fallback_bitwise(self, n, N, tile, block, K):
        V, q = _data(n, N, seed=n)
        kw = dict(K=K, eps=0.25, delta=0.1, value_range=8.0, tile=tile,
                  block=block, precision="int8")
        i_f, s_f, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                         use_pallas=True, **kw)
        i_j, s_j, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                         use_pallas=False, **kw)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_j))
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_j))

    def test_fused_matches_numpy_oracle(self):
        from repro.core.boundedme_jax import _pad_operands, _tile_major
        from repro.core.schedule import flatten_schedule
        from repro.kernels import ref
        from repro.kernels.fused_cascade import fused_cascade_pallas

        n, N, K, tile, block = 300, 900, 4, 8, 128
        V, q = _data(n, N, seed=2)
        plan = make_plan(n, N, K=K, eps=0.2, delta=0.1, value_range=8.0,
                         tile=tile, block=block, precision="int8")
        Vp, qp = _pad_operands(jnp.asarray(V), jnp.asarray(q), plan)
        V4 = _tile_major(Vp, plan)
        qb = qp.reshape(plan.n_blocks, plan.block)
        V8, vscale = quantize_tiles(V4)
        q8, qscale = quantize_blocks(qb)
        perm = jax.random.permutation(jax.random.PRNGKey(5), plan.n_blocks)
        flat = flatten_schedule(plan.schedule)
        cols = np.asarray(perm)[flat.bpos]
        slotcode, rmeta = flat.packed()
        ids_k, vals_k = fused_cascade_pallas(
            V8, q8, jnp.asarray(slotcode), jnp.asarray(rmeta),
            jnp.asarray(cols), n_arms=plan.n, K=plan.K,
            t_final=flat.t_final, n_final=flat.n_final,
            vscale=vscale, qscale=qscale, interpret=True)
        ids_o, vals_o = ref.fused_cascade_ref(
            V8, q8, flat, cols, n_arms=plan.n, K=plan.K,
            vscale=vscale, qscale=qscale)
        np.testing.assert_array_equal(np.asarray(ids_k), ids_o)
        np.testing.assert_allclose(np.asarray(vals_k), vals_o,
                                   rtol=1e-6, atol=1e-7)

    def test_decode_batched_bitwise_and_rescored(self):
        V, q = _data(256, 1024, seed=5)
        Q = np.stack([q, -q, 0.3 * q, _data(1, 1024, seed=9)[1]])
        plan = make_plan(256, 1024, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=128, precision="int8")
        key = jax.random.PRNGKey(11)
        for fe in (False, True):
            ip, sp = bounded_me_decode(V, Q, key, plan=plan,
                                       final_exact=fe, use_pallas=True)
            ij, sj = bounded_me_decode(V, Q, key, plan=plan,
                                       final_exact=fe, use_pallas=False)
            np.testing.assert_array_equal(np.asarray(ip), np.asarray(ij))
            np.testing.assert_array_equal(np.asarray(sp), np.asarray(sj))
        # final_exact scores are fp32-exact mean products, no quant error
        for b in range(Q.shape[0]):
            for i, s in zip(np.asarray(ip)[b], np.asarray(sp)[b]):
                assert abs(s - float(V[i] @ Q[b]) / 1024.0) < 1e-5

    def test_int8_cascade_still_one_dispatch(self):
        """Quantization must not cost extra kernel launches: the whole int8
        cascade (quantize + pulls + eliminations + extraction) lowers to
        exactly one pallas_call, like the fp32 path."""
        from repro.core.boundedme_jax import _run_blocked
        from repro.kernels import ops

        plan = make_plan(512, 2048, K=3, eps=0.3, delta=0.1, value_range=8.0,
                         tile=8, block=128, precision="int8")
        assert len(plan.schedule.rounds) >= 3
        rng = np.random.default_rng(0)
        V = jnp.asarray(rng.normal(size=(512, 2048)), jnp.float32)
        q = jnp.asarray(rng.normal(size=2048), jnp.float32)

        def fused(V, q, k):
            return _run_blocked(V, q, k, plan=plan, use_pallas=True)

        jaxpr = jax.make_jaxpr(fused)(V, q, jax.random.PRNGKey(0))
        assert ops.count_pallas_calls(jaxpr.jaxpr) == 1

    def test_mismatched_scales_raise(self):
        from repro.kernels.fused_cascade import fused_cascade_pallas

        with pytest.raises(ValueError):
            fused_cascade_pallas(
                jnp.zeros((1, 1, 8, 128), jnp.int8),
                jnp.zeros((1, 128), jnp.int8),
                jnp.zeros((1,), jnp.int32), jnp.zeros((1, 3), jnp.int32),
                jnp.zeros((1,), jnp.int32), n_arms=8, K=1, t_final=1,
                n_final=1, vscale=jnp.ones((1, 1)), interpret=True)


class TestRecallRegression:
    """(eps, delta) holds empirically at int8 (the widened-bounds check)."""

    def test_tiny_eps_recovers_planted_topk(self):
        """Exact top-K recovery at tiny eps, with winner margins above the
        irreducible int8 bias (~plan.quant_err per estimate).  int8 cannot
        separate arms closer than that — `eps_effective` floors at
        ~2*quant_err per saturated round, which is the honest contract the
        moderate-eps test below checks on unplanted data."""
        n, N, K, B = 1024, 2048, 3, 5
        V, _ = _data(n, N, seed=6)
        rng = np.random.default_rng(7)
        Q = rng.normal(size=(B, N)).astype(np.float32)
        V *= 0.2                       # noise scores well under the plants
        for b in range(B):             # per-query planted winners, spaced
            unit = Q[b] / np.linalg.norm(Q[b])
            for j in range(K):
                V[17 * b + j] = (4.0 + 0.5 * j) * unit
        plan = make_plan(n, N, K=K, eps=1e-4, delta=0.05,
                         value_range=8.0, block=256, precision="int8")
        ids, _ = bounded_me_decode(V, Q, jax.random.PRNGKey(0), plan=plan,
                                   final_exact=True, use_pallas=False)
        truth = np.argsort(-(V @ Q.T), axis=0)[:K].T
        for b in range(B):
            assert (set(np.asarray(ids)[b].tolist())
                    == set(truth[b].tolist())), b

    def test_moderate_eps_recall_floor(self):
        """int8 at eps=0.1 must stay within the guarantee: every returned
        arm is eps_effective-optimal on the mean-product scale."""
        n, N, K, B = 2048, 1024, 4, 8
        V, _ = _data(n, N, seed=8)
        rng = np.random.default_rng(9)
        Q = rng.normal(size=(B, N)).astype(np.float32)
        plan = make_plan(n, N, K=K, eps=0.1, delta=0.05, value_range=8.0,
                         block=256, precision="int8")
        ids, scores = bounded_me_decode(V, Q, jax.random.PRNGKey(1),
                                        plan=plan, final_exact=True,
                                        use_pallas=False)
        exact = (V @ Q.T).T / N                                # (B, n)
        kth_best = -np.sort(-exact, axis=1)[:, K - 1]          # (B,)
        eps_eff = plan.eps_effective
        for b in range(B):
            for s in np.asarray(scores)[b]:
                assert s >= kth_best[b] - eps_eff, (b, s, kth_best[b])

    def test_int8_vs_fp32_same_winners_at_small_eps(self):
        V, q = _data(512, 1024, seed=10)
        kw = dict(K=3, eps=1e-3, delta=0.05, value_range=8.0, block=128,
                  final_exact=True)
        i8, s8, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(2),
                                       precision="int8", **kw)
        i32, s32, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(2),
                                         precision="fp32", **kw)
        np.testing.assert_array_equal(np.asarray(i8), np.asarray(i32))
        np.testing.assert_allclose(np.asarray(s8), np.asarray(s32),
                                   rtol=1e-5, atol=1e-6)


class TestAdversarialScaleTiles:
    def test_one_huge_row_per_tile(self):
        """One huge-magnitude row per tile: per-tile symmetric scales keep
        every tile's winner representable, and the fp32 rescore returns
        exact scores.  A global (per-table) scale would quantize the noise
        rows to zero and still pass; the point is the huge rows must not
        poison each *other's* ranking."""
        n, N, K, tile, block = 128, 512, 5, 8, 64
        rng = np.random.default_rng(42)
        V = (0.01 * rng.normal(size=(n, N))).astype(np.float32)
        q = np.ones(N, np.float32)
        n_tiles = n // tile
        # distinct huge magnitudes, one per tile, winners = the K largest
        mags = 50.0 + np.arange(n_tiles, dtype=np.float32)
        for t in range(n_tiles):
            V[t * tile + (t % tile)] = mags[t] * 0.01
        ids, scores, plan = bounded_me_blocked(
            V, q, jax.random.PRNGKey(0), K=K, eps=1e-4, delta=0.05,
            value_range=8.0, tile=tile, block=block, final_exact=True,
            precision="int8")
        expect = {t * tile + (t % tile)
                  for t in range(n_tiles - K, n_tiles)}
        assert set(np.asarray(ids).tolist()) == expect
        for i, s in zip(np.asarray(ids), np.asarray(scores)):
            # fp32-exact up to accumulation order (sums are O(300) here)
            assert abs(s - float(V[i] @ q) / N) < 1e-4

    def test_huge_negative_row_does_not_crush_tilemate(self):
        """A huge-|value| row coarsens its tile's scale; the widened bounds
        plus the fp32 rescore must still surface a moderate winner sharing
        that tile."""
        n, N, tile, block = 64, 512, 8, 64
        rng = np.random.default_rng(3)
        V = (0.001 * rng.normal(size=(n, N))).astype(np.float32)
        V[0] = -100.0 * np.abs(rng.normal(size=N)).astype(np.float32)
        winner = rng.normal(size=N).astype(np.float32)
        V[1] = winner          # same tile as the huge-magnitude row
        q = winner / np.linalg.norm(winner)
        ids, _, _ = bounded_me_blocked(
            V, q, jax.random.PRNGKey(1), K=1, eps=1e-4, delta=0.05,
            value_range=8.0, tile=tile, block=block, final_exact=True,
            precision="int8")
        assert int(np.asarray(ids)[0]) == 1
