"""Continuous-batching runtime tests (DESIGN.md §13).

The robustness contract under test: every request submitted to
`ServeRuntime` terminates as a typed `ServeResult` — answered within its
admitted (eps, delta) or refused with a reason — and *no* traffic
(poison queries, overload bursts, injected dispatch faults, store flush
failures) ever raises out of the engine.
"""

import numpy as np
import pytest

from repro.launch.admission import STATUSES, PriorityClass
from repro.launch.faults import FaultInjector, InjectedDispatchError
from repro.launch.serve import ServeRuntime, arrival_trace, simulate_stream
from repro.store import DynamicTableStore

N_ROWS, DIM = 192, 24


def _table(seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(N_ROWS, DIM)).astype(np.float32)


def _queries(n, seed=1):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, DIM)).astype(np.float32)


def _rt(table=None, **kw):
    kw.setdefault("K", 4)
    kw.setdefault("eps", 0.2)
    kw.setdefault("delta", 0.1)
    kw.setdefault("lanes", 4)
    kw.setdefault("batch_wait_ms", 1.0)
    kw.setdefault("queue_capacity", 16)
    return ServeRuntime(_table() if table is None else table, **kw)


def _drain_all(rt, now=0.0):
    done, busy = rt.drain(now=now)
    return done, now + busy


# ---- happy path ---------------------------------------------------------

def test_light_load_serves_everything_ok():
    rt = _rt()
    rt.warmup()
    qs = _queries(40)
    stats = simulate_stream(rt, qs, interarrival_ms=5.0)
    assert stats["availability"] == 1.0
    assert stats["outcomes"]["ok"] == 40
    assert sum(stats["outcomes"].values()) == 40
    assert stats["latency_ms"]["p99"] >= stats["latency_ms"]["p50"]
    for rid in range(40):
        res = rt.result(rid)
        assert res is not None and res.status == "ok"
        assert res.ids.shape == (4,) and res.scores.shape == (4,)
        assert res.eps_served == pytest.approx(0.2)
        assert res.delta_served == pytest.approx(0.1)


def test_answers_meet_contract_recall():
    rt = _rt(eps=0.05, recall_sample_rate=1.0)
    rt.warmup()
    stats = simulate_stream(rt, _queries(32), interarrival_ms=5.0)
    assert stats["recall"]["samples"] > 0
    assert stats["recall"]["mean"] > 0.8


def test_every_status_is_typed_and_closed():
    rt = _rt()
    rt.warmup()
    qs = _queries(60)
    qs[5] = np.nan                                  # poison
    simulate_stream(rt, qs, pattern="bursty", seed=3, open_loop=True,
                    interarrival_ms=0.01)
    seen = set()
    for rid in range(60):
        res = rt.result(rid)
        assert res is not None, f"request {rid} has no terminal result"
        assert res.status in STATUSES
        if res.answered:
            assert res.eps_served is not None
        else:
            assert res.reason
        seen.add(res.status)
    assert "rejected" in seen                       # the poison query


# ---- admission ----------------------------------------------------------

def test_poison_rejected_then_engine_keeps_serving():
    rt = _rt()
    rt.warmup()
    r_bad = rt.submit(np.full(DIM, np.inf, np.float32), now=0.0)
    bad = rt.result(r_bad)
    assert bad.status == "rejected" and "poison" in bad.reason
    r_good = rt.submit(_queries(1)[0], now=0.0)
    rt.poll(now=0.01)
    assert rt.result(r_good).status == "ok"
    assert rt.stats()["queue"]["rejected_poison"] == 1


def test_wrong_dim_rejected_not_raised():
    rt = _rt()
    rid = rt.submit(np.ones(DIM + 3, np.float32), now=0.0)
    res = rt.result(rid)
    assert res.status == "rejected" and "shape" in res.reason


def test_overload_sheds_typed_and_never_crashes():
    rt = _rt(queue_capacity=8)
    rt.warmup()
    qs = _queries(300)
    stats = simulate_stream(rt, qs, pattern="bursty", seed=1,
                            open_loop=True, interarrival_ms=0.01)
    assert sum(stats["outcomes"].values()) == 300
    assert stats["outcomes"]["overloaded"] > 0          # shedding fired
    assert stats["outcomes"]["failed"] == 0             # but nothing broke
    assert 0.0 < stats["availability"] < 1.0
    assert stats["queue"]["peak_depth"] <= 8
    # engine is still healthy after the storm
    rid = rt.submit(_queries(1, seed=9)[0], now=1e6)
    rt.poll(now=1e6 + 0.01)
    assert rt.result(rid).status == "ok"


def test_interactive_displaces_batch_when_full():
    classes = {
        "interactive": PriorityClass("interactive", priority=0,
                                     sheddable=False, deadline_ms=0),
        "batch": PriorityClass("batch", priority=2, deadline_ms=0),
    }
    rt = _rt(queue_capacity=3, classes=classes,
             default_class="batch")
    rt.warmup()
    qs = _queries(4)
    rids = [rt.submit(qs[i], now=float(i) * 1e-6, cls="batch")
            for i in range(3)]
    ri = rt.submit(qs[3], now=1e-5, cls="interactive")
    displaced = [r for r in rids if rt._results.get(r) is not None]
    assert len(displaced) == 1
    res = rt.result(displaced[0])
    assert res.status == "overloaded" and "displaced" in res.reason
    rt.drain(now=1.0)
    assert rt.result(ri).answered
    assert rt.stats()["classes"]["interactive"]["answered"] == 1
    assert rt.stats()["classes"]["batch"]["shed"] == 1


def test_request_deadline_expires_as_typed_overloaded():
    classes = {"default": PriorityClass("default", deadline_ms=1.0)}
    rt = _rt(classes=classes)
    rt.warmup()
    rid = rt.submit(_queries(1)[0], now=0.0)
    rt.poll(now=0.5)                    # long past the 1 ms deadline
    res = rt.result(rid)
    assert res.status == "overloaded" and res.reason == "deadline"
    assert rt.stats()["queue"]["expired_deadline"] == 1


# ---- degradation ladder --------------------------------------------------

def test_pressure_degrades_eps_before_rejecting():
    rt = _rt(eps=0.2, eps_floor=0.8, degrade_rungs=3, queue_capacity=20)
    rt.warmup()
    qs = _queries(20, seed=4)
    rids = [rt.submit(qs[i], now=0.0) for i in range(20)]
    rt.drain(now=0.0)
    results = [rt.result(r) for r in rids]
    assert all(r.answered for r in results)         # nobody refused
    degraded = [r for r in results if r.status == "degraded"]
    assert degraded, "full queue must climb the ladder"
    for r in degraded:
        assert r.eps_served > 0.2
        assert r.eps_served <= 0.8 + 1e-9
    st = rt.stats()["degradation"]
    assert sum(st["served_per_rung"][1:]) == len(degraded)
    assert st["rungs"][0] == pytest.approx(0.2)
    assert st["rungs"][-1] == pytest.approx(0.8)


def test_deadline_urgency_degrades_even_with_shallow_queue():
    # open-loop overload under tight deadlines never builds queue depth
    # (requests expire first), so pressure must also come from urgency:
    # a batch that burned most of its deadline budget waiting dispatches
    # at a degraded rung even though depth/capacity stays tiny.
    rt = _rt(eps=0.2, eps_floor=0.8, degrade_rungs=3, queue_capacity=64)
    rt.warmup()
    qs = _queries(3, seed=11)
    rids = [rt.submit(q, now=0.0) for q in qs]
    # 3 requests in a 64-slot queue: load ~0.06, far below degrade_start.
    # Poll at 70% of the default 50 ms deadline: urgency 0.7 → rung > 0.
    rt.poll(now=0.035)
    results = [rt.result(r) for r in rids]
    assert all(r.status == "degraded" for r in results)
    assert all(r.eps_served > 0.2 for r in results)


def test_degraded_results_never_cached_as_full_quality():
    rt = _rt(eps=0.2, eps_floor=0.8, degrade_rungs=2, queue_capacity=8,
             cache_entries=64)
    rt.warmup()
    q = _queries(1, seed=5)[0]
    fill = _queries(8, seed=6)
    rid = rt.submit(q, now=0.0)
    for i in range(7):
        rt.submit(fill[i], now=0.0)
    rt.drain(now=0.0)                   # full queue: q served degraded
    first = rt.result(rid)
    assert first.status == "degraded"
    # resubmit the same query with an idle queue: a degraded answer must
    # NOT satisfy it from the cache
    r2 = rt.submit(q, now=10.0)
    rt.poll(now=10.01)
    second = rt.result(r2)
    assert second.status == "ok" and not second.cached
    assert second.eps_served == pytest.approx(0.2)
    # but the full-quality serve IS cacheable
    r3 = rt.submit(q, now=20.0)
    third = rt.result(r3)
    assert third.status == "ok" and third.cached


def test_no_floor_means_single_rung_no_degradation():
    rt = _rt(queue_capacity=4)
    assert rt.ladder.n_rungs == 1
    rt.warmup()
    rids = [rt.submit(q, now=0.0) for q in _queries(4, seed=7)]
    rt.drain(now=0.0)
    assert all(rt.result(r).status == "ok" for r in rids)


# ---- faults -------------------------------------------------------------

def test_transient_dispatch_fault_retries_to_success():
    inj = FaultInjector(5, error_rate=1.0, persistent_rate=0.0)
    rt = _rt(fault_injector=inj, max_retries=2, retry_backoff_ms=1.0)
    rt.warmup()
    rid = rt.submit(_queries(1)[0], now=0.0)
    _, busy = rt.poll(now=0.01)
    res = rt.result(rid)
    assert res.status == "ok" and res.retries >= 1
    assert busy > rt.retry_backoff_s * 0.9      # backoff hit the clock
    st = rt.stats()["faults"]
    assert st["retries"] >= 1 and st["failed_batches"] == 0


def test_persistent_fault_fails_only_the_batch_and_quarantines():
    inj = FaultInjector(5, error_rate=1.0, persistent_rate=1.0)
    rt = _rt(fault_injector=inj, max_retries=1)
    rt.warmup()
    q = _queries(1)[0]
    rid = rt.submit(q, now=0.0)
    rt.poll(now=0.01)                    # never raises out of the engine
    res = rt.result(rid)
    assert res.status == "failed"
    assert "retries" in res.reason and res.retries == 1
    # identical bytes are refused at admission now
    r2 = rt.submit(q, now=2.0)
    res2 = rt.result(r2)
    assert res2.status == "rejected" and "quarantined" in res2.reason
    st = rt.stats()
    assert st["faults"]["failed_batches"] == 1
    assert st["queue"]["rejected_quarantined"] == 1
    # the engine itself survives: disable the schedule, serve normally
    inj.error_rate = 0.0
    r3 = rt.submit(_queries(1, seed=8)[0], now=3.0)
    rt.poll(now=3.01)
    assert rt.result(r3).status == "ok"


def test_injected_faults_never_escape_simulate_stream():
    inj = FaultInjector(1, error_rate=0.3, persistent_rate=0.3,
                        latency_rate=0.2, latency_ms=2.0)
    rt = _rt(fault_injector=inj, max_retries=2, queue_capacity=32)
    rt.warmup()
    stats = simulate_stream(rt, _queries(150), pattern="bursty", seed=2,
                            open_loop=True, interarrival_ms=0.05)
    assert sum(stats["outcomes"].values()) == 150   # zero crashes
    assert stats["faults"]["dispatch_errors"] > 0   # faults really fired
    inj_stats = stats["faults"]["injected"]
    assert inj_stats["dispatch_errors"] == stats["faults"]["dispatch_errors"]


def test_store_flush_failure_keeps_serving_and_retries():
    store = DynamicTableStore(_table(), capacity_slack=1.5)
    inj = FaultInjector(0, flush_failure_rate=1.0)
    rt = _rt(table=store, fault_injector=inj)
    rt.warmup()
    store.upsert(0, np.full(DIM, 0.5, np.float32))
    rid = rt.submit(_queries(1)[0], now=0.0)
    rt.poll(now=0.01)                        # flush fails inside; no raise
    assert rt.result(rid).status == "ok"     # served on the current table
    st = rt.stats()
    assert st["faults"]["store_flush_failures"] >= 1
    assert store.pending_updates == 1        # staged op intact
    inj.flush_failure_rate = 0.0             # fault clears
    rt.poll(now=2.0)
    assert store.pending_updates == 0        # retried flush applied
    assert rt.stats()["updates"]["applied"] == 1
    assert rt.cache.invalidations >= 1       # version bump invalidated


# ---- scheduler ----------------------------------------------------------

def test_continuous_refill_backfills_between_dispatches():
    rt = _rt(lanes=4, queue_capacity=32)
    rt.warmup()
    # 10 requests queued at once: one poll must serve them all (4+4+2)
    # because work conservation dispatches the backlog without waiting
    # out the batch deadline again
    rids = [rt.submit(q, now=0.0) for q in _queries(10, seed=11)]
    done, _ = rt.poll(now=0.005)
    assert sorted(done) == sorted(rids)
    st = rt.stats()
    assert st["dispatches"] == 3
    assert st["full_dispatches"] == 2
    assert st["lanes"]["mean_occupancy"] == pytest.approx(10 / 3)


def test_partial_young_batch_waits_for_deadline():
    rt = _rt(lanes=4, batch_wait_ms=5.0)
    rt.warmup()
    rt.submit(_queries(1)[0], now=0.0)
    done, _ = rt.poll(now=0.001)       # younger than the 5 ms wait
    assert done == []
    done, _ = rt.poll(now=0.006)       # aged past it
    assert len(done) == 1


def test_warmup_compiles_every_rung_off_clock():
    rt = _rt(eps=0.2, eps_floor=0.6, degrade_rungs=3)
    assert rt.warmup() > 0.0
    sizes = [ex._fn._cache_size() for ex in rt._rung_execs]
    assert sizes == [1, 1, 1]
    simulate_stream(rt, _queries(8), interarrival_ms=5.0)
    assert [ex._fn._cache_size() for ex in rt._rung_execs][0] == 1


# ---- arrival traces / driver --------------------------------------------

def test_arrival_trace_uniform_matches_legacy_spacing():
    t = arrival_trace(5, interarrival_ms=2.0, pattern="uniform", seed=99)
    assert np.allclose(t, np.arange(5) * 2e-3)


@pytest.mark.parametrize("pattern", ["poisson", "bursty"])
def test_arrival_trace_reproducible_and_seeded(pattern):
    a = arrival_trace(64, pattern=pattern, seed=3)
    b = arrival_trace(64, pattern=pattern, seed=3)
    c = arrival_trace(64, pattern=pattern, seed=4)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)          # arrival times nondecreasing


def test_bursty_trace_is_actually_bursty():
    t = arrival_trace(256, interarrival_ms=1.0, pattern="bursty", seed=0)
    gaps = np.diff(t)
    # intra-burst gaps are far below the mean spacing, quiet gaps far above
    assert gaps.min() < 0.3e-3
    assert gaps.max() > 3e-3


def test_simulate_stream_reports_trace_metadata():
    rt = _rt()
    rt.warmup()
    stats = simulate_stream(rt, _queries(16), pattern="poisson", seed=7,
                            open_loop=True, interarrival_ms=1.0)
    tr = stats["trace"]
    assert tr["pattern"] == "poisson" and tr["seed"] == 7
    assert tr["open_loop"] is True
    assert tr["span_s"] > 0 and tr["offered_rps"] > 0


def test_stats_schema_has_tail_latency_and_counters():
    rt = _rt()
    rt.warmup()
    simulate_stream(rt, _queries(12), interarrival_ms=2.0)
    st = rt.stats()
    for key in ("p50", "p95", "p99", "max", "mean"):
        assert key in st["latency_ms"]
    for key in ("depth", "capacity", "peak_depth", "admitted",
                "rejected_poison", "overloaded", "displaced",
                "expired_deadline"):
        assert key in st["queue"]
    for key in ("retries", "dispatch_errors", "failed_batches",
                "store_flush_failures"):
        assert key in st["faults"]
    assert set(st["outcomes"]) == set(STATUSES)
    assert st["lanes"]["lanes"] == 4


# ---- lane accounting -----------------------------------------------------

def test_dispatch_lane_stats_non_adaptive():
    from repro.distributed.sharding import dispatch_lane_stats
    rt = _rt()
    out = dispatch_lane_stats(None, schedule=rt.plan.schedule, lanes=8,
                              filled=5)
    assert out["occupancy"] == 5
    assert out["lane_util"] == pytest.approx(5 / 8)
    assert out["executed_pull_frac"] == 1.0
    assert out["wasted_lane_frac"] == pytest.approx(3 / 8)


def test_dispatch_lane_stats_adaptive_reduces_pull_frac():
    from repro.core.schedule import pulls_through_round
    from repro.distributed.sharding import dispatch_lane_stats
    rt = _rt()
    sched = rt.plan.schedule
    if len(sched.rounds) < 2:
        pytest.skip("schedule too short to early-exit")
    early = np.zeros(4, np.int64)            # every lane exits round 0
    out = dispatch_lane_stats(early, schedule=sched, lanes=4, filled=4)
    pulls = pulls_through_round(sched)
    assert out["executed_pull_frac"] == pytest.approx(
        pulls[0] / pulls[-1])
    late = np.full(4, len(sched.rounds) - 1, np.int64)
    out_late = dispatch_lane_stats(late, schedule=sched, lanes=4, filled=4)
    assert out_late["executed_pull_frac"] == pytest.approx(1.0)


# ---- CLI validation (PR-6 satellite) -------------------------------------

def _parse(argv, capsys):
    """Parse + validate argv; returns the stderr of a rejection."""
    from repro.launch.serve import _build_parser, _validate_args
    ap = _build_parser()
    args = ap.parse_args(argv)
    with pytest.raises(SystemExit):
        _validate_args(ap, args)
    return capsys.readouterr().err


def test_cli_churn_without_dynamic_is_actionable(capsys):
    err = _parse(["--arch", "x", "--loop", "--churn-rate", "0.1"], capsys)
    assert "--churn-rate" in err and "--dynamic" in err
    assert "add --dynamic" in err


def test_cli_zero_deadline_rejected(capsys):
    err = _parse(["--arch", "x", "--loop", "--deadline-ms", "0"], capsys)
    assert "--deadline-ms" in err and "> 0" in err
    assert "--request-deadline-ms" in err       # points at the right knob


def test_cli_eps_floor_below_eps_rejected(capsys):
    err = _parse(["--arch", "x", "--loop", "--runtime",
                  "--eps", "0.3", "--eps-floor", "0.1"], capsys)
    assert "--eps-floor" in err and "relax" in err


def test_cli_eps_floor_requires_runtime(capsys):
    err = _parse(["--arch", "x", "--loop", "--eps-floor", "0.5"], capsys)
    assert "--runtime" in err


def test_cli_fault_injection_requires_runtime(capsys):
    err = _parse(["--arch", "x", "--loop",
                  "--inject-error-rate", "0.5"], capsys)
    assert "--inject-error-rate" in err and "--runtime" in err


def test_cli_flush_faults_require_dynamic(capsys):
    err = _parse(["--arch", "x", "--loop", "--runtime",
                  "--inject-flush-rate", "0.5"], capsys)
    assert "--dynamic" in err


def test_cli_valid_combination_passes():
    from repro.launch.serve import _build_parser, _validate_args
    ap = _build_parser()
    args = ap.parse_args(
        ["--arch", "x", "--loop", "--runtime", "--dynamic",
         "--churn-rate", "0.1", "--eps-floor", "0.5",
         "--inject-flush-rate", "0.2", "--pattern", "bursty"])
    _validate_args(ap, args)            # no SystemExit
