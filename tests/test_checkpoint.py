"""Checkpointer: roundtrip, atomicity, retention, resume semantics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (latest_step, list_steps,
                                           restore_checkpoint,
                                           save_checkpoint)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.normal(size=(3,)), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    restored, step = restore_checkpoint(str(tmp_path), t)
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_last(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep_last=2)
    assert list_steps(str(tmp_path)) == [4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_partial_write_invisible(tmp_path):
    """A crashed (un-renamed) tmp dir must never be restored from."""
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    os.makedirs(tmp_path / "step_00000009.tmp")  # simulated crash
    assert latest_step(str(tmp_path)) == 3
    _, step = restore_checkpoint(str(tmp_path), t)
    assert step == 3


def test_shape_mismatch_raises(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    bad = dict(t, a=jnp.zeros((5, 8)))
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_restore_empty_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), _tree())
