"""Adaptive early-exit cascade (ISSUE 5 tentpole, DESIGN.md §12).

Covers the acceptance criteria that need to run from a clean checkout:
``adaptive=False`` bit-identity with the pre-adaptive paths, bitwise
kernel/fallback parity with ``adaptive=True`` (fp32 and int8, hoeffding and
bernstein), actual early exit with correct results on easy instances, the
adversarial near-tie regression (a too-eager certification predicate must
not fire before the schedule's certified round), and the serve-engine /
sharded plumbing.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.boundedme_jax import (bounded_me_batched, bounded_me_blocked,
                                      bounded_me_decode, make_plan)
from repro.core.schedule import cert_coeffs, pulls_through_round


def _data(n, N, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, N)).astype(np.float32),
            rng.normal(size=N).astype(np.float32))


class TestAdaptiveOffBitIdentity:
    """adaptive=False must be bit-identical to not passing the kwarg at
    all — on the kernel and both fallbacks, fp32 and int8."""

    @pytest.mark.parametrize("precision", ["fp32", "int8"])
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_decode_off_is_bit_identical(self, precision, use_pallas):
        V, q = _data(192, 768, seed=3)
        Q = np.stack([q, -q, 0.25 * q])
        plan = make_plan(192, 768, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=96, precision=precision)
        key = jax.random.PRNGKey(11)
        for fe in (False, True):
            i0, s0 = bounded_me_decode(V, Q, key, plan=plan, final_exact=fe,
                                       use_pallas=use_pallas)
            i1, s1 = bounded_me_decode(V, Q, key, plan=plan, final_exact=fe,
                                       use_pallas=use_pallas, adaptive=False)
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))

    def test_default_bound_leaves_schedule_unchanged(self):
        """bound='hoeffding' must not perturb the static round plan (the
        adaptive=False bit-identity rests on this)."""
        a = make_plan(512, 4096, K=3, eps=0.1, delta=0.05, value_range=4.0)
        b = make_plan(512, 4096, K=3, eps=0.1, delta=0.05, value_range=4.0,
                      bound="hoeffding")
        assert a.schedule == b.schedule
        c = make_plan(512, 4096, K=3, eps=0.1, delta=0.05, value_range=4.0,
                      bound="bernstein")
        # bernstein reserves certification budget: never fewer pulls
        assert c.schedule.total_pulls >= a.schedule.total_pulls

    def test_blocked_off_is_bit_identical(self):
        V, q = _data(123, 300, seed=5)
        kw = dict(K=3, eps=0.25, delta=0.1, value_range=8.0, block=64)
        for use_pallas in (False, True):
            i0, s0, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                           use_pallas=use_pallas, **kw)
            i1, s1, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                           use_pallas=use_pallas,
                                           adaptive=False, **kw)
            np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
            np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


class TestAdaptiveParity:
    """Kernel (interpret) == jnp fallback, bitwise, with adaptive=True."""

    @pytest.mark.parametrize("precision", ["fp32", "int8"])
    @pytest.mark.parametrize("bound", ["hoeffding", "bernstein"])
    def test_decode_kernel_matches_fallback(self, precision, bound):
        V, q = _data(200, 1000, seed=1)
        Q = np.stack([q, -q, 0.5 * q])
        plan = make_plan(200, 1000, K=3, eps=0.15, delta=0.1,
                         value_range=8.0, block=256, precision=precision,
                         bound=bound)
        key = jax.random.PRNGKey(5)
        for fe in (False, True):
            ia, sa, ra = bounded_me_decode(V, Q, key, plan=plan,
                                           final_exact=fe, use_pallas=False,
                                           adaptive=True, k_out=4)
            ik, sk, rk = bounded_me_decode(V, Q, key, plan=plan,
                                           final_exact=fe, use_pallas=True,
                                           adaptive=True, k_out=4)
            np.testing.assert_array_equal(np.asarray(ia), np.asarray(ik))
            np.testing.assert_array_equal(np.asarray(sa), np.asarray(sk))
            np.testing.assert_array_equal(np.asarray(ra), np.asarray(rk))

    def test_batched_fused_matches_single_loop(self):
        V, q = _data(160, 640, seed=9)
        Q = np.stack([q, -q])
        plan = make_plan(160, 640, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=64, bound="bernstein")
        keys = jax.random.split(jax.random.PRNGKey(0), 2)
        ib, sb, rb = bounded_me_batched(V, Q, keys, plan=plan,
                                        adaptive=True, use_pallas=True)
        for b in range(2):
            iu, su, ru, _ = bounded_me_blocked(V, Q[b], keys[b], plan=plan,
                                               adaptive=True,
                                               use_pallas=True)
            np.testing.assert_array_equal(np.asarray(ib[b]), np.asarray(iu))
            np.testing.assert_array_equal(np.asarray(sb[b]), np.asarray(su))
            assert int(rb[b]) == int(ru)

    def test_adaptive_final_exact_scores_are_exact(self):
        """Early exit must not leak estimate scores through final_exact."""
        V, q = _data(200, 1000, seed=2)
        Q = np.stack([q, 0.3 * q])
        for precision in ("fp32", "int8"):
            plan = make_plan(200, 1000, K=3, eps=0.2, delta=0.1,
                             value_range=8.0, block=256, precision=precision)
            ids, scores, _ = bounded_me_decode(
                V, Q, jax.random.PRNGKey(1), plan=plan, final_exact=True,
                use_pallas=False, adaptive=True)
            for b in range(2):
                for i, s in zip(np.asarray(ids)[b], np.asarray(scores)[b]):
                    assert abs(s - float(V[i] @ Q[b]) / 1000.0) < 1e-5


class TestEarlyExit:
    """Non-saturated schedules (many coordinate blocks, eps matched to the
    effective range) where radii shrink gradually across rounds — the
    regime where adaptivity can actually save pulls."""

    N, n, block = 32768, 256, 64         # 512 blocks, 32 arm tiles
    eps, vr = 1.6, 8.0

    def _easy_instance(self, seed=0):
        """Huge top-1 margin (planted self-similar row): certifies early."""
        rng = np.random.default_rng(seed)
        V = rng.normal(size=(self.n, self.N)).astype(np.float32)
        q = rng.normal(size=self.N).astype(np.float32)
        V[7] = q                 # score ~ |q|^2/N ~ 1 vs noise ~ 1/sqrt(N)
        return V, q

    def test_easy_instance_exits_early_and_stays_correct(self):
        V, q = self._easy_instance()
        plan = make_plan(self.n, self.N, K=1, eps=self.eps, delta=0.05,
                         value_range=self.vr, block=self.block)
        n_rounds = len(plan.schedule.rounds)
        assert n_rounds >= 4
        # genuinely non-saturated: the last round still samples
        assert plan.schedule.rounds[-1].t_cum < plan.n_blocks
        ids, _, rounds, _ = bounded_me_blocked(
            V, q, jax.random.PRNGKey(0), plan=plan, adaptive=True,
            final_exact=True, use_pallas=False)
        assert int(np.asarray(ids)[0]) == 7
        assert int(rounds) < n_rounds          # actually exited early
        # the exit translates into a real pull saving (>= 30%)
        pulls = pulls_through_round(plan.schedule)
        assert pulls[int(rounds)] < 0.7 * pulls[-1]

    def test_hard_instance_runs_full_schedule_and_matches_nonadaptive(self):
        """No certification => rounds_used == n_rounds and outputs equal
        the non-adaptive ones bitwise (the frozen path is never taken)."""
        rng = np.random.default_rng(4)
        V = rng.normal(size=(self.n, self.N)).astype(np.float32)
        q = rng.normal(size=self.N).astype(np.float32)
        # top-2 near-tie far below every round's radius: never certifies
        V[0] = q
        V[8] = np.float32(1.0 - 1e-4) * q
        plan = make_plan(self.n, self.N, K=1, eps=self.eps, delta=0.05,
                         value_range=self.vr, block=self.block)
        assert plan.schedule.rounds[-1].t_cum < plan.n_blocks
        key = jax.random.PRNGKey(2)
        # kernel path: a never-fired adaptive query is bit-identical to the
        # non-adaptive run (the frozen path is never taken and the actual
        # pull count equals the scheduled one)
        i0, s0 = bounded_me_decode(V, q[None], key, plan=plan,
                                   final_exact=False, use_pallas=True)
        i1, s1, r1 = bounded_me_decode(V, q[None], key, plan=plan,
                                       final_exact=False, use_pallas=True,
                                       adaptive=True)
        assert int(np.asarray(r1)[0]) == len(plan.schedule.rounds)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        # jnp fallback: same ids/rounds; scores agree to float tolerance
        # only, because XLA strength-reduces the non-adaptive path's
        # compile-time-constant denominator while the adaptive path's
        # (traced t_stop) stays a true division
        i2, s2 = bounded_me_decode(V, q[None], key, plan=plan,
                                   final_exact=False, use_pallas=False)
        i3, s3, r3 = bounded_me_decode(V, q[None], key, plan=plan,
                                       final_exact=False, use_pallas=False,
                                       adaptive=True)
        assert int(np.asarray(r3)[0]) == len(plan.schedule.rounds)
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(i3))
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s3), rtol=1e-6)


class TestNearTieRegression:
    """ISSUE 5 satellite: a top-2 gap just under eps must not fire before
    the schedule's certified round (2 r_l <= gap)."""

    def _constant_rows_instance(self, n, N, tile, gap):
        # constant rows + all-ones query => zero reward variance and
        # exactly-known means: c1 for row 0, c1 - gap for row `tile`
        # (its own tile), 0 elsewhere
        V = np.zeros((n, N), np.float32)
        c1 = np.float32(0.5)
        V[0] = c1
        V[tile] = np.float32(c1 - gap)
        q = np.ones(N, np.float32)
        return V, q

    n, N, tile, block = 128, 32768, 8, 64      # 512 blocks: non-saturated
    eps, vr = 1.0, 4.0

    def test_exit_waits_for_certified_round(self):
        plan = make_plan(self.n, self.N, K=1, eps=self.eps, delta=0.05,
                         value_range=self.vr, tile=self.tile,
                         block=self.block)
        radii = cert_coeffs(plan.schedule)[:-1, 1]     # hoeffding: b_l only
        n_rounds = len(plan.schedule.rounds)
        assert n_rounds >= 4
        # pick a target round in the strictly-decreasing radius regime and
        # a gap strictly between its threshold and the previous round's
        lt = next(l for l in range(2, n_rounds) if radii[l] < radii[l - 1])
        gap = float(radii[lt] + radii[lt - 1])   # 2r_lt <= gap < 2r_{lt-1}
        assert gap < self.eps                    # a near-tie under eps
        V, q = self._constant_rows_instance(self.n, self.N, self.tile, gap)
        for use_pallas in (False, True):
            ids, _, rounds, _ = bounded_me_blocked(
                V, q, jax.random.PRNGKey(0), plan=plan, adaptive=True,
                final_exact=True, use_pallas=use_pallas)
            assert int(np.asarray(ids)[0]) == 0, use_pallas
            # fires exactly at the first round whose radius certifies the
            # gap — one round earlier would be unsound, later is waste
            assert int(rounds) == lt + 1, use_pallas

    def test_gap_above_first_threshold_fires_round_one(self):
        """Sanity inverse: a gap clearing 2 r_1 certifies immediately."""
        plan = make_plan(self.n, self.N, K=1, eps=self.eps, delta=0.05,
                         value_range=self.vr, tile=self.tile,
                         block=self.block)
        radii = cert_coeffs(plan.schedule)[:-1, 1]
        gap = float(2.5 * radii[0])
        V, q = self._constant_rows_instance(self.n, self.N, self.tile, gap)
        _, _, rounds, _ = bounded_me_blocked(
            V, q, jax.random.PRNGKey(0), plan=plan, adaptive=True,
            final_exact=True, use_pallas=False)
        assert int(rounds) == 1


class TestServeEngineAdaptive:
    def test_engine_reports_rounds_histogram(self):
        from repro.launch.serve import MIPSServeEngine

        rng = np.random.default_rng(0)
        table = 0.01 * rng.normal(size=(128, 256)).astype(np.float32)
        table[3] = 1.0
        eng = MIPSServeEngine(table, K=1, eps=0.1, delta=0.1, block=64,
                              batch_size=4, deadline_ms=0.0,
                              cache_entries=0, adaptive=True,
                              use_pallas=False)
        for i in range(8):
            eng.submit(np.float32(1.0 + 0.001 * i)
                       * table[3] + rng.normal(size=256).astype(np.float32)
                       * np.float32(0.001))
        eng.drain()
        st = eng.stats()["adaptive"]
        assert st["enabled"] and st["bound"] == "hoeffding"
        assert st["samples"] == 8
        assert sum(st["rounds_hist"].values()) == 8
        assert 0.0 < st["mean_pull_frac"] <= 1.0

    def test_engine_adaptive_off_stats_shape(self):
        from repro.launch.serve import MIPSServeEngine

        rng = np.random.default_rng(1)
        table = rng.normal(size=(64, 128)).astype(np.float32)
        eng = MIPSServeEngine(table, K=1, eps=0.2, block=64, batch_size=2,
                              deadline_ms=0.0, cache_entries=0,
                              use_pallas=False)
        eng.submit(rng.normal(size=128).astype(np.float32))
        eng.drain()
        st = eng.stats()["adaptive"]
        assert st == {"enabled": False, "bound": "hoeffding"}


_ENV_CODE_PREAMBLE = r"""
import os
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow
def test_sharded_adaptive_two_devices():
    """2-device sharded path: adaptive=False stays bit-identical to the
    single-device decode (transitively, to the PR-4 kernel), adaptive=True
    keeps the exact merge and reports per-shard rounds_used."""
    _run(r"""
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(0)
n, N, B, K = 512, 1024, 3, 3
V = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
key = jax.random.PRNGKey(7)
kw = dict(mesh=mesh, K=K, eps=1e-4, delta=0.05, value_range=8.0, block=128)
i0, s0, g0 = sharded_bounded_me_decode(V, Q, key, **kw)
i1, s1, g1 = sharded_bounded_me_decode(V, Q, key, adaptive=False, **kw)
np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
# int8, adaptive=False bit-identity too
i2, s2, _ = sharded_bounded_me_decode(V, Q, key, precision="int8", **kw)
i3, s3, _ = sharded_bounded_me_decode(V, Q, key, precision="int8",
                                      adaptive=False, **kw)
np.testing.assert_array_equal(np.asarray(i2), np.asarray(i3))
np.testing.assert_array_equal(np.asarray(s2), np.asarray(s3))
# adaptive on an easy instance: exact merge intact + rounds exported
V = 0.01 * np.asarray(rng.normal(size=(n, N)), np.float32)
qv = np.asarray(rng.normal(size=N), np.float32)
qv /= np.linalg.norm(qv)
V[5] = 0.9 * qv
V = jnp.asarray(V)
Qe = jnp.asarray(np.stack([qv, 1.1 * qv, 0.9 * qv]))
ia, sa, ga, rounds = sharded_bounded_me_decode(
    V, Qe, key, mesh=mesh, K=1, eps=0.1, delta=0.05, value_range=4.0,
    block=128, adaptive=True)
assert np.all(np.asarray(ia)[:, 0] == 5)
assert rounds.shape == (3, 2)
truth = (np.asarray(V) @ np.asarray(Qe).T).T[:, 5] / N
np.testing.assert_allclose(np.asarray(sa)[:, 0], truth, rtol=1e-5)
print("OK")
""")
