"""Per-arch smoke tests: reduced config, one forward + one train step on CPU.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); these instantiate the same family at reduced width/depth and
assert output shapes + finiteness.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.model import forward, init_params, logits_from_hidden
from repro.models.steps import decode_step, loss_fn, prefill_step, train_step
from repro.optim.adamw import AdamWConfig, init_opt

ARCHS = sorted(REGISTRY)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        b["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return b


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = REGISTRY[name].smoke()
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, smoke_state):
    cfg, params = smoke_state(arch)
    b = _batch(cfg)
    h, _ = forward(params, cfg, b["tokens"],
                   patch_embeds=b.get("patch_embeds"),
                   enc_frames=b.get("enc_frames"))
    assert h.shape == (2, 32, cfg.d_model)
    logits = logits_from_hidden(params, cfg, h)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, smoke_state):
    cfg, params = smoke_state(arch)
    b = _batch(cfg)
    opt = init_opt(params)
    p2, opt2, metrics = train_step(params, opt, b, cfg, AdamWConfig(lr=1e-3))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(bb, np.float32))
        for a, bb in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch, smoke_state):
    cfg, params = smoke_state(arch)
    b = _batch(cfg)
    kw = {k: b[k] for k in ("patch_embeds", "enc_frames") if k in b}
    hid, caches = prefill_step(params, cfg, b["tokens"][:, :16],
                               cache_len=32, **kw)
    assert hid.shape == (2, cfg.d_model)
    tok, caches = decode_step(params, cfg, caches, b["tokens"][:, 15:16],
                              jnp.int32(16))
    assert tok.shape == (2,)
    assert bool((tok >= 0).all()) and bool((tok < cfg.vocab).all())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "command-r-35b",
                                  "qwen3-moe-30b-a3b"])
def test_boundedme_decode_agrees_with_exact(arch, smoke_state):
    cfg, params = smoke_state(arch)
    b = _batch(cfg)
    _, caches = prefill_step(params, cfg, b["tokens"][:, :16], cache_len=32)
    cfg_b = dataclasses.replace(cfg, mips_mode="boundedme", mips_eps=0.05)
    cfg_e = dataclasses.replace(cfg, mips_mode="exact")
    tok_b, _ = decode_step(params, cfg_b, caches, b["tokens"][:, 15:16],
                           jnp.int32(16), key=jax.random.PRNGKey(3))
    tok_e, _ = decode_step(params, cfg_e, caches, b["tokens"][:, 15:16],
                           jnp.int32(16))
    assert np.array_equal(np.asarray(tok_b), np.asarray(tok_e))


def test_decode_consistency_all_families(smoke_state):
    """Cached decode == uncached forward (cf high to disable MoE drops)."""
    for arch in ("tinyllama-1.1b", "mamba2-130m", "whisper-medium",
                 "jamba-v0.1-52b", "qwen3-moe-30b-a3b", "internvl2-26b"):
        cfg, _ = smoke_state(arch)
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        params = init_params(cfg, jax.random.PRNGKey(1))
        b = _batch(cfg)
        kw = {k: b[k] for k in ("patch_embeds", "enc_frames") if k in b}
        S = 32
        h_full, _ = forward(params, cfg, b["tokens"], **kw)
        _, caches = forward(params, cfg, b["tokens"][:, :S - 1],
                            cache_len=S, **kw)
        h_dec, _ = forward(params, cfg, b["tokens"][:, S - 1:],
                           caches=caches, pos=jnp.int32(S - 1), **kw)
        err = float(jnp.abs(h_full[:, -1] - h_dec[:, 0]).max())
        assert err < 5e-4, f"{arch}: decode mismatch {err}"
