"""Tenant-isolation suite for the multi-tenant serving stack (ISSUE 10).

The contracts under test (DESIGN.md §16):

* **bit-identity** — a tenant served through `MultiTenantRuntime` gets
  answers bit-identical to a dedicated single-tenant `ServeRuntime`
  built from the same `TenantConfig` and seed;
* **flood isolation** — poison storms and overload from one tenant can
  only fill that tenant's private queue: a well-behaved tenant's
  answers stay bit-identical to a quiet run and its latency bounded;
* **residency round-trip** — evicting a table and paging it back in is
  bit-identical (rows, ids, version, value range, pq codebook, staged
  mutations) and never exceeds the byte budget;
* **fairness** — deficit-round-robin throttles a hot tenant to its
  weighted share instead of letting arrival skew starve cold tenants;
* **executor-cache coherence** — the regression this PR fixes:
  `grow()`, `refresh_codebook()` and page-in must each invalidate the
  per-table executor cache (the cache key is salted on store identity,
  capacity and codebook refreshes), so no request is ever answered by
  an executor calibrated against a dead table image.

The 2-device sharded case runs in a subprocess (same isolation rule as
tests/test_sharded_serve.py).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.launch.admission import DeficitRoundRobin, PriorityClass
from repro.launch.engine import CascadeExecutor, ServeRuntime
from repro.launch.tenancy import (MultiTenantRuntime, TableRegistry,
                                  TenancyError, TenantConfig)
from repro.store import DynamicTableStore

DIM = 96
LANES = 4


def _table(rows, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.normal(size=(rows, DIM)) / np.sqrt(DIM)
            ).astype(np.float32)


def _queries(n, seed):
    rng = np.random.default_rng(1000 + seed)
    return rng.normal(size=(n, DIM)).astype(np.float32)


def _dedicated(table, cfg: TenantConfig, queries, *, batch_wait_ms=1.0):
    """A dedicated single-tenant runtime serving the same contract."""
    rt = ServeRuntime(
        table, K=cfg.K, eps=cfg.eps, delta=cfg.delta,
        eps_floor=cfg.eps_floor, degrade_rungs=cfg.degrade_rungs,
        degrade_start=cfg.degrade_start, lanes=LANES,
        batch_wait_ms=batch_wait_ms, queue_capacity=cfg.queue_capacity,
        classes={"default": PriorityClass("default",
                                          priority=cfg.priority,
                                          deadline_ms=cfg.deadline_ms)},
        precision=cfg.precision, pull_mode=cfg.pull_mode,
        pq_subdims=cfg.pq_subdims, pq_codes=cfg.pq_codes,
        cache_entries=cfg.cache_entries,
        cache_resolution=cfg.cache_resolution, seed=cfg.seed)
    rt.warmup()
    rids = [rt.submit(q, now=float(i) * 0.01)
            for i, q in enumerate(queries)]
    rt.drain(now=10.0)
    return [rt.result(r) for r in rids]


class TestBitIdentity:
    def test_answers_match_dedicated_engines(self):
        """Two tenants with different contracts/precision through one
        MultiTenantRuntime == two dedicated ServeRuntimes, bitwise."""
        cfg_a = TenantConfig(K=3, eps=1.2, delta=0.2, deadline_ms=0.0,
                             seed=11)
        cfg_b = TenantConfig(K=2, eps=2.0, delta=0.2, precision="int8",
                             deadline_ms=0.0, seed=22)
        TA, TB = _table(96, 0), _table(80, 1)
        QA, QB = _queries(10, 0), _queries(10, 1)
        ref_a = _dedicated(TA, cfg_a, QA)
        ref_b = _dedicated(TB, cfg_b, QB)

        reg = TableRegistry(lanes=LANES)
        reg.register("a", TA, cfg_a)
        reg.register("b", TB, cfg_b)
        mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
        mt.warmup()
        rids = []
        for i in range(10):
            rids.append((mt.submit(QA[i], tenant="a", now=i * 0.01),
                         ref_a[i], "a"))
            rids.append((mt.submit(QB[i], tenant="b", now=i * 0.01),
                         ref_b[i], "b"))
        mt.drain(now=10.0)
        for rid, ref, name in rids:
            got = mt.result(rid)
            assert got.tenant == name
            assert got.status == ref.status
            np.testing.assert_array_equal(got.ids, ref.ids)
            np.testing.assert_array_equal(got.scores, ref.scores)

    def test_cache_hits_are_tenant_private(self):
        """The same query to two tenants must not cross-serve from the
        other tenant's LRU (per-tenant caches, per-tenant answers)."""
        cfg = TenantConfig(K=2, eps=1.5, delta=0.2, deadline_ms=0.0)
        reg = TableRegistry(lanes=LANES)
        reg.register("a", _table(64, 3), cfg)
        reg.register("b", _table(64, 4), cfg)
        mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
        mt.warmup()
        q = _queries(1, 9)[0]
        ra1 = mt.submit(q, tenant="a", now=0.0)
        mt.drain(now=1.0)
        first = mt.result(ra1)
        # same bytes again: a-hit must replay a's answer, b must compute
        # its own from its own table
        ra2 = mt.submit(q, tenant="a", now=2.0)
        rb = mt.submit(q, tenant="b", now=2.0)
        mt.drain(now=3.0)
        hit, fresh = mt.result(ra2), mt.result(rb)
        assert hit.cached and not fresh.cached
        np.testing.assert_array_equal(hit.ids, first.ids)
        assert not np.array_equal(np.sort(fresh.scores),
                                  np.sort(first.scores))


class TestFloodIsolation:
    def _serve_b(self, flood: bool):
        cfg_a = TenantConfig(K=2, eps=1.5, delta=0.2, deadline_ms=5.0,
                             queue_capacity=8, seed=1)
        cfg_b = TenantConfig(K=2, eps=1.5, delta=0.2, deadline_ms=0.0,
                             seed=2)
        reg = TableRegistry(lanes=LANES)
        reg.register("a", _table(64, 5), cfg_a)
        reg.register("b", _table(64, 6), cfg_b)
        mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
        mt.warmup()
        QB = _queries(12, 2)
        flood_q = _queries(1, 3)[0]
        poison = np.full(DIM, np.nan, np.float32)
        b_rids, t = [], 0.0
        for i in range(12):
            if flood:
                # tenant a: a poison storm plus a burst past its private
                # queue's capacity, all at once
                for j in range(12):
                    if j < 6:
                        mt.submit(poison, tenant="a", now=t)
                    mt.submit(flood_q + np.float32(i + j), tenant="a",
                              now=t)
            b_rids.append(mt.submit(QB[i], tenant="b", now=t))
            # poll past batch_wait so b's fresh request dispatches alone
            # in BOTH runs (identical batch composition, the bit-identity
            # precondition)
            done, busy = mt.poll(now=t + 0.0015)
            t += 0.004 + busy
        mt.drain(now=t + 1.0)
        results = [mt.result(r) for r in b_rids]
        return results, mt.stats()

    def test_poison_overload_flood_leaves_b_bit_identical(self):
        quiet, _ = self._serve_b(flood=False)
        flooded, stats = self._serve_b(flood=True)
        # the flood really stressed tenant a...
        a = stats["tenants"]["a"]["outcomes"]
        assert a["rejected"] > 0            # poison refused at admission
        assert a["overloaded"] > 0          # queue bound displaced/shed
        # ...while every b answer is the same bits as the quiet run
        for q, f in zip(quiet, flooded):
            assert q.answered and f.answered
            np.testing.assert_array_equal(q.ids, f.ids)
            np.testing.assert_array_equal(q.scores, f.scores)
        b = stats["tenants"]["b"]
        assert b["outcomes"]["ok"] + b["outcomes"]["degraded"] == 12
        # b's tail latency stays bounded on the virtual clock: the flood
        # can cost b at most its DRR-share of batch waits + dispatches,
        # not a queue collapse
        assert b["latency_ms"]["p99"] < 250.0


class TestResidency:
    def test_eviction_pagein_roundtrip_bit_identical(self):
        """Evict + page-in preserves rows, ids, version, codebook and
        staged mutations; answers before == answers after, bitwise."""
        rows = _table(64, 7)
        store = DynamicTableStore(rows, precision="pq", pq_subdims=8)
        store.upsert(3, rows[5])            # mutate: version bump
        store.flush_updates()
        store.refresh_codebook()
        store.append(rows[0] * 0.5)         # staged, NOT flushed: must
        store.upsert(7, rows[9])            # survive the page round-trip
        cfg = TenantConfig(K=2, eps=2.0, delta=0.2, precision="pq",
                           deadline_ms=0.0)
        reg = TableRegistry(lanes=LANES)
        reg.register("t", store, cfg)
        execs, _ = reg.executors("t")
        key = jax.random.PRNGKey(0)
        Qb = np.zeros((LANES, DIM), np.float32)
        Qb[0] = _queries(1, 4)[0]
        ids0, sc0, _, _ = execs[0].dispatch(Qb, key)

        before = dict(version=store.version, staged=store.pending_updates,
                      host=store.host_table().copy(),
                      codebook=np.array(store.codebook()),
                      snap=store.snapshot())
        reg.evict("t")
        assert not reg.is_resident("t") and reg.store("t") is None
        dt = reg.ensure_resident("t")
        assert dt >= 0.0
        st2 = reg.store("t")
        assert st2 is not store
        assert st2.version == before["version"]
        assert st2.pending_updates == before["staged"]
        np.testing.assert_array_equal(st2.host_table(), before["host"])
        np.testing.assert_array_equal(np.array(st2.codebook()),
                                      before["codebook"])
        r2, i2 = st2.snapshot()
        np.testing.assert_array_equal(r2, before["snap"][0])
        np.testing.assert_array_equal(i2, before["snap"][1])
        # a fresh executor ladder (page-in salted the cache) must serve
        # the same bits
        execs2, _ = reg.executors("t")
        assert execs2[0] is not execs[0]
        ids1, sc1, _, _ = execs2[0].dispatch(Qb, key)
        np.testing.assert_array_equal(np.asarray(ids0), np.asarray(ids1))
        np.testing.assert_array_equal(np.asarray(sc0), np.asarray(sc1))
        assert reg.executor_builds("t").get("page_in") == 1

    def test_budget_never_exceeded_and_typed_refusal(self):
        one = DynamicTableStore(_table(64, 8)).resident_bytes()
        reg = TableRegistry(byte_budget=int(2.4 * one), lanes=LANES)
        reg.register("a", _table(64, 8))
        reg.register("b", _table(64, 9))
        reg.register("c", _table(64, 10))   # must evict, not OOM
        assert reg.resident_bytes() <= reg.byte_budget
        assert [reg.is_resident(n) for n in ("a", "b", "c")] \
            == [False, True, True]
        # pinned + in-flight tables are not eviction candidates
        reg.pin("b")
        with pytest.raises(TenancyError):
            reg.evict("b")
        with reg.serving("c"):
            with pytest.raises(TenancyError):
                reg.evict("c")
            # nothing evictable: a new table must be refused, pool intact
            with pytest.raises(TenancyError):
                reg.register("d", _table(64, 11))
        assert reg.tenants() == ["a", "b", "c"]
        assert reg.resident_bytes() <= reg.byte_budget
        # a table bigger than the whole budget is refused up front
        with pytest.raises(TenancyError):
            reg.register("huge", _table(4096, 12))


class TestFairness:
    def test_drr_unit_weighted_shares(self):
        drr = DeficitRoundRobin(4)
        for n, w in (("a", 1.0), ("b", 1.0), ("c", 2.0)):
            drr.add_flow(n, w)
        served = {n: 0 for n in "abc"}
        backlog = {n: 10_000 for n in "abc"}
        for _ in range(100):
            drr.start_round({n: backlog[n] > 0 for n in "abc"})
            for n in drr.flows():
                while drr.allowance(n) >= 1 and backlog[n] > 0:
                    take = min(4, drr.allowance(n), backlog[n])
                    drr.consume(n, take)
                    served[n] += take
                    backlog[n] -= take
            drr.rotate()
        assert served["a"] == served["b"]
        assert abs(served["c"] / served["a"] - 2.0) < 0.05

    def test_drr_idle_flow_cannot_hoard_deficit(self):
        """cap_rounds bounds the burst an idle-then-flooding flow gets."""
        drr = DeficitRoundRobin(4, cap_rounds=2.0)
        drr.add_flow("idle")
        for _ in range(50):
            drr.start_round({"idle": True})
        assert drr.allowance("idle") <= 8   # 2 rounds' worth, not 50
        drr.reset("idle")
        assert drr.allowance("idle") == 0

    def test_hot_tenant_throttled_not_starving(self):
        """12x arrival skew past the hot tenant's queue bound: cold
        tenants keep answering everything, the hot tenant is shed down
        to what its private queue holds but never starved."""
        reg = TableRegistry(lanes=LANES)
        for name, seed in (("hot", 20), ("c1", 21), ("c2", 22)):
            reg.register(name, _table(64, seed),
                         TenantConfig(K=2, eps=1.5, delta=0.2,
                                      deadline_ms=100.0,
                                      queue_capacity=8, seed=seed))
        mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
        mt.warmup()
        rng = np.random.default_rng(42)
        t = 0.0
        for i in range(15):
            for _ in range(12):
                mt.submit(rng.normal(size=DIM).astype(np.float32),
                          tenant="hot", now=t)
            mt.submit(rng.normal(size=DIM).astype(np.float32),
                      tenant="c1", now=t)
            mt.submit(rng.normal(size=DIM).astype(np.float32),
                      tenant="c2", now=t)
            _, busy = mt.poll(now=t + 0.0015)
            t += 0.004 + busy
        mt.drain(now=t + 1.0)
        s = mt.stats()["tenants"]

        def answered(n):
            return s[n]["outcomes"]["ok"] + s[n]["outcomes"]["degraded"]

        assert answered("c1") == 15 and answered("c2") == 15
        assert answered("hot") >= 30            # throttled, not starved
        assert s["hot"]["outcomes"]["overloaded"] > 0   # skew was shed
        assert s["c1"]["outcomes"]["overloaded"] == 0
        assert s["c2"]["outcomes"]["overloaded"] == 0
        # closed outcome set per tenant: every request typed exactly once
        for n in ("hot", "c1", "c2"):
            assert sum(s[n]["outcomes"].values()) == s[n]["requests"]


class TestExecutorCacheCoherence:
    """The stale-executor regression: every store transition that
    invalidates a compiled plan must miss the executor cache."""

    def _fresh_answer(self, store, cfg, q):
        ex = CascadeExecutor(store, K=cfg.K, eps=cfg.eps, delta=cfg.delta,
                             lanes=LANES, precision=cfg.precision,
                             pq_subdims=cfg.pq_subdims,
                             pq_codes=cfg.pq_codes)
        Qb = np.zeros((LANES, DIM), np.float32)
        Qb[0] = q
        key = jax.random.PRNGKey(0)
        ids, sc, _, _ = ex.dispatch(Qb, key)
        return np.asarray(ids[0]), np.asarray(sc[0])

    def test_refresh_codebook_invalidates(self):
        """refresh_codebook() must rebuild (re-measuring pq quant_err
        against the new codebook) — the pre-PR-10 cache would keep the
        old executor because capacity and value range are unchanged."""
        rows = _table(64, 30)
        store = DynamicTableStore(rows, precision="pq", pq_subdims=8)
        cfg = TenantConfig(K=2, eps=2.0, delta=0.2, precision="pq",
                           deadline_ms=0.0)
        reg = TableRegistry(lanes=LANES)
        reg.register("t", store, cfg)
        e0 = reg.executors("t")[0][0]
        # shift the corpus then retrain: the frozen codebook (and the
        # quant_err measured against it) is now for a different table
        for i in range(32):
            store.upsert(i, (rows[i] * 3.0).astype(np.float32))
        store.flush_updates()
        store.refresh_codebook()
        execs, _ = reg.executors("t")
        assert execs[0] is not e0, "stale executor served after retrain"
        assert reg.executor_builds("t").get("codebook_refresh") == 1
        # zero stale answers: cached path == freshly built executor
        q = _queries(1, 31)[0]
        Qb = np.zeros((LANES, DIM), np.float32)
        Qb[0] = q
        key = jax.random.PRNGKey(0)
        got_ids, got_sc, _, _ = execs[0].dispatch(Qb, key)
        ref_ids, ref_sc = self._fresh_answer(store, cfg, q)
        np.testing.assert_array_equal(np.asarray(got_ids)[0], ref_ids)
        np.testing.assert_array_equal(np.asarray(got_sc)[0], ref_sc)

    def test_grow_invalidates(self):
        store = DynamicTableStore(_table(64, 32), capacity=72)
        reg = TableRegistry(lanes=LANES)
        reg.register("t", store, TenantConfig(K=2, eps=1.5, delta=0.2,
                                              deadline_ms=0.0))
        e0 = reg.executors("t")[0][0]
        store.grow(256)
        execs, _ = reg.executors("t")
        assert execs[0] is not e0
        assert execs[0].n == store.capacity_rows
        assert reg.executor_builds("t").get("grow") == 1

    def test_cache_bounded_and_rebuilds_after_lru_eviction(self):
        reg = TableRegistry(lanes=LANES, max_executors=2)
        for name, seed in (("a", 40), ("b", 41), ("c", 42)):
            reg.register(name, _table(48, seed),
                         TenantConfig(K=1, eps=2.0, delta=0.3,
                                      deadline_ms=0.0))
        for name in ("a", "b", "c"):
            reg.executors(name)
            assert reg.executor_cache_size() <= 2
        # "a" was LRU-evicted from the cache; re-acquiring rebuilds and
        # still serves (bounded jit cache is the only cost)
        execs, _ = reg.executors("a")
        assert reg.executor_builds("a").get("cache_evicted") == 1
        assert reg.executor_cache_size() <= 2

    def test_runtime_serves_fresh_answers_across_grow(self):
        """End-to-end: a runtime tenant whose store grows mid-stream
        must serve post-grow queries against the grown capacity."""
        store = DynamicTableStore(_table(48, 50), capacity=56)
        reg = TableRegistry(lanes=LANES)
        reg.register("t", store, TenantConfig(K=2, eps=1.5, delta=0.2,
                                              deadline_ms=0.0, seed=5))
        mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
        mt.warmup()
        r1 = mt.submit(_queries(1, 51)[0], tenant="t", now=0.0)
        mt.drain(now=1.0)
        assert mt.result(r1).answered
        store.grow(128)
        big = _table(1, 52)[0] * 10.0       # new row that should win
        store.append(big)
        r2 = mt.submit(big, tenant="t", now=2.0)
        mt.drain(now=3.0)
        res = mt.result(r2)
        assert res.answered
        new_id = int(store.live_ids().max())
        assert new_id in np.asarray(res.ids), \
            "post-grow row invisible: stale executor answered"


_ENV_CODE_PREAMBLE = r"""
import os
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow
def test_sharded_tenant_two_devices():
    """A 2-device sharded tenant + a single-device tenant in one
    registry: the sharded tenant is auto-pinned (never evicted), both
    serve exact answers at tiny eps through the same runtime."""
    _run(r"""
from repro.launch.tenancy import (MultiTenantRuntime, TableRegistry,
                                  TenancyError, TenantConfig)
from repro.store import ShardedTableStore
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(0)
dim = 128
VS = rng.normal(size=(256, dim)).astype(np.float32)
VD = rng.normal(size=(96, dim)).astype(np.float32)
store = ShardedTableStore(VS, mesh=mesh)
reg = TableRegistry(lanes=2)
reg.register("sharded", store, TenantConfig(
    K=3, eps=1e-4, delta=0.05, deadline_ms=0.0, seed=1), mesh=mesh)
reg.register("local", VD, TenantConfig(
    K=3, eps=1e-4, delta=0.05, deadline_ms=0.0, seed=2))
assert reg.is_pinned("sharded") and not reg.is_pinned("local")
try:
    reg.evict("sharded")
    raise SystemExit("sharded table must refuse eviction")
except TenancyError:
    pass
mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
mt.warmup()
Q = rng.normal(size=(4, dim)).astype(np.float32)
rids = [(mt.submit(q, tenant=("sharded" if i % 2 == 0 else "local"),
                   now=i * 0.01), i) for i, q in enumerate(Q)]
mt.drain(now=1.0)
for rid, i in rids:
    res = mt.result(rid)
    assert res.answered, res.status
    V = VS if i % 2 == 0 else VD
    truth = np.argsort(-(V @ Q[i]))[:3]
    np.testing.assert_array_equal(np.sort(res.ids), np.sort(truth))
s = mt.stats()
assert s["outcomes"]["ok"] == 4
assert s["registry"]["tenants"]["sharded"]["sharded"] is True
print("OK")
""")
