"""Smoke test: the pull-loop roofline is importable and measured (ISSUE 7).

The seed shipped a dry-run-artifact roofline that was never wired to the
MIPS workload; satellite 4 of ISSUE 7 replaces it with the pull-loop
model.  This pins the module contract: ``analyse`` prices a plan's
per-pull HBM traffic for BOTH pull modes (coord must move strictly fewer
bytes per pull than row at the default widths), every cell is
memory-bound against the v5e machine balance, and ``run()`` returns the
BENCH_PR7 roofline payload with host-measured timings.
"""

import sys
from os.path import dirname, join

import pytest

sys.path.insert(0, join(dirname(__file__), ".."))

from benchmarks import roofline  # noqa: E402
from repro.core.boundedme_jax import make_plan  # noqa: E402


def test_analyse_prices_both_pull_modes():
    kw = dict(K=2, eps=3.0, delta=0.1, value_range=2.0, range_mode="exact")
    row = roofline.analyse(make_plan(1024, 8192, pull_mode="row", **kw))
    coord = roofline.analyse(
        make_plan(1024, 8192, pull_mode="coord", coord_block=128, **kw))
    # a coord pull DMAs a 128-wide slab where a row pull DMAs 512
    assert coord["bytes_per_pull"] * 4 == row["bytes_per_pull"]
    assert coord["flops_per_pull"] * 4 == row["flops_per_pull"]
    # and the schedule-level totals keep the ordering at this d
    assert coord["total_bytes"] < row["total_bytes"]
    for cell in (row, coord):
        assert cell["bound"] == "memory"
        assert cell["intensity_flops_per_byte"] < cell["machine_balance"]
        assert cell["t_mem_floor_s"] > cell["t_compute_s"]


def test_int8_accounts_for_scales():
    kw = dict(K=2, eps=3.0, delta=0.1, value_range=2.0, range_mode="exact")
    fp32 = roofline.analyse(make_plan(1024, 2048, pull_mode="row", **kw))
    int8 = roofline.analyse(
        make_plan(1024, 2048, pull_mode="row", precision="int8", **kw))
    # int8 table slab is 4x smaller but carries tile+1 fp32 scales
    assert int8["bytes_per_pull"] < fp32["bytes_per_pull"]
    scales = (int8["tile"] + 1) * 4
    assert int8["bytes_per_pull"] == \
        int8["tile"] * int8["block"] + int8["block"] * 4 + scales


@pytest.mark.slow
def test_run_returns_measured_payload():
    payload = roofline.run(csv=False)
    assert payload["hybrid_resolves_to"] in ("row", "coord")
    assert len(payload["cells"]) == 4
    for cell in payload["cells"]:
        assert cell["measured_ms_host"] > 0.0
        assert cell["achieved_bytes_per_s_host"] > 0.0
    assert 0.0 < payload["coord_bytes_ratio"] < 1.0
    assert roofline.table(payload).count("|") > 20
