"""Pull-mode plumbing: coord geometry, hybrid dispatch, serving (ISSUE 7).

The statistical contract of the coordinate estimator is certified by
`tests/test_guarantees.py` and its kernel parity by
`tests/test_fuzz_cascade.py`; this file pins everything around those —
the schedule-level cost model (`Schedule.total_coords`), plan geometry,
the `choose_pull_mode` decision rule, end-to-end correctness through
`mips_topk`, the serving engines (including the int8-store-shadow
incompatibility, rejected at construction), the serve CLI validation,
and the shard-local coord schedules of `sharded_bounded_me_decode`
(subprocess, 2 fake CPU devices — same isolation rule as
tests/test_sharded_serve.py).
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.boundedme_jax import choose_pull_mode, make_plan
from repro.core.mips import mips_topk
from repro.core.schedule import make_schedule

_ENV_CODE_PREAMBLE = r"""
import os
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


class TestScheduleCostModel:
    def test_total_coords_is_width_weighted(self):
        s = make_schedule(64, 32, K=2, eps=0.5, delta=0.1, value_range=1.0,
                          pull_mode="coord", pull_width=128)
        assert s.pull_mode == "coord"
        assert s.total_coords == s.total_pulls * 128

    def test_row_default_width_one(self):
        s = make_schedule(64, 32, K=2, eps=0.5, delta=0.1, value_range=1.0)
        assert s.pull_mode == "row" and s.pull_width == 1
        assert s.total_coords == s.total_pulls

    def test_hybrid_rejected_at_schedule_level(self):
        with pytest.raises(ValueError, match="resolved by make_plan"):
            make_schedule(64, 32, pull_mode="hybrid")

    def test_unknown_mode_and_bad_width_rejected(self):
        with pytest.raises(ValueError, match="unknown pull_mode"):
            make_schedule(64, 32, pull_mode="diag")
        with pytest.raises(ValueError, match="pull_width"):
            make_schedule(64, 32, pull_width=0)


class TestPlanGeometry:
    def test_coord_plan_reblocks_the_feature_axis(self):
        d, cb = 1000, 128
        p = make_plan(256, d, K=2, eps=0.5, delta=0.1, pull_mode="coord",
                      coord_block=cb)
        assert p.pull_mode == "coord"
        assert p.block == cb
        assert p.n_blocks == -(-d // cb)
        assert p.schedule.pull_mode == "coord"
        assert p.schedule.pull_width == p.block

    def test_coord_block_clamped_to_dim(self):
        p = make_plan(64, 48, K=1, pull_mode="coord", coord_block=128)
        assert p.block == 48 and p.n_blocks == 1

    def test_row_plan_unchanged_by_coord_block(self):
        a = make_plan(256, 2048, K=2, pull_mode="row", coord_block=16)
        b = make_plan(256, 2048, K=2, pull_mode="row", coord_block=128)
        assert a == b and a.block == 512

    def test_unknown_pull_mode_rejected(self):
        with pytest.raises(ValueError, match="unknown pull_mode"):
            make_plan(64, 128, pull_mode="diag")
        with pytest.raises(ValueError, match="coord_block"):
            make_plan(64, 128, pull_mode="coord", coord_block=0)


class TestHybridDispatch:
    def test_margin_rule(self):
        row = make_plan(1024, 8192, K=2, eps=3.0, delta=0.1,
                        value_range=2.0, range_mode="exact",
                        pull_mode="row")
        coord = make_plan(1024, 8192, K=2, eps=3.0, delta=0.1,
                          value_range=2.0, range_mode="exact",
                          pull_mode="coord")
        assert coord.total_multiplies < row.total_multiplies / 1.10
        assert choose_pull_mode(row, coord) == "coord"
        # with an enormous margin, row always wins ties
        assert choose_pull_mode(row, coord, row_margin=10.0) == "row"
        # identical plans: row wins at any nonnegative margin
        assert choose_pull_mode(row, row, row_margin=0.0) == "row"
        with pytest.raises(ValueError, match="row_margin"):
            choose_pull_mode(row, coord, row_margin=-0.1)

    def test_hybrid_never_worse_than_best_by_margin(self):
        for d in (128, 512, 2048, 8192):
            kw = dict(K=2, eps=3.0, delta=0.1, value_range=2.0,
                      range_mode="exact")
            row = make_plan(1024, d, pull_mode="row", **kw)
            coord = make_plan(1024, d, pull_mode="coord", **kw)
            hyb = make_plan(1024, d, pull_mode="hybrid", **kw)
            best = min(row.total_multiplies, coord.total_multiplies)
            assert hyb.total_multiplies <= 1.10 * best
            assert hyb == (row if hyb.pull_mode == "row" else coord)


class TestEndToEnd:
    def test_mips_topk_all_modes_find_the_winner(self):
        rng = np.random.default_rng(0)
        n, d, K = 200, 777, 3
        V = rng.normal(size=(n, d)).astype(np.float32)
        q = rng.normal(size=(d,)).astype(np.float32)
        truth = np.argsort(-(V @ q))[:K]
        for pm in ("row", "coord", "hybrid"):
            ids, scores = mips_topk(V, q, K, eps=1e-4, delta=0.05,
                                    value_range=8.0, final_exact=True,
                                    pull_mode=pm, coord_block=128)
            np.testing.assert_array_equal(np.sort(np.asarray(ids)),
                                          np.sort(truth)), pm
            np.testing.assert_allclose(np.asarray(scores),
                                       (V @ q)[np.asarray(ids)] / d,
                                       rtol=1e-5), pm


class TestServingEngines:
    def _workload(self, seed=0, n=128, d=512):
        rng = np.random.default_rng(seed)
        V = rng.normal(size=(n, d)).astype(np.float32)
        Q = rng.normal(size=(8, d)).astype(np.float32)
        return V, Q

    @pytest.mark.parametrize("pull_mode", ["coord", "hybrid"])
    def test_engine_serves_and_records_resolved_mode(self, pull_mode):
        from repro.launch.engine import MIPSServeEngine

        V, Q = self._workload()
        eng = MIPSServeEngine(V, K=2, eps=1e-4, delta=0.1, value_range=8.0,
                              batch_size=4, pull_mode=pull_mode,
                              coord_block=128)
        assert eng.plan.pull_mode in ("row", "coord")
        if pull_mode == "coord":
            assert eng.plan.pull_mode == "coord"
            assert eng.plan.block == 128
        rids = [eng.submit(q) for q in Q]
        eng.drain()
        truth = np.argsort(-(V @ Q.T), axis=0)[:2].T
        for b, rid in enumerate(rids):
            ids, _ = eng.result(rid)
            assert sorted(ids.tolist()) == sorted(truth[b].tolist())

    def test_runtime_hybrid_resolves_per_rung(self):
        from repro.launch.engine import ServeRuntime

        V, _ = self._workload()
        rt = ServeRuntime(V, K=2, eps=0.4, eps_floor=1.6, degrade_rungs=3,
                          delta=0.1, value_range=8.0, lanes=4,
                          pull_mode="hybrid")
        for ex in rt._rung_execs:
            assert ex.plan.pull_mode in ("row", "coord")

    def test_int8_store_shadow_rejects_non_row(self):
        from repro.launch.engine import MIPSServeEngine
        from repro.store import DynamicTableStore

        V, _ = self._workload()
        store = DynamicTableStore(V, block=128, precision="int8")
        with pytest.raises(ValueError, match="int8 store shadow"):
            MIPSServeEngine(store, K=2, pull_mode="coord")
        with pytest.raises(ValueError, match="int8 store shadow"):
            MIPSServeEngine(store, K=2, pull_mode="hybrid")
        # row still works, and fp32 stores take any mode
        MIPSServeEngine(store, K=2, pull_mode="row")
        fp32_store = DynamicTableStore(V, block=128, precision="fp32")
        eng = MIPSServeEngine(fp32_store, K=2, eps=1e-4, value_range=8.0,
                              pull_mode="coord")
        assert eng.plan.pull_mode == "coord"

    def test_cli_rejects_int8_dynamic_coord(self):
        from repro.launch.serve import _build_parser, _validate_args

        ap = _build_parser()
        argv = ["--arch", "tiny", "--loop", "--dynamic",
                "--precision", "int8", "--pull-mode", "coord"]
        with pytest.raises(SystemExit):
            _validate_args(ap, ap.parse_args(argv))
        # sharded int8 quantizes in-jit at the plan's geometry: allowed
        args = ap.parse_args(argv + ["--shards", "2"])
        _validate_args(ap, args)
        # and fp32 dynamic coord is fine
        args = ap.parse_args(["--arch", "tiny", "--loop", "--dynamic",
                              "--pull-mode", "coord"])
        _validate_args(ap, args)


@pytest.mark.slow
def test_sharded_decode_coord_matches_single_device():
    """Shard-local coordinate schedules, exact cross-shard merge: the
    2-device sharded coord path must return the true top-K with exact
    scores, and agree with the single-device coord decode path."""
    _run(r"""
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(0)
n, N, B, K = 512, 1024, 3, 3
V = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
key = jax.random.PRNGKey(7)
for mode in ("coord", "hybrid"):
    i2, s2, gaps = sharded_bounded_me_decode(
        V, Q, key, mesh=mesh, K=K, eps=1e-4, delta=0.05, value_range=8.0,
        block=128, pull_mode=mode, coord_block=128)
    truth = np.argsort(-(np.asarray(V) @ np.asarray(Q).T), axis=0)[:K].T
    exact = np.take_along_axis(
        (np.asarray(V) @ np.asarray(Q).T).T / N, truth, axis=1)
    assert np.array_equal(np.sort(np.asarray(i2), axis=1),
                          np.sort(truth, axis=1)), mode
    order = np.argsort(-np.asarray(s2), axis=1)
    np.testing.assert_allclose(
        np.sort(np.asarray(s2), axis=1)[:, ::-1], exact, rtol=1e-5)
print("OK")
""")
