"""`stats()` byte-compat regression suite for the PR-9 observability layer.

The observability tentpole migrates every serving-stack counter onto the
`repro.obs.metrics` registry with the hard constraint that every existing
``stats()`` dict stays *byte-compatible*: same keys, same order, same
deterministic values.  This suite pins that contract against a golden
snapshot (``tests/data/golden_stats_pr9.json``) captured from the
pre-observability code on fixed seeds and an explicit virtual clock.

Timing-derived leaves (latency percentiles, rows/s throughput) are
scrubbed to a type marker before comparison — their *keys and key order*
are still pinned, only the wall-clock-dependent values are not.

Regenerate (only when a PR intentionally extends a stats surface) with::

    PYTHONPATH=src python tests/test_obs_regression.py --write
"""

import json
import os

import numpy as np

from repro.launch.admission import PriorityClass
from repro.launch.faults import FaultInjector
from repro.launch.serve import MIPSServeEngine, ServeRuntime
from repro.store import DynamicTableStore

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_stats_pr9.json")

DIM = 16

#: keys whose values are wall-clock measurements (scrubbed to a type
#: marker; key presence and order still compared)
_TIMING_SUBTREES = ("latency_ms",)
_TIMING_LEAVES = ("rows_per_s",)
#: float leaves that depend on served scores (platform-sensitive at the
#: ulp level); presence pinned, value scrubbed
_SCORE_LEAVES = ("mean",)          # recall.mean only (see _scrub)
#: keys PR 9 *added* to FaultInjector.stats() (the per-kind seen/rates
#: satellite); dropped from `got` before comparing with the pre-PR
#: golden — everything else must still match byte-for-byte
_ADDITIVE_KEYS = ("seen", "rates")


def _drop_additive(obj):
    """Recursively remove the PR-9 additive stats keys from a payload."""
    if isinstance(obj, dict):
        return {k: _drop_additive(v) for k, v in obj.items()
                if k not in _ADDITIVE_KEYS}
    if isinstance(obj, list):
        return [_drop_additive(v) for v in obj]
    return obj


def _scrub(obj, path=()):
    """Replace timing-derived leaves with a type marker, keep structure."""
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            if k in _TIMING_SUBTREES and isinstance(v, dict):
                out[k] = {kk: "<float>" for kk in v}
            elif k in _TIMING_LEAVES:
                out[k] = "<float>"
            elif path and path[-1] == "recall" and k in _SCORE_LEAVES:
                out[k] = "<float>"
            else:
                out[k] = _scrub(v, path + (k,))
        return out
    if isinstance(obj, list):
        return [_scrub(v, path) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return "<nonfinite>"
    if isinstance(obj, float):
        return round(obj, 9)
    return obj


# ---- deterministic scenarios -------------------------------------------

def engine_scenario() -> dict:
    """Micro-batching engine: full + deadline flushes, a cache hit."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(64, DIM)).astype(np.float32)
    eng = MIPSServeEngine(table, K=2, eps=0.3, delta=0.2, batch_size=4,
                          deadline_ms=5.0, cache_entries=8,
                          recall_sample_rate=0.5, seed=0)
    qs = rng.normal(size=(10, DIM)).astype(np.float32)
    qs[7] = qs[0]                                   # exact-repeat: LRU hit
    for i in range(10):
        eng.submit(qs[i], now=i * 1e-3)
        eng.poll(now=i * 1e-3)
    eng.drain(now=1.0)
    return eng.stats()


def store_engine_scenario() -> dict:
    """Store-backed engine: staged churn, int8 shadow, recalibration."""
    rng = np.random.default_rng(1)
    st = DynamicTableStore(rng.normal(size=(24, DIM)).astype(np.float32),
                           tile=8, block=DIM, precision="int8",
                           capacity_slack=2.0)
    eng = MIPSServeEngine(st, K=2, eps=0.3, delta=0.2, batch_size=2,
                          deadline_ms=1.0, cache_entries=4,
                          recall_sample_rate=0.0, seed=0)
    qs = rng.normal(size=(8, DIM)).astype(np.float32)
    for i in range(8):
        if i % 2 == 0:
            st.upsert(i, rng.normal(size=DIM).astype(np.float32))
        if i == 5:
            st.delete(0)
        eng.submit(qs[i], now=i * 1e-3)
        eng.poll(now=i * 1e-3 + 5e-4)
    eng.drain(now=1.0)
    return {"engine": eng.stats(), "store": st.stats()}


def runtime_scenario() -> dict:
    """Runtime under everything at once: poison, displacement, faults,
    degradation, deadline expiry, store flush failures, quarantine."""
    rng = np.random.default_rng(2)
    table = rng.normal(size=(96, DIM)).astype(np.float32)
    store = DynamicTableStore(table, tile=8, block=DIM,
                              capacity_slack=1.5)
    inj = FaultInjector(7, latency_rate=0.3, latency_ms=2.0,
                        error_rate=0.3, persistent_rate=0.5,
                        flush_failure_rate=0.5)
    classes = {
        "interactive": PriorityClass("interactive", priority=0,
                                     deadline_ms=5000.0, sheddable=False),
        "default": PriorityClass("default", priority=1,
                                 deadline_ms=5000.0),
        "batch": PriorityClass("batch", priority=2, deadline_ms=100.0),
    }
    rt = ServeRuntime(store, K=2, eps=0.3, delta=0.2, eps_floor=1.2,
                      degrade_rungs=3, lanes=2, batch_wait_ms=0.1,
                      queue_capacity=4, classes=classes, max_retries=1,
                      retry_backoff_ms=0.1, fault_injector=inj,
                      cache_entries=4, recall_sample_rate=0.25, seed=0)
    rt.warmup()
    qs = rng.normal(size=(32, DIM)).astype(np.float32)
    rt.submit(np.full(DIM, np.nan, np.float32), now=0.0)     # poison
    names = ("default", "batch", "interactive")
    t = 0.0
    for i in range(20):
        if i % 3 == 0:
            store.upsert(i, qs[i])          # churn -> flush-fault surface
        rt.submit(qs[i], now=t, cls=names[i % 3])
        rt.poll(now=t + 1e-3)
        t += 2e-3
    # displacement: fill the queue with sheddable batch work, then an
    # interactive arrival displaces the youngest batch victim
    for i in range(20, 25):
        rt.submit(qs[i], now=t, cls="batch")
    rt.submit(qs[25], now=t, cls="interactive")
    rt.drain(now=t + 1e-3)
    # deadline expiry: queue batch-class work, poll far past its deadline
    t += 1.0
    for i in range(26, 29):
        rt.submit(qs[i], now=t, cls="batch")
    rt.poll(now=t + 10.0)
    rt.drain(now=t + 10.0)
    return {"runtime": rt.stats(), "injector": inj.stats()}


def all_scenarios() -> dict:
    """The full scrubbed golden payload."""
    return _scrub({
        "engine": engine_scenario(),
        "store_engine": store_engine_scenario(),
        "runtime": runtime_scenario(),
    })


# ---- the regression test ------------------------------------------------

def test_stats_byte_compatible_with_golden():
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = json.loads(json.dumps(_drop_additive(all_scenarios())))
    assert list(got) == list(golden)
    for name in golden:
        assert got[name] == golden[name], (
            f"stats() drifted from the pre-PR golden in scenario "
            f"{name!r}:\n got: {json.dumps(got[name], indent=1)}\n "
            f"want: {json.dumps(golden[name], indent=1)}")


def test_key_order_pinned():
    """json round-trip preserves insertion order: pin it explicitly."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = json.loads(json.dumps(_drop_additive(all_scenarios())))

    def walk(a, b, path=""):
        assert list(a) == list(b), f"key order drift at {path or '/'}"
        for k in a:
            if isinstance(a[k], dict) and isinstance(b.get(k), dict):
                walk(a[k], b[k], f"{path}/{k}")

    for name in golden:
        walk(golden[name], got[name], name)


if __name__ == "__main__":
    import sys
    if "--write" in sys.argv:
        payload = all_scenarios()
        with open(GOLDEN, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=False)
        print(f"wrote {GOLDEN}")
    else:
        print(json.dumps(all_scenarios(), indent=1))
