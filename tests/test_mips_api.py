"""Public MIPS/NNS API behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_topk, mips_topk, nns_topk


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return (rng.normal(size=(800, 1024)).astype(np.float32),
            rng.normal(size=1024).astype(np.float32))


def test_exact_topk(data):
    V, q = data
    ids, scores = exact_topk(jnp.asarray(V), jnp.asarray(q), K=4)
    truth = np.argsort(-(V @ q))[:4]
    np.testing.assert_array_equal(np.asarray(ids), truth)
    np.testing.assert_allclose(np.asarray(scores),
                               (V @ q)[truth] / V.shape[1], rtol=1e-5)


def test_mips_topk_boundedme_matches_exact_small_eps(data):
    V, q = data
    ids, _ = mips_topk(V, q, K=3, method="boundedme", eps=1e-4, delta=0.05,
                       key=jax.random.PRNGKey(0), final_exact=True)
    truth = np.argsort(-(V @ q))[:3]
    assert set(np.asarray(ids).tolist()) == set(truth.tolist())


def test_mips_topk_rejects_unknown_method(data):
    V, q = data
    with pytest.raises(ValueError):
        mips_topk(V, q, method="annoy")


def test_nns_reduction(data):
    V, q = data
    ids, _ = nns_topk(V, q, K=1, method="boundedme", eps=1e-4, delta=0.05,
                      key=jax.random.PRNGKey(1), final_exact=True)
    truth = np.argmin(((V - q[None]) ** 2).sum(1))
    assert int(ids[0]) == int(truth)


def test_nns_exact_mode(data):
    V, q = data
    ids, _ = nns_topk(V, q, K=1, method="exact")
    truth = np.argmin(((V - q[None]) ** 2).sum(1))
    assert int(ids[0]) == int(truth)
