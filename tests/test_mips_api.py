"""Public MIPS/NNS API behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact_topk, mips_topk, nns_topk


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    return (rng.normal(size=(800, 1024)).astype(np.float32),
            rng.normal(size=1024).astype(np.float32))


def test_exact_topk(data):
    V, q = data
    ids, scores = exact_topk(jnp.asarray(V), jnp.asarray(q), K=4)
    truth = np.argsort(-(V @ q))[:4]
    np.testing.assert_array_equal(np.asarray(ids), truth)
    np.testing.assert_allclose(np.asarray(scores),
                               (V @ q)[truth] / V.shape[1], rtol=1e-5)


def test_mips_topk_boundedme_matches_exact_small_eps(data):
    V, q = data
    ids, _ = mips_topk(V, q, K=3, method="boundedme", eps=1e-4, delta=0.05,
                       key=jax.random.PRNGKey(0), final_exact=True)
    truth = np.argsort(-(V @ q))[:3]
    assert set(np.asarray(ids).tolist()) == set(truth.tolist())


def test_mips_topk_rejects_unknown_method(data):
    V, q = data
    with pytest.raises(ValueError):
        mips_topk(V, q, method="annoy")


def test_nns_reduction(data):
    V, q = data
    ids, _ = nns_topk(V, q, K=1, method="boundedme", eps=1e-4, delta=0.05,
                      key=jax.random.PRNGKey(1), final_exact=True)
    truth = np.argmin(((V - q[None]) ** 2).sum(1))
    assert int(ids[0]) == int(truth)


def test_nns_exact_mode(data):
    V, q = data
    ids, _ = nns_topk(V, q, K=1, method="exact")
    truth = np.argmin(((V - q[None]) ** 2).sum(1))
    assert int(ids[0]) == int(truth)


def test_default_value_range_cached_per_table(data):
    """The O(nN) table reduction runs once per table object, not per call."""
    from repro.core import mips

    V, q = data
    Vj = jnp.asarray(V)
    v1 = mips.table_abs_max(Vj)
    key = id(Vj)
    assert key in mips._TABLE_MAX._entries
    # poison the cached value: a second call must hit the cache, not recompute
    ref, _ = mips._TABLE_MAX._entries[key]
    mips._TABLE_MAX._entries[key] = (ref, 123.5)
    assert mips.table_abs_max(Vj) == 123.5
    del mips._TABLE_MAX._entries[key]
    assert abs(v1 - float(np.abs(V).max())) < 1e-6
    vr = mips.default_value_range(Vj, jnp.asarray(q))
    assert vr >= 2.0 * abs(q).max() * v1 * 0.999
