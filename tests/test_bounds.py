"""Unit + property tests for the without-replacement concentration bounds."""

import math

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import bounds


class TestRho:
    def test_rho_decreases_to_zero(self):
        N = 100
        vals = [bounds.rho_m(m, N) for m in range(1, N + 1)]
        assert all(v >= -1e-12 for v in vals)
        assert vals[-1] <= 1.0 / N + 1e-12  # nearly 0 at m=N
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))

    def test_rho_at_one_is_one(self):
        assert bounds.rho_m(1, 1000) == pytest.approx(1.0, abs=1e-9)

    @given(st.integers(2, 10_000), st.integers(1, 10_000))
    @settings(max_examples=200, deadline=None)
    def test_rho_in_unit_interval(self, N, m):
        m = min(m, N)
        assert -1e-12 <= bounds.rho_m(m, N) <= 1.0 + 1e-12


class TestMRequired:
    @given(st.floats(1e-3, 0.99), st.floats(1e-3, 0.5),
           st.integers(2, 1_000_000))
    @settings(max_examples=300, deadline=None)
    def test_never_exceeds_N(self, eps, delta, N):
        assert 1 <= bounds.m_required(eps, delta, N) <= N

    def test_saturates_as_eps_to_zero(self):
        N = 1000
        assert bounds.m_required(1e-9, 0.1, N) == N

    def test_monotone_in_eps(self):
        N = 100_000
        ms = [bounds.m_required(e, 0.1, N) for e in (0.5, 0.2, 0.1, 0.05)]
        assert ms == sorted(ms)

    def test_beats_hoeffding(self):
        # the whole point of MAB-BP: m(u) <= min(N, Hoeffding m)
        for eps in (0.01, 0.05, 0.2):
            for N in (100, 10_000):
                m_wr = bounds.m_required(eps, 0.1, N)
                m_h = bounds.hoeffding_required(eps, 0.1)
                assert m_wr <= min(N, m_h) + 1

    def test_satisfies_corollary_inequality(self):
        # plugging m back in: deviation at m samples should be <= eps
        for eps in (0.02, 0.1, 0.3):
            for N in (500, 50_000):
                m = bounds.m_required(eps, 0.05, N)
                if m < N:
                    assert bounds.deviation_bound(m, N, 0.05) <= eps * 1.01


class TestEmpiricalCoverage:
    """Statistical validation of Corollary 1 on real sampling."""

    @pytest.mark.parametrize("eps,delta", [(0.1, 0.1), (0.05, 0.2)])
    def test_without_replacement_coverage(self, eps, delta):
        rng = np.random.default_rng(0)
        N = 2000
        x = rng.uniform(0, 1, N)
        mu = x.mean()
        m = bounds.m_required(eps, delta, N)
        trials = 400
        fails = 0
        for t in range(trials):
            sample = rng.choice(x, size=m, replace=False)
            if sample.mean() - mu > eps:
                fails += 1
        # failure rate must respect delta (generous slack for 400 trials)
        assert fails / trials <= delta + 0.05

    def test_exact_at_full_sample(self):
        rng = np.random.default_rng(1)
        x = rng.uniform(0, 1, 512)
        assert bounds.deviation_bound(512, 512, 0.01) == 0.0
