"""Unit + property tests for the without-replacement concentration bounds."""

import math

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import bounds


class TestRho:
    def test_rho_decreases_to_zero(self):
        N = 100
        vals = [bounds.rho_m(m, N) for m in range(1, N + 1)]
        assert all(v >= -1e-12 for v in vals)
        assert vals[-1] <= 1.0 / N + 1e-12  # nearly 0 at m=N
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))

    def test_rho_at_one_is_one(self):
        assert bounds.rho_m(1, 1000) == pytest.approx(1.0, abs=1e-9)

    @given(st.integers(2, 10_000), st.integers(1, 10_000))
    @settings(max_examples=200, deadline=None)
    def test_rho_in_unit_interval(self, N, m):
        m = min(m, N)
        assert -1e-12 <= bounds.rho_m(m, N) <= 1.0 + 1e-12


class TestMRequired:
    @given(st.floats(1e-3, 0.99), st.floats(1e-3, 0.5),
           st.integers(2, 1_000_000))
    @settings(max_examples=300, deadline=None)
    def test_never_exceeds_N(self, eps, delta, N):
        assert 1 <= bounds.m_required(eps, delta, N) <= N

    def test_saturates_as_eps_to_zero(self):
        N = 1000
        assert bounds.m_required(1e-9, 0.1, N) == N

    def test_monotone_in_eps(self):
        N = 100_000
        ms = [bounds.m_required(e, 0.1, N) for e in (0.5, 0.2, 0.1, 0.05)]
        assert ms == sorted(ms)

    def test_beats_hoeffding(self):
        # the whole point of MAB-BP: m(u) <= min(N, Hoeffding m)
        for eps in (0.01, 0.05, 0.2):
            for N in (100, 10_000):
                m_wr = bounds.m_required(eps, 0.1, N)
                m_h = bounds.hoeffding_required(eps, 0.1)
                assert m_wr <= min(N, m_h) + 1

    def test_satisfies_corollary_inequality(self):
        # plugging m back in: deviation at m samples should be <= eps
        for eps in (0.02, 0.1, 0.3):
            for N in (500, 50_000):
                m = bounds.m_required(eps, 0.05, N)
                if m < N:
                    assert bounds.deviation_bound(m, N, 0.05) <= eps * 1.01


class TestEmpiricalCoverage:
    """Statistical validation of Corollary 1 on real sampling."""

    @pytest.mark.parametrize("eps,delta", [(0.1, 0.1), (0.05, 0.2)])
    def test_without_replacement_coverage(self, eps, delta):
        rng = np.random.default_rng(0)
        N = 2000
        x = rng.uniform(0, 1, N)
        mu = x.mean()
        m = bounds.m_required(eps, delta, N)
        trials = 400
        fails = 0
        for t in range(trials):
            sample = rng.choice(x, size=m, replace=False)
            if sample.mean() - mu > eps:
                fails += 1
        # failure rate must respect delta (generous slack for 400 trials)
        assert fails / trials <= delta + 0.05

    def test_exact_at_full_sample(self):
        rng = np.random.default_rng(1)
        x = rng.uniform(0, 1, 512)
        assert bounds.deviation_bound(512, 512, 0.01) == 0.0


class TestFullCoverageEdge:
    """ISSUE 5 satellite: m >= N edge behavior is clamped in `bounds`,
    never left for callers to cap."""

    def test_rho_m_is_exactly_zero_at_and_past_N(self):
        for N in (2, 7, 100, 10_000):
            assert bounds.rho_m(N, N) == 0.0
            assert bounds.rho_m(N + 1, N) == 0.0
            assert bounds.rho_m(10 * N, N) == 0.0

    def test_m_required_clamps_nonfinite_u_to_N(self):
        # eps small enough to overflow u to inf used to raise from
        # ceil(inf/inf); now it returns full coverage
        for N in (10, 1000, 1_000_000):
            assert bounds.m_required(1e-300, 0.05, N) == N
            assert bounds.m_required(1e-30, 0.05, N, value_range=1e30) == N

    def test_deviation_bound_zero_past_N(self):
        assert bounds.deviation_bound(501, 500, 0.1) == 0.0
        assert bounds.bernstein_radius(501, 500, 0.1, 1.0, 0.3) == 0.0

    def test_m_required_eb_clamps_to_N(self):
        for N in (10, 1000):
            assert bounds.m_required_eb(1e-300, 0.05, N) == N
            assert 1 <= bounds.m_required_eb(0.5, 0.05, N) <= N


class TestBernsteinFamily:
    """The variance-aware empirical Bernstein–Serfling radius family."""

    def test_radius_nonincreasing_in_m(self):
        N = 2000
        vals = [bounds.bernstein_radius(m, N, 0.05, 1.0, 0.25)
                for m in range(1, N + 1)]
        assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))
        assert vals[-1] == 0.0

    def test_low_variance_beats_hoeffding_radius(self):
        # past the additive-term crossover (m ~ kappa^2 log(5/delta)),
        # near-zero empirical variance certifies far tighter than the
        # variance-blind Hoeffding-Serfling radius
        N, delta = 5000, 0.05
        for m in (500, 2000):
            eb = bounds.bernstein_radius(m, N, delta, 1.0, std=0.01)
            hs = bounds.deviation_bound(m, N, delta, 1.0)
            assert eb < hs

    def test_m_required_eb_shrinks_with_variance(self):
        N, eps, delta = 50_000, 0.05, 0.05
        m_hi = bounds.m_required_eb(eps, delta, N, std=0.5)   # worst case
        m_lo = bounds.m_required_eb(eps, delta, N, std=0.01)
        assert m_lo < m_hi <= N

    def test_m_required_eb_satisfies_its_radius(self):
        N, delta = 10_000, 0.05
        for eps in (0.02, 0.1, 0.3):
            for std in (0.01, 0.2, 0.5):
                m = bounds.m_required_eb(eps, delta, N, 1.0, std)
                assert bounds.bernstein_radius(m, N, delta, 1.0, std) <= eps
                if m > 1:
                    assert bounds.bernstein_radius(m - 1, N, delta, 1.0,
                                                   std) > eps

    def test_empirical_coverage_of_eb_radius(self):
        """The anytime EB radius must cover the true mean on real samples
        (statistical, seeded, generous slack)."""
        rng = np.random.default_rng(7)
        N, m, delta = 4000, 300, 0.1
        x = rng.uniform(0.4, 0.6, N)          # low-variance list
        mu = x.mean()
        fails = 0
        trials = 300
        for _ in range(trials):
            s = rng.choice(x, size=m, replace=False)
            rad = bounds.bernstein_radius(m, N, delta, 0.2,
                                          std=float(s.std()))
            if abs(s.mean() - mu) > rad:
                fails += 1
        assert fails / trials <= delta + 0.06


class TestCoordFamily:
    """The coordinate-estimator radius family (ISSUE 7, DESIGN.md §14)."""

    def test_monotone_nonincreasing_in_m(self):
        d_blocks, delta = 64, 0.1
        radii = [bounds.coord_radius(m, d_blocks, delta, 2.0)
                 for m in range(1, d_blocks + 1)]
        assert all(a >= b for a, b in zip(radii, radii[1:]))

    def test_exactly_zero_at_full_coverage(self):
        for d_blocks in (2, 16, 64, 257):
            for extra in (0, 1, 10):
                assert bounds.coord_radius(d_blocks + extra, d_blocks,
                                           0.05, 3.0) == 0.0

    def test_value_range_scaling_is_linear(self):
        d_blocks, delta = 128, 0.05
        for m in (1, 7, 64):
            r1 = bounds.coord_radius(m, d_blocks, delta, 1.0)
            r2 = bounds.coord_radius(m, d_blocks, delta, 2.0)
            assert r2 == pytest.approx(2.0 * r1, rel=1e-12)

    def test_quant_err_widens_as_range(self):
        # the widening identity pinned by the docstring: the int8 bias
        # enters the radius purely as +2*quant_err of range
        d_blocks, delta, vr, qe = 64, 0.1, 2.0, 0.125
        for m in (1, 5, 33):
            assert (bounds.coord_radius(m, d_blocks, delta, vr, qe)
                    == bounds.coord_radius(m, d_blocks, delta,
                                           vr + 2.0 * qe, 0.0))
            assert (bounds.coord_radius(m, d_blocks, delta, vr, qe)
                    > bounds.coord_radius(m, d_blocks, delta, vr, 0.0))

    def test_m_required_inverts_radius(self):
        d_blocks, delta, vr = 256, 0.05, 2.0
        for eps in (0.05, 0.2, 1.0):
            m = bounds.coord_m_required(eps, delta, d_blocks, vr)
            assert 1 <= m <= d_blocks
            assert bounds.coord_radius(m, d_blocks, delta, vr) <= eps
            if m > 1:
                assert bounds.coord_radius(m - 1, d_blocks, delta, vr) > eps

    def test_overflow_clamps_to_full_coverage(self):
        # eps -> 0: u_term overflows to inf; must clamp to d_blocks, never
        # raise or return nan (the m_required edge behavior, inherited)
        for eps in (1e-300, 1e-30):
            assert bounds.coord_m_required(eps, 0.05, 64) == 64

    def test_quant_bias_exhausting_budget_forces_full_coverage(self):
        # deterministic bias >= eps: sampling cannot help; only full
        # coverage (zero sampling error) is valid
        assert bounds.coord_m_required(0.1, 0.05, 64, 2.0,
                                       quant_err=0.1) == 64
        assert bounds.coord_m_required(0.1, 0.05, 64, 2.0,
                                       quant_err=0.2) == 64
        # bias strictly inside the budget: strictly fewer than full
        # coverage once eps is loose enough
        m = bounds.coord_m_required(4.0, 0.05, 64, 2.0, quant_err=0.1)
        assert m < 64

    def test_degenerate_single_block(self):
        assert bounds.coord_m_required(0.5, 0.05, 1) == 1
        assert bounds.coord_radius(1, 1, 0.05) == 0.0
