"""Property test: TableRegistry invariants under random interleavings.

Hypothesis (via the `optional_hypothesis` shim in conftest — skips
gracefully when the package is absent) drives random sequences of
register / evict / pin / unpin / serve / mutate+flush / grow across
four tenants against a byte-budgeted registry and asserts, after every
single operation:

* accounting is truthful — the registry's ``resident_bytes()`` equals
  the sum of the resident stores' actual ``resident_bytes()``;
* the budget holds — resident bytes never exceed the budget, with the
  single documented exception of *pinned* tables growing past it (the
  operator override);
* eviction candidates are exactly the unpinned, resident, not-in-flight
  tables, ordered least-recently-served first;
* a tenant marked in-flight (serving) is never an eviction candidate;
* paging out and back in is content-preserving — a tenant served after
  eviction sees exactly the rows it had when evicted.

No executors are built here (that jit cost belongs to the isolation
suite); the invariants are pure registry state machine properties.
"""

import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.launch.tenancy import TableRegistry, TenancyError, TenantConfig
from repro.store import DynamicTableStore

given, settings, st = optional_hypothesis()

DIM = 32
ROWS = 32
GROWN = ROWS * 2
NAMES = ("t0", "t1", "t2", "t3")
OPS = ("register", "evict", "pin", "unpin", "serve", "mutate", "grow")


def _rows(i):
    rng = np.random.default_rng(100 + i)
    return rng.normal(size=(ROWS, DIM)).astype(np.float32)


def _unit_bytes():
    return DynamicTableStore(_rows(0)).resident_bytes()


@given(st.lists(st.tuples(st.sampled_from(OPS),
                          st.integers(min_value=0, max_value=3)),
                min_size=1, max_size=60))
@settings(max_examples=25, deadline=None)
def test_registry_invariants_under_random_interleavings(ops):
    unit = _unit_bytes()
    # two plain tables fit, a third forces eviction, and one grown
    # table still needs a rebalance next to a plain one
    budget = int(2.2 * unit)
    reg = TableRegistry(byte_budget=budget, lanes=2)
    registered = set()
    pinned = set()
    stashed = {}          # name -> host rows captured at eviction

    for kind, i in ops:
        name = NAMES[i]
        if kind == "register":
            if name in registered:
                with pytest.raises(TenancyError):
                    reg.register(name, _rows(i))
            else:
                try:
                    reg.register(name, _rows(i),
                                 TenantConfig(deadline_ms=0.0))
                    registered.add(name)
                except TenancyError:
                    # no room and nothing evictable: refused, unchanged
                    assert name not in reg.tenants()
        elif name not in registered:
            # every other op on an unknown tenant is a typed refusal
            with pytest.raises(TenancyError):
                if kind == "evict":
                    reg.evict(name)
                elif kind == "pin":
                    reg.pin(name)
                elif kind == "unpin":
                    reg.unpin(name)
                else:
                    reg.store(name)
        elif kind == "evict":
            if reg.is_resident(name):
                expected = np.array(reg.store(name).host_table(),
                                    copy=True)
                if name in pinned:
                    with pytest.raises(TenancyError):
                        reg.evict(name)
                else:
                    reg.evict(name)
                    stashed[name] = expected
            else:
                reg.evict(name)            # idempotent no-op
        elif kind == "pin":
            reg.pin(name)
            pinned.add(name)
        elif kind == "unpin":
            reg.unpin(name)
            pinned.discard(name)
        elif kind == "serve":
            try:
                with reg.serving(name):
                    reg.ensure_resident(name)
                    reg.touch(name)
                    # in-flight tables are never eviction candidates
                    assert name not in reg.lru_order()
            except TenancyError:
                continue    # no room to page in (everything pinned)
            got = reg.store(name).host_table()
            if name in stashed:
                np.testing.assert_array_equal(got, stashed.pop(name))
        elif kind == "mutate":
            store = reg.store(name)
            if store is not None:
                store.upsert(0, _rows(i)[1])
                store.flush_updates()
                stashed.pop(name, None)
        elif kind == "grow":
            store = reg.store(name)
            if store is not None and store.capacity_rows < GROWN:
                store.grow(GROWN)
                try:
                    reg.ensure_resident(name)   # re-account + rebalance
                except TenancyError:
                    # grown table itself was paged back out
                    assert not reg.is_resident(name)
                stashed.pop(name, None)

        # ---- invariants, after every operation ------------------------
        assert set(reg.tenants()) == registered
        resident = [n for n in reg.tenants() if reg.is_resident(n)]
        actual = sum(reg.store(n).resident_bytes() for n in resident)
        assert actual == reg.resident_bytes(), "untruthful accounting"
        assert (reg.resident_bytes() <= budget
                or all(reg.is_pinned(n) for n in resident)), \
            "budget exceeded by evictable tables"
        order = reg.lru_order()
        assert order == [n for n in sorted(
            resident, key=lambda n: reg.stats()["tenants"][n]["last_serve"])
            if not reg.is_pinned(n)]
        for n in pinned & set(resident):
            assert reg.is_pinned(n)
